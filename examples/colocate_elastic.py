"""Co-located serving + training with the (lt, ut) elastic scheduler.

The paper's headline scenario (Figs 10/11): a latency-critical serving
cell shares a machine with a batch training cell; the supervisor moves
columns between them based on the serving tail latency.  Here both cells
are real (8 virtual devices), the serving latency is measured per decode
batch, and the ThresholdScheduler triggers real column transfers with
live resharding on both cells.

Run:  PYTHONPATH=src python examples/colocate_elastic.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig, smoke_config
from repro.configs.registry import get_arch
from repro.core import DeviceGrid, ElasticPolicy, Supervisor, ThresholdScheduler
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.train.optimizer import OptConfig


def main():
    grid = DeviceGrid.from_flat(jax.devices(), pods=1, rows=2, cols=4)
    sup = Supervisor(grid)
    arch = smoke_config(get_arch("qwen3-4b"))

    server = sup.create_cell("server", arch, "serve", ncols=1)
    server.init_serve()
    trainer = sup.create_cell("batch", arch, "train", ncols=3,
                              opt_cfg=OptConfig(lr=1e-3))
    pipe = SyntheticPipeline(DataConfig(kind="bigram", vocab=256), arch,
                             ShapeConfig("t", "train", 32, 24))

    # synthetic SLO: tail threshold band around the measured decode time
    sched = ThresholdScheduler(
        sup, "server", "batch",
        ElasticPolicy(lt=0.0, ut=0.0, window=8, cooldown=0.0,
                      min_server_cols=1, min_donor_cols=1),
    )

    jit_cache = {}

    def serve_batch(load: int):
        """Measure decode latency under `load` queued decode batches."""
        B, S = 4, 32
        model = server.model      # rebuilt by resize -> fresh compile (real cost)
        if id(model) not in jit_cache:
            jit_cache.clear()
            jit_cache[id(model)] = jax.jit(model.decode)
        step = jit_cache[id(model)]
        cache = model.init_cache(B, S)
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32),
                 "pos": jnp.zeros((B,), jnp.int32)}
        logits, cache = step(server.serve_params, cache, batch)  # warm
        t0 = time.perf_counter()
        for _ in range(load):
            logits, cache = step(server.serve_params, cache, batch)
        logits.block_until_ready()
        # the tail request waits for the whole queue: its latency is the
        # full drain time (this is what the SLO sees under load)
        return time.perf_counter() - t0

    # calibrate the SLO band to this machine: lt/ut around the idle latency
    idle = np.median([serve_batch(2) for _ in range(3)])
    sched.policy = ElasticPolicy(lt=idle * 1.3, ut=idle * 2.0, window=8,
                                 cooldown=0.0, min_server_cols=1, min_donor_cols=1)
    print(f"idle decode latency {idle*1e3:.1f} ms -> band "
          f"({sched.policy.lt*1e3:.1f}, {sched.policy.ut*1e3:.1f}) ms")

    phases = [("calm", 2), ("burst", 14), ("calm", 2)]
    for phase, load in phases:
        for tick in range(4):
            lat = serve_batch(load)
            sched.observe(lat)
            act = sched.maybe_act()
            trainer.train_steps(pipe.get_batch, 1)
            note = f" -> {act['kind']}" if act else ""
            print(f"[{phase:5s}] lat={lat*1e3:6.1f}ms "
                  f"server={sup.cells['server'].zone.ncols}col "
                  f"batch={sup.cells['batch'].zone.ncols}col{note}")
    print(f"actions: {[a['kind'] for a in sched.actions]}")
    print(f"trainer reached step {trainer.step}; epoch {sup.table.epoch}")


if __name__ == "__main__":
    main()
