"""Co-located serving + training under the declarative elastic loop.

The paper's headline scenario (Figs 10/11): a latency-critical serving
cell shares a machine with a batch training cell.  Desired state is a
ClusterSpec — server bounded to [1, 3] columns, trainer taking the rest
— and a :class:`ReconcilePolicy` closes the loop: the serving cell's
batcher records per-request TTFT into its ``CellAccounting``, the policy
pulls those live samples, and on a threshold crossing it rewrites the
spec's desired ``ncols`` and re-applies it.  The reconciler turns every
+1/-1 into a real column transfer with live resharding on both cells —
this file never touches a resize/transfer primitive.

Run:  PYTHONPATH=src python examples/colocate_elastic.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import itertools

import numpy as np
import jax

from repro.configs.base import ShapeConfig, smoke_config
from repro.configs.registry import get_arch
from repro.core import (
    CellSpec,
    ClusterSpec,
    DeviceGrid,
    ElasticPolicy,
    ReconcilePolicy,
    SLOTarget,
    Supervisor,
)
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.serve.batcher import Request
from repro.train.optimizer import OptConfig

MAX_LEN, SLOTS, PROMPT_LEN, MAX_NEW = 48, 4, 12, 4


def main():
    grid = DeviceGrid.from_flat(jax.devices(), pods=1, rows=2, cols=4)
    sup = Supervisor(grid)
    arch = smoke_config(get_arch("qwen3-4b"))

    # -- desired state: bounded serving cell + batch trainer on the rest
    spec = ClusterSpec(cells=(
        CellSpec("server", arch, "serve", ncols=1, min_ncols=1, max_ncols=3),
        CellSpec("batch", arch, "train", ncols=3, min_ncols=1,
                 opt_cfg=OptConfig(lr=1e-3)),
    ))
    sup.apply(spec)
    server, trainer = sup.cells["server"], sup.cells["batch"]
    server.init_serve()
    pipe = SyntheticPipeline(DataConfig(kind="bigram", vocab=256), arch,
                             ShapeConfig("t", "train", 32, 24))

    # the batcher is rebuilt after any topology change (resize rebuilds the
    # cell's model -> fresh compile, a real cost the elastic loop pays)
    state = {"epoch": None, "bat": None}

    def batcher():
        if state["epoch"] != server.zone_epoch:
            state["epoch"] = server.zone_epoch
            state["bat"] = server.make_batcher(batch_slots=SLOTS, max_len=MAX_LEN)
        return state["bat"]

    rng = np.random.default_rng(0)
    rid = itertools.count()

    def serve_tick(load: int):
        """Submit `load` requests and drain them; TTFT/TPOT land in the
        server cell's CellAccounting (what the policy reads)."""
        bat = batcher()
        for _ in range(load):
            prompt = rng.integers(1, arch.vocab, size=PROMPT_LEN).astype(np.int32)
            bat.submit(Request(rid=next(rid), prompt=prompt,
                               max_new_tokens=MAX_NEW))
        bat.run_until_drained()

    # calibrate the SLO band to this machine: lt/ut around the idle TTFT
    for _ in range(3):
        serve_tick(2)
    idle = float(np.median([r.ttft for r in server.accounting.requests
                            if r.ttft is not None]))
    slo = SLOTarget(ttft_p99=idle * 2.0)
    spec = spec.with_cell(
        dataclasses.replace(spec.cell("server"), slo=slo))
    sup.apply(spec)
    policy = ReconcilePolicy(
        sup, "server", "batch",
        ElasticPolicy(lt=idle * 1.3, ut=slo.ttft_p99, window=8,
                      percentile=99.0, cooldown=0.0, metric="ttft"),
    )
    print(f"idle TTFT {idle*1e3:.1f} ms -> band "
          f"({policy.policy.lt*1e3:.1f}, {policy.policy.ut*1e3:.1f}) ms")
    # the calibration's first tick paid program compiles; keep those
    # TTFT samples out of the policy window
    policy.pull()
    policy.samples.clear()

    phases = [("calm", 2), ("burst", 14), ("calm", 2)]
    for phase, load in phases:
        for _tick in range(4):
            serve_tick(load)
            act = policy.maybe_act()
            if act:
                # warm the rebuilt batcher (fresh mesh -> fresh compile)
                # and drop the compile-tainted samples from the window
                serve_tick(2)
                policy.pull()
                policy.samples.clear()
            trainer.train_steps(pipe.get_batch, 1)
            note = f" -> {act['kind']} [{act['plan']}]" if act else ""
            print(f"[{phase:5s}] server={sup.cells['server'].zone.ncols}col "
                  f"batch={sup.cells['batch'].zone.ncols}col{note}")
    print(f"actions: {[a['kind'] for a in policy.actions]}")
    print(f"served {len(server.accounting.requests)} requests; "
          f"trainer reached step {trainer.step}; epoch {sup.table.epoch}")
    print(f"reconcile converged: {sup.reconcile().empty}")


if __name__ == "__main__":
    main()
