"""Quickstart: the IFTS runtime in ~60 lines.

Boots a supervisor over the local device grid, spawns a training cell
(a subOS), trains a tiny model, resizes the cell on the fly, opens an
on-demand channel to a serving cell, syncs weights, and serves a request.

Run:  PYTHONPATH=src python examples/quickstart.py
(uses 8 virtual host devices so resize/transfer are real)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax

from repro.configs.base import ShapeConfig, smoke_config
from repro.configs.registry import get_arch
from repro.core import DeviceGrid, Supervisor
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.serve.batcher import Request
from repro.train.optimizer import OptConfig


def main():
    # -- supervisor boots first (paper: the firstly-booted instance)
    grid = DeviceGrid.from_flat(jax.devices(), pods=1, rows=2, cols=4)
    sup = Supervisor(grid)
    print(f"supervisor up: grid={grid.shape}, epoch={sup.table.epoch}")

    # -- spawn a training cell (a subOS) on 2 columns (2x2 chips)
    arch = smoke_config(get_arch("qwen3-4b"))
    trainer = sup.create_cell("trainer", arch, "train", ncols=2,
                              opt_cfg=OptConfig(lr=1e-3, warmup_steps=20, total_steps=400))
    pipe = SyntheticPipeline(DataConfig(kind="bigram", vocab=256), arch,
                             ShapeConfig("t", "train", 32, 32))
    m = trainer.train_steps(pipe.get_batch, 20)
    print(f"trained 20 steps on {trainer.zone.ncols} cols: xent={m['xent']:.3f}")

    # -- elastic resize: grow the cell, keep training (live reshard)
    stats = sup.resize_cell("trainer", 3)
    print(f"resized 2->3 cols in {stats['seconds']:.3f}s "
          f"({stats['bytes']/1e6:.1f} MB resharded)")
    m = trainer.train_steps(pipe.get_batch, 10)
    print(f"10 more steps on 3 cols: xent={m['xent']:.3f}")

    # -- spawn a serving cell and share weights over an on-demand channel
    server = sup.create_cell("server", arch, "serve", ncols=1)
    server.init_serve()
    ch = sup.open_channel("trainer", "server")
    shardings = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(server.mesh, s),
        server.model.params_pspecs())
    st = ch.send(trainer.state.params, shardings)
    server.serve_params = ch.recv()
    print(f"weight sync: {st['bytes']/1e6:.1f} MB in {st['seconds']*1e3:.1f} ms")

    # -- serve
    bat = server.make_batcher(batch_slots=4, max_len=64)
    bat.submit(Request(rid=0, prompt=np.array([5, 7, 11], np.int32), max_new_tokens=8))
    done = bat.run_until_drained()
    print(f"served request -> tokens {done[0].output}")

    # -- accounting: exact, per-cell (nothing is shared)
    print(f"events: {[e['op'] for e in sup.events]}")
    print(f"final epoch: {sup.table.epoch}")
    sup.destroy_cell("server")
    sup.destroy_cell("trainer")
    print("done.")


if __name__ == "__main__":
    main()
