"""Quickstart: the IFTS runtime in ~60 lines, declaratively.

Boots a supervisor over the local device grid, applies a ClusterSpec
(the desired state: one training cell), trains a tiny model, *rescales
the spec* to grow the cell on the fly, adds a serving cell + weight-sync
channel to the spec, and serves a request.  Every topology change goes
through ``Supervisor.apply`` — the reconciler turns the spec diff into
create/resize/channel primitives.

Run:  PYTHONPATH=src python examples/quickstart.py
(uses 8 virtual host devices so resize/transfer are real)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax

from repro.configs.base import ShapeConfig, smoke_config
from repro.configs.registry import get_arch
from repro.core import CellSpec, ChannelSpec, ClusterSpec, DeviceGrid, Supervisor
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.serve.batcher import Request
from repro.train.optimizer import OptConfig


def main():
    # -- supervisor boots first (paper: the firstly-booted instance)
    grid = DeviceGrid.from_flat(jax.devices(), pods=1, rows=2, cols=4)
    sup = Supervisor(grid)
    print(f"supervisor up: grid={grid.shape}, epoch={sup.table.epoch}")

    # -- desired state: one training cell (a subOS) on 2 columns
    arch = smoke_config(get_arch("qwen3-4b"))
    spec = ClusterSpec(cells=(
        CellSpec("trainer", arch, "train", ncols=2, min_ncols=1, max_ncols=3,
                 opt_cfg=OptConfig(lr=1e-3, warmup_steps=20, total_steps=400)),
    ))
    plan = sup.apply(spec)
    print(f"applied spec -> plan [{plan.summary()}], epoch={sup.table.epoch}")
    trainer = sup.cells["trainer"]
    pipe = SyntheticPipeline(DataConfig(kind="bigram", vocab=256), arch,
                             ShapeConfig("t", "train", 32, 32))
    m = trainer.train_steps(pipe.get_batch, 20)
    print(f"trained 20 steps on {trainer.zone.ncols} cols: xent={m['xent']:.3f}")

    # -- elastic grow: rewrite the DESIRED width; reconcile does the resize
    spec = spec.scale("trainer", 3)
    plan = sup.apply(spec)
    grow = plan.by_verb("grow")[0]
    print(f"rescaled 2->3 cols [{grow.status}] "
          f"({grow.result['bytes']/1e6:.1f} MB resharded)")
    m = trainer.train_steps(pipe.get_batch, 10)
    print(f"10 more steps on 3 cols: xent={m['xent']:.3f}")

    # -- add a serving cell + an on-demand weight channel to the spec
    spec = spec.with_cell(CellSpec("server", arch, "serve", ncols=1)) \
               .with_channel(ChannelSpec("trainer", "server"))
    plan = sup.apply(spec)
    print(f"applied serving spec -> plan [{plan.summary()}]")
    server = sup.cells["server"]
    server.init_serve()
    ch = sup.find_channel("trainer", "server")
    shardings = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(server.mesh, s),
        server.model.params_pspecs())
    st = ch.send(trainer.state.params, shardings)
    server.serve_params = ch.recv()
    print(f"weight sync: {st['bytes']/1e6:.1f} MB in {st['seconds']*1e3:.1f} ms")

    # -- serve
    bat = server.make_batcher(batch_slots=4, max_len=64)
    bat.submit(Request(rid=0, prompt=np.array([5, 7, 11], np.int32), max_new_tokens=8))
    done = bat.run_until_drained()
    print(f"served request -> tokens {done[0].output}")

    # -- converged: reconcile again is a no-op
    print(f"reconcile converged: {sup.reconcile().empty}")
    print(f"events: {[e['op'] for e in sup.events]}")

    # -- empty spec tears everything down
    sup.apply(ClusterSpec())
    print(f"final epoch: {sup.table.epoch}, cells: {list(sup.cells)}")
    print("done.")


if __name__ == "__main__":
    main()
