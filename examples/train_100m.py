"""End-to-end driver: train a ~100M-param qwen3-family model.

Demonstrates the full training substrate — sharded init, microbatched
train step, deterministic restart-safe data pipeline, async checkpointing,
and (the fault-tolerance path) a mid-run simulated failure with restore
from the last checkpoint.  Loss should drop toward the bigram-chain
entropy floor.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse
import shutil
import time

import jax

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import single_device_grid, Supervisor
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.train.optimizer import OptConfig
from repro.train.train_step import abstract_train_state, train_state_pspecs

ARCH_100M = ArchConfig(
    name="qwen3-100m",
    family="dense",
    num_layers=10,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    head_dim=64,
    d_ff=2560,
    vocab=16384,
    vocab_pad_multiple=128,
    qk_norm=True,
    tie_embeddings=False,
    microbatch=1,
)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=60)  # CPU demo; use 300+ on real chips
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=32)
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = p.parse_args()

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    sup = Supervisor(single_device_grid())
    cell = sup.create_cell(
        "lm100m", ARCH_100M, "train", ncols=1,
        opt_cfg=OptConfig(lr=6e-4, warmup_steps=40, total_steps=args.steps),
    )
    print(f"model: {cell.model.n_params()/1e6:.1f}M params")
    shape = ShapeConfig("train", "train", args.seq, args.batch)
    pipe = SyntheticPipeline(DataConfig(kind="bigram", vocab=2048), ARCH_100M, shape)
    print(f"bigram entropy floor: {pipe.bigram_entropy():.3f} nats")

    t0 = time.time()
    fail_at = args.steps // 2
    pending = None
    while cell.step < args.steps:
        if cell.step == fail_at and cell.status != "recovered-once":
            # ---- simulated node failure + restore from checkpoint --------
            print(f"[{cell.step}] simulating failure; restoring from checkpoint")
            if pending is not None:
                pending.result()
            step = ckpt.latest_step(args.ckpt_dir)
            target = abstract_train_state(cell.model, cell.opt_cfg)
            shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(cell.mesh, s),
                train_state_pspecs(cell.model))
            cell.state = ckpt.restore(args.ckpt_dir, step, target, shardings)
            cell.step = step
            cell.status = "recovered-once"
            print(f"  restored at step {step} "
                  f"(data pipeline is deterministic — no batch skew)")
        m = cell.train_steps(pipe.get_batch, 10)
        if cell.step % args.ckpt_every == 0:
            pending = ckpt.save(args.ckpt_dir, cell.step, cell.state, blocking=False)
        tput = args.batch * args.seq * cell.step / (time.time() - t0)
        print(f"[{cell.step:4d}] xent={m['xent']:.3f} lr={m['lr']:.2e} "
              f"gnorm={m['grad_norm']:.2f} ({tput:,.0f} tok/s)")
    if pending is not None:
        pending.result()
    print(f"final xent {m['xent']:.3f} vs floor {pipe.bigram_entropy():.3f}")


if __name__ == "__main__":
    main()
