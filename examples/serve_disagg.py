"""Disaggregated serving demo: prefill cell -> KV channels -> 2 decode replicas.

The paper's "isolate first, then share on demand" applied to inference,
declared as desired state: a ClusterSpec names one prefill cell (2 cols),
a decode cell with ``replicas=2`` (two uniform 1-col cells), and one
``kv`` ChannelSpec that expands to a channel per replica.  One
``Supervisor.apply`` materializes all of it; the DisaggServer then routes
each request to the decode replica with the most free slots, same-bucket
prompts sharing ONE batched prefill invocation.  Weights flow on demand:
decode/0 initializes them, decode/1 and the prefill cell pull them over
array channels.

Run:  PYTHONPATH=src python examples/serve_disagg.py
(uses 8 virtual host devices so the cells sit on disjoint zones)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax

from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.core import CellSpec, ChannelSpec, ClusterSpec, DeviceGrid, Supervisor
from repro.serve.batcher import Request
from repro.serve.disagg import DisaggServer


def main():
    grid = DeviceGrid.from_flat(jax.devices(), pods=1, rows=2, cols=4)
    sup = Supervisor(grid)
    arch = smoke_config(get_arch("qwen3-4b"))

    # -- desired state: prompts vs tokens, decode scaled out to 2 replicas
    spec = ClusterSpec(
        cells=(CellSpec("prefill", arch, "serve", ncols=2),
               CellSpec("decode", arch, "serve", ncols=1, replicas=2)),
        channels=(ChannelSpec("prefill", "decode", kind="kv"),),
    )
    plan = sup.apply(spec)
    print(f"applied spec -> plan [{plan.summary()}], epoch={sup.table.epoch}")
    decode_names = spec.cell("decode").instances()
    print(f"cells up: prefill={sup.cells['prefill'].zone.ncols} cols, "
          f"decode replicas={decode_names}")
    sup.cells[decode_names[0]].init_serve(rng=jax.random.PRNGKey(0))

    # -- share on demand: weight fan-out + per-replica KV handoff channels
    srv = DisaggServer(sup, "prefill", decode_names,
                       batch_slots=2, max_len=64, chunk=16)
    print(f"channels: {[(c.kind, c.src.name, '->', c.dst.name) for c in sup.channels]}")

    # -- serve a burst of long-prompt requests
    rng = np.random.RandomState(0)
    for rid, L in enumerate([33, 40, 48, 35, 44, 38]):
        srv.submit(Request(rid=rid, prompt=rng.randint(1, arch.vocab, size=L).astype(np.int32),
                           max_new_tokens=8))
    done = srv.run_until_drained()
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  req {r.rid}: prompt={len(r.prompt)} toks "
              f"ttft={r.ttft * 1e3:.1f}ms tpot={r.tpot * 1e3:.1f}ms -> {r.output}")

    # -- the handoff in numbers: invocations, routing, channel traffic
    st = srv.stats()
    print(f"prefill invocations: {st['prefill_invocations']} (same-bucket "
          f"prompts batched; token-at-a-time would need "
          f"{sum(len(r.prompt) for r in done)})")
    print(f"decode invocations:  {st['decode_invocations']} across "
          f"{st['replicas']} replicas (requests per replica: "
          f"{st['per_replica_requests']})")
    print(f"kv channels: {st['kv_bytes'] / 1e6:.2f} MB over {st['kv_transfers']} "
          f"transfers in {st['kv_seconds'] * 1e3:.1f} ms")
    print(f"serving summary: {st['decode_serving']}")

    # -- empty spec tears everything down
    sup.apply(ClusterSpec())
    print(f"cells after teardown: {list(sup.cells)}")
    print("done.")


if __name__ == "__main__":
    main()
