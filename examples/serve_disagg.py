"""Disaggregated serving demo: prefill cell -> KV channel -> decode cell.

The paper's "isolate first, then share on demand" applied to inference:
two serving subOSes own their zones outright; the only coupling is the
on-demand channels the supervisor opens between them — one to sync the
weights (decode -> prefill), one to stream per-request KV-cache rows
(prefill -> decode).  Prompts run as single chunked-prefill program
invocations on the prefill cell; the decode cell only ever runs decode
steps, so its per-token latency never queues behind prompt processing.

Run:  PYTHONPATH=src python examples/serve_disagg.py
(uses 8 virtual host devices so the two cells sit on disjoint zones)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax

from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.core import DeviceGrid, Supervisor
from repro.serve.batcher import Request
from repro.serve.disagg import DisaggServer


def main():
    grid = DeviceGrid.from_flat(jax.devices(), pods=1, rows=2, cols=4)
    sup = Supervisor(grid)
    arch = smoke_config(get_arch("qwen3-4b"))

    # -- two isolated serving cells: prompts vs tokens
    sup.create_cell("prefill", arch, "serve", ncols=2)
    decode = sup.create_cell("decode", arch, "serve", ncols=1)
    decode.init_serve(rng=jax.random.PRNGKey(0))
    print(f"cells up: prefill={sup.cells['prefill'].zone.ncols} cols, "
          f"decode={decode.zone.ncols} cols, epoch={sup.table.epoch}")

    # -- share on demand: weight sync + KV handoff channels
    srv = DisaggServer(sup, "prefill", "decode",
                       batch_slots=4, max_len=64, chunk=16)
    print(f"channels: {[(c.kind, c.src.name, '->', c.dst.name) for c in sup.channels]}")

    # -- serve a burst of long-prompt requests
    rng = np.random.RandomState(0)
    for rid, L in enumerate([33, 40, 48, 35, 44, 38]):
        srv.submit(Request(rid=rid, prompt=rng.randint(1, arch.vocab, size=L).astype(np.int32),
                           max_new_tokens=8))
    done = srv.run_until_drained()
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  req {r.rid}: prompt={len(r.prompt)} toks "
              f"ttft={r.ttft * 1e3:.1f}ms tpot={r.tpot * 1e3:.1f}ms -> {r.output}")

    # -- the handoff in numbers: invocations, channel traffic, exact accounting
    st = srv.stats()
    print(f"prefill invocations: {st['prefill_invocations']} (1 per prompt; "
          f"token-at-a-time would need {sum(len(r.prompt) for r in done)})")
    print(f"decode invocations:  {st['decode_invocations']}")
    print(f"kv channel: {st['kv_bytes'] / 1e6:.2f} MB over {st['kv_transfers']} "
          f"transfers in {st['kv_seconds'] * 1e3:.1f} ms")
    print(f"decode-cell serving summary: {st['decode_serving']}")
    sup.destroy_cell("prefill")
    sup.destroy_cell("decode")
    print("done.")


if __name__ == "__main__":
    main()
