"""Disaggregated serving under the supervisor daemon: autoscale + self-heal.

The paper's "isolate first, then share on demand" applied to inference,
with the management loop CLOSED: a ClusterSpec names one prefill cell, a
decode cell with ``replicas=2`` (bounded ``[2, 3]``), a ``kv``
ChannelSpec per replica, a ``tpot_p99`` SLOTarget and a ``ckpt_dir``.
One ``Supervisor.apply`` materializes all of it; from then on a
:class:`SupervisorDaemon` tick — interleaved with traffic via
``run_until_drained(on_step=daemon.tick)`` — does everything the old
imperative demos sequenced by hand:

* **autoscale**: when the request queue backs up past the band derived
  from the declared SLO, the policy rewrites ``replicas`` and reconcile
  materializes a third decode cell, which ``DisaggServer.sync``
  live-attaches (KV channel + weight fan-out + fresh batcher);
* **self-heal**: killing a decode replica's column mid-traffic marks the
  cell failed; its in-flight requests requeue, reconcile re-carves the
  cell once the column is repaired, the declared ``ckpt_dir`` restores
  its params (no re-init, no fan-out), and sync re-attaches it — zero
  requests lost, zero manual primitive calls.

Run:  PYTHONPATH=src python examples/serve_disagg.py [--trace-out FILE]
(uses 8 virtual host devices so the cells sit on disjoint zones;
``--trace-out`` exports the whole run — request span trees + the
daemon's decision audit — as Chrome trace-event JSON, openable in
Perfetto / chrome://tracing: ``make trace-demo``)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import tempfile

import numpy as np
import jax

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.core import (
    CellSpec,
    ChannelSpec,
    ClusterSpec,
    DeviceGrid,
    SLOTarget,
    Supervisor,
    SupervisorDaemon,
)
from repro.serve.batcher import Request
from repro.serve.disagg import DisaggServer


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="export the run as Chrome trace-event JSON "
                         "(Perfetto-loadable), incl. the decision audit")
    args = ap.parse_args(argv)
    grid = DeviceGrid.from_flat(jax.devices(), pods=1, rows=2, cols=4)
    sup = Supervisor(grid)
    arch = smoke_config(get_arch("qwen3-4b"))
    ckpt_dir = tempfile.mkdtemp(prefix="decode-ckpt-")

    # -- desired state: prompts vs tokens; decode bounded [2,3] replicas,
    #    latency objective + checkpoint location declared, not scripted
    spec = ClusterSpec(
        cells=(CellSpec("prefill", arch, "serve", ncols=1),
               CellSpec("decode", arch, "serve", ncols=1, replicas=2,
                        min_replicas=2, max_replicas=3,
                        slo=SLOTarget(tpot_p99=0.25), ckpt_dir=ckpt_dir)),
        channels=(ChannelSpec("prefill", "decode", kind="kv"),),
    )
    plan = sup.apply(spec)
    print(f"applied spec -> plan [{plan.summary()}], epoch={sup.table.epoch}")
    decode_names = spec.cell("decode").instances()
    sup.cells[decode_names[0]].init_serve(rng=jax.random.PRNGKey(0))

    # -- share on demand: weight fan-out + per-replica KV handoff channels
    srv = DisaggServer(sup, "prefill", decode_names,
                       batch_slots=2, max_len=64, chunk=16)
    print(f"channels: {[(c.kind, c.src.name, '->', c.dst.name) for c in sup.channels]}")
    # checkpoint the params so recovery restores STATE, not just a zone
    ckpt.save(ckpt_dir, 0, sup.cells[decode_names[0]].serve_params)

    # -- the closed loop: health + reconcile + SLO autoscale + replica sync
    daemon = SupervisorDaemon(sup)
    daemon.attach_server(srv)
    daemon.add_slo_policy("decode", autoscale_replicas=True,
                          queue_depth=lambda: len(srv.pending),
                          queue_high=4, window=16, cooldown=0.0)

    rng = np.random.RandomState(0)

    def burst(n, rid0):
        for rid in range(rid0, rid0 + n):
            L = int(rng.randint(28, 52))
            srv.submit(Request(
                rid=rid, prompt=rng.randint(1, arch.vocab, size=L).astype(np.int32),
                max_new_tokens=8))
        return rid0 + n

    # -- burst 1: the backlog crosses the SLO-derived band -> autoscale
    next_rid = burst(12, 0)
    srv.run_until_drained(on_step=daemon.tick)
    print(f"burst 1 drained: {len(srv.done)}/12 served, "
          f"replicas={len(srv.replicas)}, "
          f"actions={[a['kind'] for p in daemon.policies for a in p.actions]}")

    # -- burst 2: kill a decode replica's column mid-traffic
    next_rid = burst(6, next_rid)
    for _ in range(2):
        srv.step()
        daemon.tick()
    victim = srv.replicas[1].cell
    pod, col = victim.zone.pods[0], victim.zone.c0
    affected = sup.fail_column(pod, col)
    print(f"killed column ({pod},{col}) -> affected={affected}")
    for _ in range(3):                     # daemon reaps + requeues; recover
        srv.step()                         # stays blocked while the column
        daemon.tick()                      # is quarantined
    sup.restore_column(pod, col)           # the repair arrives
    srv.run_until_drained(on_step=daemon.tick)
    done = {r.rid for r in srv.done}
    restored = [e for e in sup.events if e["op"] == "restore_ckpt"]
    print(f"burst 2 drained: all {next_rid} requests done={done == set(range(next_rid))}, "
          f"requeued={srv.requeued}, replicas={len(srv.replicas)}")
    print(f"recovery restored from checkpoint: "
          f"{[(e['cell'], 'step ' + str(e['step'])) for e in restored]}")

    # -- the handoff in numbers: invocations, routing, channel traffic
    st = srv.stats()
    print(f"prefill invocations: {st['prefill_invocations']} (same-bucket "
          f"prompts batched)")
    print(f"decode invocations:  {st['decode_invocations']} across "
          f"{st['replicas']} replicas (requests per replica: "
          f"{st['per_replica_requests']})")
    print(f"kv channels: {st['kv_bytes'] / 1e6:.2f} MB over {st['kv_transfers']} "
          f"transfers in {st['kv_seconds'] * 1e3:.1f} ms")
    if st["paged_kv"]:
        print(f"kv pool: prefix hits {st['prefix_hit_tokens']} tok / misses "
              f"{st['prefix_miss_tokens']} tok, saved "
              f"{st['kv_bytes_saved'] / 1e6:.2f} MB, pages in use "
              f"{st['pages_in_use']} (evicted {st['pages_evicted']}, "
              f"occupancy {st['pool_occupancy']:.2f})")
    print(f"serving summary: {st['decode_serving']}")
    print(f"daemon: {daemon.ticks} ticks, "
          f"{sum(1 for r in daemon.history if r['plan'] != 'noop')} non-noop plans")
    print(f"tail telemetry: { {k: round(v.get('p99', 0), 4) for k, v in st['telemetry'].items() if 'p99' in v} }")

    # -- the decision audit: WHY the daemon scaled / recovered / synced
    print("decision audit (scale/recover/sync):")
    for hit in daemon.audit.query():
        if any(k in hit["kind"] for k in
               ("grow", "shrink", "scale", "recover", "sync",
                "mark_failed", "destroy", "drain")):
            print(f"  tick {hit['tick']:3d}  {hit['kind']:<16} "
                  f"{hit.get('cell') or '-':<10} {hit.get('reason', '')}")

    # -- flight-recorder export: one span tree per request, audit folded
    #    in as instant events (must run BEFORE teardown drops the cells)
    if args.trace_out:
        trace = srv.trace_export(args.trace_out, daemon=daemon)
        print(f"trace: {len(trace['traceEvents'])} events "
              f"-> {args.trace_out} (open in Perfetto / chrome://tracing)")

    # -- empty spec tears everything down
    sup.apply(ClusterSpec())
    print(f"cells after teardown: {list(sup.cells)}")
    print("done.")


if __name__ == "__main__":
    main()
