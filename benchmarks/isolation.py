"""Paper Figs 7 / 8 / 9 — performance isolation under co-location.

Fig 7: pairwise interference matrix (MODELED from calibrated system
models).  Fig 8: tail latency vs load + SLO throughput.  Fig 9: Search
co-located with batch workloads, p99 degradation per system.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.simlib import SYSTEMS, p99, simulate_serving

WORKLOADS = ("cpu", "cache", "io", "net")  # SPEC/cachebench/IOzone/netperf analogue
# background pressure each workload class exerts (cachebench writes are the
# paper's worst case — Fig 7's hot column)
PRESSURE = {"cpu": 0.25, "cache": 1.0, "io": 0.6, "net": 0.45}


def fig7_matrix(rows: List[dict]):
    for sys_name in ("rainforest", "lxc", "xen", "linux-3.17.4"):
        sm = SYSTEMS[sys_name]
        for fg in WORKLOADS:
            solo = simulate_serving(sm, rate=250, n_servers=48, colo_load=0.0, seed=7)
            for bg in WORKLOADS:
                colo = simulate_serving(
                    sm, rate=250, n_servers=48, colo_load=PRESSURE[bg], seed=11)
                deg = (np.mean(colo) / np.mean(solo) - 1) * 100
                rows.append({
                    "name": f"fig7_degradation_pct/{sys_name}/{fg}_vs_{bg}",
                    "us_per_call": float(np.mean(colo) * 1e6),
                    "derived": f"deg={deg:.1f}% MODELED",
                })


def fig8_slo(rows: List[dict]):
    """Tail latency vs request rate; throughput at the 200 ms SLO."""
    slo = 0.200
    for sys_name in ("rainforest", "lxc", "xen", "linux-2.6.35M"):
        sm = SYSTEMS[sys_name]
        max_ok = 0
        for rate in range(250, 651, 50):
            # two Search instances share the box: pressure grows with load.
            # bare Linux schedules freely across all 12 cores (paper: better
            # average, worse tail past 450 req/s)
            ns = 12 * 8 if "linux" in sys_name else 6 * 8
            lat = simulate_serving(sm, rate=float(rate), n_servers=ns,
                                   base_service=0.05, colo_load=rate / 650.0, seed=rate)
            tail = p99(lat)
            if tail <= slo:
                max_ok = rate
            rows.append({
                "name": f"fig8_p99ms/{sys_name}/rate{rate}",
                "us_per_call": tail * 1e6,
                "derived": f"{'OK' if tail <= slo else 'VIOLATE'} MODELED",
            })
        rows.append({
            "name": f"fig8_slo_throughput/{sys_name}",
            "us_per_call": float(max_ok),
            "derived": "req/s at p99<=200ms MODELED",
        })


def fig9_colo(rows: List[dict]):
    for sys_name in ("rainforest", "lxc", "xen", "linux-3.17.4"):
        sm = SYSTEMS[sys_name]
        solo = p99(simulate_serving(sm, rate=300, n_servers=48, colo_load=0.0, seed=3))
        worst = 0.0
        for bg in WORKLOADS:
            colo = p99(simulate_serving(sm, rate=300, n_servers=48, colo_load=PRESSURE[bg], seed=5))
            worst = max(worst, colo / solo - 1)
        rows.append({
            "name": f"fig9_worst_tail_degradation/{sys_name}",
            "us_per_call": worst * 100,
            "derived": f"paper: rf<=8% lxc<=46% MODELED",
        })


def run(rows: List[dict]):
    fig7_matrix(rows)
    fig8_slo(rows)
    fig9_colo(rows)
