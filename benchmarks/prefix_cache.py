"""Prefix-cache benchmark: shared-system-prompt serving, cold vs warm.

The workload every production serving stack optimizes for: N requests
that share one long system prompt and differ only in a short user
suffix.  Cold (empty prefix trees) the full prompt is prefilled and its
whole KV crosses the prefill -> decode channel per request; warm (trees
already holding the system prompt) the shared pages are mapped read-only
from the decode pool, only the suffix is prefilled (``prefill_extend``)
and only the suffix pages cross the channel.

The same workload exercises BOTH cache-plane payloads, selected by the
pool's capability (``KVPool.capability``): attention families share
paged KV; ssm/hybrid families (``--arch mamba2-2.7b`` /
``zamba2-2.7b``) share interned recurrent-state snapshots — warm
requests restore the deepest chunk-boundary checkpoint and
prefill-extend only the suffix, and the prefill -> decode channel
carries one dense row instead of row + snapshot chain.

Reported per phase: TTFT p50/p99, channel bytes, pool occupancy, prefix
hit/miss tokens, kv_bytes_saved (paged) / snapshot_bytes_saved
(snapshot).  The headline assertion (``--smoke`` gate, CI): warm-prefix
TTFT p50 < 0.6x cold (paged) or < 0.7x cold (snapshot — the restore
still replays KV loads for hybrid attention chunks) with
``kv_bytes_saved > 0`` / ``snapshot_bytes_saved > 0`` and warm channel
bytes below cold.

Phases (one server, programs compiled before anything is timed):

  0. compile  — a throwaway cold+warm round on prefix A (pays every jit)
  1. cold     — fresh prefix B, trees miss end-to-end: the baseline
  2. warm     — prefix B again with new suffixes: the prefix-cache win
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.core import DeviceGrid, Supervisor
from repro.serve.batcher import Request


def _requests(cfg, sysp, n, suffix_len, rid0, seed):
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n):
        tail = rng.randint(1, cfg.vocab, size=suffix_len).astype(np.int32)
        out.append(Request(rid=rid0 + i, prompt=np.concatenate([sysp, tail]),
                           max_new_tokens=4))
    return out


def _phase(srv, reqs):
    """Run one request wave; counters are reported as PHASE DELTAS (the
    server's ledgers are cumulative — a compile-round hit must not be
    able to satisfy the warm phase's gate)."""
    before = srv.stats()
    t0 = time.monotonic()
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained(max_steps=20_000)
    wall = time.monotonic() - t0
    rids = {r.rid for r in reqs}
    served = [r for r in srv.done if r.rid in rids]
    ttfts = sorted(r.ttft for r in served)
    st = srv.stats()
    return {
        "wall_s": wall,
        "ttft_p50": float(np.percentile(ttfts, 50)),
        "ttft_p99": float(np.percentile(ttfts, 99)),
        "kv_bytes": st["kv_bytes"] - before["kv_bytes"],
        "prefix_hit_tokens": (st["prefix_hit_tokens"]
                              - before["prefix_hit_tokens"]),
        "kv_bytes_saved": st["kv_bytes_saved"] - before["kv_bytes_saved"],
        "snapshot_hit_tokens": (st["snapshot_hit_tokens"]
                                - before["snapshot_hit_tokens"]),
        "snapshot_bytes_saved": (st["snapshot_bytes_saved"]
                                 - before["snapshot_bytes_saved"]),
        "snapshots_interned": (st["snapshots_interned"]
                               - before["snapshots_interned"]),
        "pages_in_use": st["pages_in_use"],
        "pool_occupancy": st["pool_occupancy"],
    }


def run(arch: str = "qwen3-4b", *, max_len: int = 128, chunk: int = 16,
        page_size: int = 16, system_len: int = 96, suffix_len: int = 12,
        requests: int = 8, batch_slots: int = 4, smoke: bool = False):
    cfg = smoke_config(get_arch(arch))
    if cfg.sliding_window is not None and cfg.sliding_window < max_len:
        cfg = cfg.replace(sliding_window=max_len)
    from repro.serve.disagg import DisaggServer

    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=3,
                                allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("prefill", cfg, "serve", ncols=1)
    dec = sup.create_cell("dec0", cfg, "serve", ncols=1)
    dec.init_serve(rng=jax.random.PRNGKey(0))
    sup.create_cell("dec1", cfg, "serve", ncols=1)
    srv = DisaggServer(sup, "prefill", ["dec0", "dec1"],
                       batch_slots=batch_slots, max_len=max_len, chunk=chunk,
                       page_size=page_size)
    assert srv.worker is not None and srv.worker.pool is not None, \
        "prefix-cache benchmark needs a shareable cache plane (paged or snapshot)"

    rng = np.random.RandomState(0)
    prefix_a = rng.randint(1, cfg.vocab, size=system_len).astype(np.int32)
    prefix_b = rng.randint(1, cfg.vocab, size=system_len).astype(np.int32)

    # phase 0: compile every program shape (cold prefill bucket, warm
    # extend bucket, paged decode) so phases 1/2 time steady-state work
    _phase(srv, _requests(cfg, prefix_a, requests, suffix_len, 1000, seed=1))
    _phase(srv, _requests(cfg, prefix_a, requests, suffix_len, 2000, seed=2))

    cold = _phase(srv, _requests(cfg, prefix_b, requests, suffix_len, 3000,
                                 seed=3))
    warm = _phase(srv, _requests(cfg, prefix_b, requests, suffix_len, 4000,
                                 seed=4))

    ratio = warm["ttft_p50"] / max(cold["ttft_p50"], 1e-9)
    kind = srv.worker.pool.payload_kind
    out = {
        "arch": cfg.name, "payload_kind": kind,
        "max_len": max_len, "page_size": page_size,
        "system_len": system_len, "suffix_len": suffix_len,
        "requests_per_phase": requests,
        "cold": cold, "warm": warm,
        "warm_over_cold_ttft_p50": ratio,
        "warm_over_cold_kv_bytes": warm["kv_bytes"] / max(cold["kv_bytes"], 1),
    }
    print(f"== prefix_cache [{cfg.name}] system={system_len} "
          f"suffix={suffix_len} x{requests} ==")
    for phase in ("cold", "warm"):
        p = out[phase]
        print(f"  {phase:5s} ttft p50 {p['ttft_p50'] * 1e3:8.1f} ms   "
              f"p99 {p['ttft_p99'] * 1e3:8.1f} ms   "
              f"channel {p['kv_bytes'] / 1e6:7.2f} MB   "
              f"hits {p['prefix_hit_tokens']:6d} tok   "
              f"occupancy {p['pool_occupancy']:.2f}")
    print(f"  warm/cold ttft p50 = {ratio:.3f}   "
          f"channel bytes = {out['warm_over_cold_kv_bytes']:.3f}   "
          f"kv_bytes_saved = {warm['kv_bytes_saved'] / 1e6:.2f} MB")
    if kind == "snapshot":
        print(f"  snapshots interned = {warm['snapshots_interned']}   "
              f"snapshot hits = {warm['snapshot_hit_tokens']} tok   "
              f"snapshot_bytes_saved = "
              f"{warm['snapshot_bytes_saved'] / 1e6:.2f} MB")

    if smoke:
        assert warm["prefix_hit_tokens"] > 0, "warm phase made no hits"
        if kind == "snapshot":
            assert warm["snapshot_hit_tokens"] > 0, "no snapshot hits"
            assert warm["snapshot_bytes_saved"] > 0, \
                "no snapshot bytes saved"
        else:
            assert warm["kv_bytes_saved"] > 0, "no KV bytes saved"
        assert warm["kv_bytes"] < cold["kv_bytes"], \
            "warm phase should move fewer bytes over the channel"
        gate = 0.7 if kind == "snapshot" else 0.6
        assert ratio < gate, (
            f"warm TTFT p50 must beat {gate}x cold, got {ratio:.3f}")
        print("SMOKE OK")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + the CI acceptance gate")
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--system-len", type=int, default=None)
    ap.add_argument("--suffix-len", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args()
    kw = {}
    if args.smoke:
        kw = dict(max_len=128, system_len=96, suffix_len=12, requests=8,
                  smoke=True)
    for k in ("max_len", "system_len", "suffix_len", "requests"):
        v = getattr(args, k)
        if v is not None:
            kw[k] = v
    run(args.arch, **kw)


if __name__ == "__main__":
    main()
