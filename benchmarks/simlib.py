"""Discrete-event tail-latency simulator, calibrated to the paper.

This container has one CPU device, so multi-tenant *wall-clock* contention
cannot be measured here; the latency benchmarks therefore run a queueing
simulation whose system-level parameters are calibrated to the paper's
reported numbers, while every RainForest-JAX *mechanism* cost (resize,
channel bandwidth, step time) is measured for real elsewhere.  Each
benchmark prints MEASURED vs MODELED per row.

Model: a serving cell is an c-server queue (c = columns) with lognormal
service times.  "Share-first" systems add an interference term that grows
with co-located load and with core count (lock contention ~ collisions) —
the paper's Figs 2b/7/8/9/12 shapes.  Calibration anchors:

  Fig 8   SLO(200ms) throughput: linux 400, lxc 350, xen 350, rf 500 req/s
  Fig 9   colo p99 degradation:  rf <= 8%, lxc up to 46%, xen ~25%
  Fig 12  memcached p99 at 40 cores vs rf: linux-2.6.32 7.8x, 2.6.35M 4.2x,
          3.17.4 2.0x, lxc 1.3x, xen 1.4x
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


# ---------------------------------------------------------------------------
# bookkeeping supervisor: the real Reconciler over instant primitives
# ---------------------------------------------------------------------------
class SimZone:
    def __init__(self, ncols: int):
        self.ncols = ncols


class SimCell:
    """Duck-typed cell: zone/role/status/accounting, no devices."""

    def __init__(self, name: str, ncols: int, role: str = "serve", arch=None):
        from repro.core.accounting import CellAccounting
        self.name = name
        self.zone = SimZone(ncols)
        self.role = role
        self.arch = arch
        self.status = "running"
        self.accounting = CellAccounting(name)


class SimSupervisor:
    """Duck-typed supervisor running the REAL Reconciler over instant
    bookkeeping primitives — shared by the Table-5 trace benchmark and the
    planner/policy unit tests, so the duck-typed supervisor contract lives
    in exactly one place.  Primitive calls append to ``log``; transfers
    also bump ``transfers`` (the executor *cost* is modeled by callers).
    """

    def __init__(self, *cells: SimCell):
        self.cells = {c.name: c for c in cells}
        self.desired = None
        self.log = []
        self.transfers = 0

    # declarative surface -------------------------------------------------
    def apply(self, spec):
        self.desired = spec
        return self.reconcile()

    def reconcile(self):
        from repro.core.reconciler import Reconciler
        return Reconciler(self).reconcile(self.desired)

    # primitive executor layer --------------------------------------------
    def create_cell(self, name, arch, role, *, ncols, pods=(0,),
                    opt_cfg=None, parent=None):
        self.log.append(("create", name, ncols))
        self.cells[name] = SimCell(name, ncols, role, arch)
        return self.cells[name]

    def destroy_cell(self, name):
        self.log.append(("destroy", name))
        del self.cells[name]

    def resize_cell(self, name, ncols):
        self.log.append(("resize", name, ncols))
        self.cells[name].zone.ncols = ncols
        return {"ncols": ncols}

    def transfer_columns(self, src, dst, ncols=1):
        self.log.append(("transfer", src, dst, ncols))
        self.cells[src].zone.ncols -= ncols
        self.cells[dst].zone.ncols += ncols
        self.transfers += 1
        return {"ncols": ncols}

    def recover_cell(self, name, *, ncols=None, ckpt_dir=None):
        self.log.append(("recover", name, ncols, ckpt_dir))
        cell = self.cells[name]
        cell.status = "running"
        if ncols is not None:
            cell.zone.ncols = ncols
        return cell


@dataclasses.dataclass
class SystemModel:
    """Interference / overhead parameters of one OS architecture."""

    name: str
    base_overhead: float = 1.0      # service-time multiplier vs bare metal
    interference: float = 0.0       # colo service inflation fraction
    jitter_sigma: float = 0.12      # lognormal sigma when isolated
    colo_sigma: float = 0.0         # extra sigma under co-location
    contention_per_core: float = 0.0  # shared-kernel tail growth per core
    resize_seconds: float = 0.0     # cost to move one column/core


# calibrated to the paper's measurements (see module docstring)
SYSTEMS: Dict[str, SystemModel] = {
    "rainforest": SystemModel("rainforest", 1.00, 0.015, 0.12, 0.008, 0.0002, 0.066),
    "linux": SystemModel("linux", 0.97, 0.60, 0.16, 0.50, 0.0035, 0.0),
    "linux-2.6.35M": SystemModel("linux-2.6.35M", 0.98, 0.50, 0.15, 0.45, 0.0018, 0.0),
    "linux-3.17.4": SystemModel("linux-3.17.4", 0.96, 0.55, 0.14, 0.48, 0.0008, 0.0),
    "lxc": SystemModel("lxc", 1.02, 0.12, 0.14, 0.11, 0.00025, 0.002),
    "xen": SystemModel("xen", 1.04, 0.14, 0.14, 0.12, 0.0003, 0.126),
}


def simulate_serving(
    sys_model: SystemModel,
    *,
    rate: float,                  # requests / s
    duration: float = 60.0,
    n_servers: int = 6,
    base_service: float = 0.05,   # seconds at 1x (Search-like: ~50ms)
    colo_load: float = 0.0,       # 0..1 background pressure (PARSEC cell)
    n_cores_total: int = 12,
    seed: int = 0,
) -> np.ndarray:
    """Returns the array of request latencies (seconds)."""
    rng = np.random.default_rng(seed)
    n_req = max(int(rate * duration), 1)
    arrivals = np.sort(rng.uniform(0, duration, n_req))

    mult = sys_model.base_overhead * (1 + sys_model.interference * colo_load)
    sigma = sys_model.jitter_sigma + sys_model.colo_sigma * colo_load
    # shared-kernel contention grows with total cores (Fig 2b / Fig 12)
    # lock contention grows superlinearly with sharing scope
    tail_boost = sys_model.contention_per_core * n_cores_total**2 / 12.0
    service = base_service * mult * rng.lognormal(0.0, sigma, n_req)
    # contention events (lock waits) hit a fraction of requests; both the
    # frequency and the wait scale with the system's sharing degree
    share = sys_model.interference * colo_load
    hit = rng.uniform(size=n_req) < (0.02 + 0.10 * share + tail_boost)
    service = np.where(
        hit,
        service * (1 + rng.exponential(1.2 + 30 * tail_boost + 2.0 * share, n_req)),
        service,
    )

    # c-server FCFS queue
    free = np.zeros(n_servers)
    lat = np.empty(n_req)
    for i, t in enumerate(arrivals):
        j = int(np.argmin(free))
        start = max(t, free[j])
        free[j] = start + service[i]
        lat[i] = free[j] - t
    return lat


def p99(lat: np.ndarray) -> float:
    return float(np.percentile(lat, 99))
