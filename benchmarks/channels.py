"""Paper Fig 13 / RFloop — on-demand channel bandwidth (MEASURED).

Measures the three inter-cell data paths on this host:
  * ``send``      — ArrayChannel device_put transfer (RFcom/RFloop analogue)
  * ``host_loop`` — staged through host numpy (the "physical NIC" analogue)
  * ``map``       — zero-copy publish (shared-memory mapping analogue)
plus a Spark-shuffle model: job speedup when the shuffle phase uses each
path (paper: RFloop up to 1.71x vs Linux for Join/Aggregation).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np


def run(rows: List[dict]):
    import jax
    import jax.numpy as jnp
    from repro.configs.base import smoke_config
    from repro.configs.registry import get_arch
    from repro.core import DeviceGrid, Supervisor

    grid = DeviceGrid(np.array(jax.devices()[:1], dtype=object).reshape(1, 1, 1))
    sup = Supervisor(grid)
    cfg = smoke_config(get_arch("qwen3-4b"))
    a = sup.create_cell("a", cfg, "serve", ncols=1)
    sup.table = sup.table.release("a")  # reuse the single column for cell b
    b_cell = sup.create_cell("b", cfg, "serve", ncols=1)
    ch = sup.open_channel("a", "b")

    nbytes = 64 * 1024 * 1024
    x = jnp.arange(nbytes // 4, dtype=jnp.float32)
    x.block_until_ready()

    # warm + measure device_put path
    st = ch.send(x)
    _ = ch.recv()
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        ch.send(x)
        ch.recv()
    dt_send = (time.perf_counter() - t0) / reps

    # host-staged path
    t0 = time.perf_counter()
    for _ in range(reps):
        h = np.asarray(x)
        y = jnp.asarray(h)
        y.block_until_ready()
    dt_host = (time.perf_counter() - t0) / reps

    # zero-copy map
    t0 = time.perf_counter()
    for _ in range(reps):
        ch.map(x)
        ch.recv()
    dt_map = (time.perf_counter() - t0) / reps

    for name, dt in (("send", dt_send), ("host_loop", dt_host), ("map", dt_map)):
        bw = nbytes / max(dt, 1e-9) / 1e9
        rows.append({
            "name": f"fig13_channel/{name}",
            "us_per_call": dt * 1e6,
            "derived": f"bw={bw:.2f}GB/s MEASURED",
        })

    # Spark-shuffle model (cluster-scale constants; the measured numbers
    # above are single-host): a Join-like job with 60s compute + a shuffle
    # that takes 40s over a 25GbE NIC (3.13 GB/s).  The channel paths move
    # the shuffle to ICI (50 GB/s/link) or zero-copy shared HBM mapping.
    t_compute, t_shuffle_nic, bw_nic = 60.0, 40.0, 3.13e9
    path_bw = {"host_loop": bw_nic, "send": 50e9, "map": 819e9}
    base = t_compute + t_shuffle_nic
    for name, bw in path_bw.items():
        t_job = t_compute + t_shuffle_nic * (bw_nic / bw)
        rows.append({
            "name": f"fig13_spark_join/{name}",
            "us_per_call": t_job * 1e6,
            "derived": f"speedup={base/t_job:.2f}x (paper RFloop 1.71x) MODELED",
        })
