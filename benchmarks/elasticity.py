"""Paper Table 4 — elasticity overheads (MEASURED).

Creates/destroys/resizes real cells on 8 host CPU devices in a subprocess
(this process must keep seeing a single device) and reports wall times —
the analogue of the paper's create/destroy/online/offline measurements.
Every lifecycle change goes through the declarative path
(``Supervisor.apply`` of a rescaled ClusterSpec -> reconcile -> primitive),
so the timings include the spec-diff overhead applications actually pay.
Paper reference points (seconds): LXC create 2.1 / cpu 0.002; Xen create
14.2 / cpu 0.126; RainForest create 6.1 / cpu-online 0.066 / offline 0.054.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time, sys
sys.path.insert(0, "src")
import jax
from repro.configs.base import smoke_config, ShapeConfig
from repro.configs.registry import get_arch
from repro.core import CellSpec, ClusterSpec, DeviceGrid, Supervisor
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.train.optimizer import OptConfig

grid = DeviceGrid.from_flat(jax.devices(), pods=1, rows=2, cols=4)
sup = Supervisor(grid)
cfg = smoke_config(get_arch("qwen3-4b"))
pipe = SyntheticPipeline(DataConfig(kind="uniform", vocab=256), cfg,
                         ShapeConfig("t", "train", 32, 8))
out = {}
spec = ClusterSpec(cells=(
    CellSpec("c", cfg, "train", ncols=2, min_ncols=1, max_ncols=3,
             opt_cfg=OptConfig()),
))

t0 = time.monotonic()
sup.apply(spec)                                    # create via reconcile
cell = sup.cells["c"]
cell.train_steps(lambda s: pipe.get_batch(s), 1)   # includes first compile
out["create_and_first_step_s"] = time.monotonic() - t0

t0 = time.monotonic()
cell.train_steps(lambda s: pipe.get_batch(s), 1)
out["steady_step_s"] = time.monotonic() - t0

t0 = time.monotonic()
plan = sup.apply(spec.scale("c", 3))               # grow: "cpu online"
out["grow_1col_s"] = time.monotonic() - t0
out["grow_reshard_bytes"] = plan.by_verb("grow")[0].result["bytes"]

t0 = time.monotonic()
cell.train_steps(lambda s: pipe.get_batch(s), 1)   # recompile on new mesh
out["post_resize_step_s"] = time.monotonic() - t0

t0 = time.monotonic()
sup.apply(spec.scale("c", 2))                      # shrink: "cpu offline"
out["shrink_1col_s"] = time.monotonic() - t0

t0 = time.monotonic()
sup.apply(ClusterSpec())                           # empty spec: destroy
out["destroy_s"] = time.monotonic() - t0
assert not sup.cells and sup.reconcile().empty

print(json.dumps(out))
"""


def run(rows: List[dict]):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=560,
    )
    if proc.returncode != 0:
        rows.append({"name": "table4_elasticity/ERROR",
                     "us_per_call": -1,
                     "derived": proc.stderr.strip()[-160:]})
        return
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    paper = {
        "create_and_first_step_s": "paper rf=6.1s lxc=2.1s xen=14.2s",
        "grow_1col_s": "paper rf cpu-online=0.066s xen=0.126s",
        "shrink_1col_s": "paper rf cpu-offline=0.054s",
        "destroy_s": "paper rf=0s (async)",
    }
    for k, v in out.items():
        if k.endswith("_bytes"):
            continue
        rows.append({
            "name": f"table4_elasticity/{k}",
            "us_per_call": v * 1e6,
            "derived": f"{paper.get(k, '')} MEASURED".strip(),
        })
