"""Paper Figs 2b / 6 / 12 — tail latency vs scale, shared vs isolated.

MODELED rows use the calibrated simulator (see simlib docstring).
MEASURED rows time real decode steps on this host: solo vs with a
concurrent jax workload dispatching on the same device (the CPU-box
analogue of shared-substrate interference).
"""
from __future__ import annotations

import threading
import time
from typing import List

import numpy as np

from benchmarks.simlib import SYSTEMS, p99, simulate_serving


def scaling_table(rows: List[dict]):
    """Fig 12 analogue: p99 vs cores for all systems (MODELED)."""
    for cores in (10, 20, 30, 40):
        base = None
        for name in ("rainforest", "linux", "linux-2.6.35M", "linux-3.17.4", "lxc", "xen"):
            lat = simulate_serving(
                SYSTEMS[name], rate=120.0 * cores / 10, duration=30.0,
                n_servers=cores // 2, base_service=0.0002,
                n_cores_total=cores, seed=cores,
            )
            v = p99(lat) * 1e6
            if name == "rainforest":
                base = v
            rows.append({
                "name": f"fig12_memcached_p99us/{name}/cores{cores}",
                "us_per_call": v,
                "derived": f"vs_rf={v / base:.2f}x MODELED",
            })


def measured_interference(rows: List[dict]):
    """Real on-host measurement: decode-step p99 solo vs co-dispatched."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import smoke_config
    from repro.configs.registry import get_arch
    from repro.models.model import build_model
    from repro.sharding.rules import single_device_ctx

    cfg = smoke_config(get_arch("qwen3-4b"))
    model = build_model(cfg, single_device_ctx())
    params = model.init(jax.random.PRNGKey(0))
    B, S = 4, 64
    cache = model.init_cache(B, S)
    batch = {"tokens": jnp.zeros((B, 1), jnp.int32),
             "pos": jnp.zeros((B,), jnp.int32)}
    step = jax.jit(model.decode)
    step(params, cache, batch)[0].block_until_ready()  # warm

    def measure(n=60):
        lats = []
        for _ in range(n):
            t0 = time.perf_counter()
            step(params, cache, batch)[0].block_until_ready()
            lats.append(time.perf_counter() - t0)
        return np.array(lats)

    solo = measure()

    stop = threading.Event()
    w = jnp.ones((512, 512), jnp.float32)
    noise_fn = jax.jit(lambda a: a @ a)

    def noise():
        a = w
        while not stop.is_set():
            a = noise_fn(a)
            a.block_until_ready()

    t = threading.Thread(target=noise)
    t.start()
    try:
        shared = measure()
    finally:
        stop.set()
        t.join()

    rows.append({
        "name": "measured_decode_p99us/solo",
        "us_per_call": float(np.percentile(solo, 99) * 1e6),
        "derived": (
            f"p50={np.percentile(solo, 50)*1e6:.0f}us "
            f"p999={np.percentile(solo, 99.9)*1e6:.0f}us MEASURED"
        ),
    })
    rows.append({
        "name": "measured_decode_p99us/shared_device",
        "us_per_call": float(np.percentile(shared, 99) * 1e6),
        "derived": (
            f"p999={np.percentile(shared, 99.9)*1e6:.0f}us "
            f"degradation={np.percentile(shared, 99)/np.percentile(solo, 99):.2f}x MEASURED"
        ),
    })


def serving_tails(rows: List[dict]):
    """End-to-end request tails (p50/p99/p99.9) through the batcher, as
    :func:`repro.core.accounting.summarize_requests` now reports them —
    the extreme-tail column the paper's isolation argument is about."""
    import jax
    from repro.configs.base import smoke_config
    from repro.configs.registry import get_arch
    from repro.core.accounting import CellAccounting
    from repro.models.model import build_model
    from repro.serve.batcher import ContinuousBatcher, Request
    from repro.sharding.rules import single_device_ctx

    cfg = smoke_config(get_arch("qwen3-4b"))
    model = build_model(cfg, single_device_ctx())
    params = model.init(jax.random.PRNGKey(0))
    acc = CellAccounting("tails")
    bat = ContinuousBatcher(model, params, batch_slots=4, max_len=64,
                            prefill_chunk=16, accounting=acc)
    rng = np.random.RandomState(0)
    for rid in range(24):
        L = int(rng.randint(8, 48))
        bat.submit(Request(rid=rid,
                           prompt=rng.randint(1, cfg.vocab, size=L).astype(np.int32),
                           max_new_tokens=4))
    bat.run_until_drained()
    s = acc.serving_summary()
    for metric in ("ttft", "tpot"):
        rows.append({
            "name": f"measured_serving_{metric}_p999us",
            "us_per_call": s[f"{metric}_p999"] * 1e6,
            "derived": (
                f"p50={s[f'{metric}_p50']*1e3:.1f}ms "
                f"p99={s[f'{metric}_p99']*1e3:.1f}ms "
                f"n={s['requests']} MEASURED"
            ),
        })


def run(rows: List[dict]):
    scaling_table(rows)
    measured_interference(rows)
    serving_tails(rows)
