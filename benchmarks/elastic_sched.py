"""Paper Figs 10/11 + Table 5 — (lt,ut) elastic scheduling under a trace.

Replays a fluctuating request-rate trace against a serving cell co-located
with a batch cell (12 "columns" total), driven by the DECLARATIVE control
plane: desired state is a ClusterSpec (server bounded [3,10] cols, batch
[2,10]) whose server cell declares an ``SLOTarget(ttft_p99=0.200)`` — the
policy band is DERIVED from that target (``ut`` = the SLO itself,
``lt = hysteresis * ut``), not hand-picked.  Each tick the modeled p99 is
recorded into the server cell's real ``CellAccounting`` and a
:class:`SupervisorDaemon` tick runs the whole management cycle: health,
reconcile, and the :class:`ReconcilePolicy` that pulls the samples,
rescales the spec and ``apply``s — the real :class:`Reconciler` plans the
column ``transfer``s against a bookkeeping-only supervisor (instant
primitives; the resize *cost* is charged per the calibrated SystemModel).
Outputs the Table-5 analogue: batch progress, p99, throughput, #transfers.
MODELED (latencies) + the daemon/policy/spec/reconciler code paths
exercised for real — zero direct ``transfer_columns`` calls in this file.

Run:  PYTHONPATH=src python benchmarks/elastic_sched.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
from typing import List

import numpy as np

from benchmarks.simlib import SYSTEMS, SimCell, SimSupervisor, p99, simulate_serving
from repro.core.daemon import SupervisorDaemon
from repro.core.spec import CellSpec, ClusterSpec, SLOTarget


def trace_rate(t: float) -> float:
    """Fluctuating load: base 200 req/s with bursts up to ~520 (paper trace)."""
    burst = 110 * (1 + np.sin(t / 90.0)) * (np.sin(t / 13.0) > 0.4)
    return 200 + 60 * np.sin(t / 37.0) + burst


def run_system(sys_name: str, duration=2250.0, dt=10.0, seed=0):
    sm = SYSTEMS[sys_name]
    sup = SimSupervisor(SimCell("server", 6, "serve"),
                        SimCell("batch", 6, "train"))
    # desired state: the policy may move the server within [3,10] columns
    # (floor of 3 prevents shrink-into-overload oscillation), the batch
    # donor keeps at least 2.  The server declares its latency objective;
    # the scheduling band follows from it.
    slo = SLOTarget(ttft_p99=0.200)
    spec = ClusterSpec(cells=(
        CellSpec("server", None, "serve", ncols=6, min_ncols=3, max_ncols=10,
                 slo=slo),
        CellSpec("batch", None, "train", ncols=6, min_ncols=2, max_ncols=10),
    ))
    plan = sup.apply(spec)
    assert plan.empty                  # observed already matches desired
    # daemon-driven loop: the policy consumes one p99 observation per tick
    # via the server cell's accounting; band = (0.8 * SLO, SLO), median
    # over the last 6 ticks (1 min) decides moves
    daemon = SupervisorDaemon(sup)
    sched = daemon.add_slo_policy(
        "server", "batch", metric="ttft", hysteresis=0.8,
        window=6, percentile=50.0, cooldown=40.0,
    )
    assert (sched.policy.lt, sched.policy.ut) == (0.8 * slo.ttft_p99,
                                                  slo.ttft_p99)
    batch_work = 0.0
    tails, t = [], 0.0
    rid = 0
    resize_downtime = 0.0
    can_resize = sm.resize_seconds > 0 or sys_name in ("lxc", "linux")
    while t < duration:
        rate = trace_rate(t)
        ncols = sup.cells["server"].zone.ncols
        colo = min(sup.cells["batch"].zone.ncols / 12.0, 1.0)
        # 8 service threads per column (real servers multiplex cores)
        lat = simulate_serving(
            sm, rate=rate, duration=dt, n_servers=ncols * 8,
            base_service=0.05, colo_load=colo if sys_name != "rainforest" else 0.25 * colo,
            seed=int(t) ^ seed,
        )
        tail = p99(lat)
        tails.append(tail)
        # live accounting feed: the tick's tail lands in the server cell's
        # CellAccounting; the daemon's policy stage pulls it from there
        sup.cells["server"].accounting.record_request(rid, ttft=tail)
        rid += 1
        if sys_name != "linux" and can_resize:     # linux: no partition control
            rec = daemon.tick(now=t)
            if rec["actions"]:
                resize_downtime += sm.resize_seconds
        # batch progress: donor columns x time (minus resize pauses)
        batch_work += sup.cells["batch"].zone.ncols * dt
        t += dt
    return {
        "p99_ms": float(np.mean(tails) * 1e3),
        "p99_worst_ms": float(np.max(tails) * 1e3),
        "batch_work": batch_work,
        "transfers": sup.transfers,
        "resize_downtime_s": resize_downtime,
        "daemon_ticks": daemon.ticks,
    }


def run(rows: List[dict]):
    base_work = None
    for name in ("rainforest", "lxc", "xen", "linux-2.6.35M"):
        r = run_system(name)
        if name == "rainforest":
            base_work = r["batch_work"]
        rows.append({
            "name": f"table5_elastic/{name}/p99_ms",
            "us_per_call": r["p99_ms"] * 1e3,
            "derived": f"worst={r['p99_worst_ms']:.0f}ms transfers={r['transfers']} MODELED",
        })
        rows.append({
            "name": f"table5_elastic/{name}/batch_progress",
            "us_per_call": r["batch_work"],
            "derived": f"vs_rf={r['batch_work']/base_work:.2f}x paper: rf beats lxc/xen MODELED",
        })


def run_smoke(rows: List[dict]):
    """Short trace for CI: the daemon must tick every step AND actually
    move columns (the elasticity loop can't silently rot into a no-op)."""
    r = run_system("rainforest", duration=900.0)
    assert r["daemon_ticks"] == 90, r
    assert r["transfers"] > 0, "daemon-driven policy never moved a column"
    rows.append({
        "name": "table5_elastic/rainforest/smoke_p99_ms",
        "us_per_call": r["p99_ms"] * 1e3,
        "derived": (f"transfers={r['transfers']} "
                    f"ticks={r['daemon_ticks']} MODELED"),
    })


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short single-system trace for CI")
    args = ap.parse_args(argv)
    rows: List[dict] = []
    run_smoke(rows) if args.smoke else run(rows)
    print("name,us_per_call,derived")
    for r in rows:
        d = str(r["derived"]).replace(",", ";")
        print(f"{r['name']},{r['us_per_call']:.3f},{d}")


if __name__ == "__main__":
    sys.exit(main())
