"""Paper Figs 10/11 + Table 5 — (lt,ut) elastic scheduling under a trace.

Replays a fluctuating request-rate trace against a serving cell co-located
with a batch cell (12 "columns" total).  The ThresholdScheduler policy from
``repro.core.elastic`` decides column transfers; each system pays its own
resize cost and interference (calibrated SystemModel).  Outputs the
Table-5 analogue: batch progress, p99, throughput, #transfers.
MODELED (latencies) + the policy/table code paths exercised for real.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.simlib import SYSTEMS, p99, simulate_serving
from repro.core.elastic import ElasticPolicy, ThresholdScheduler
from repro.core.partition import PartitionTable


class _SimCell:
    def __init__(self, ncols):
        self.zone = type("Z", (), {"ncols": ncols})()


class _SimSupervisor:
    """Duck-typed Supervisor for the scheduler: instant bookkeeping, the
    resize *cost* is charged by the caller per the system model."""

    def __init__(self, server_cols, donor_cols):
        self.cells = {"server": _SimCell(server_cols), "batch": _SimCell(donor_cols)}
        self.transfers = 0

    def transfer_columns(self, src, dst, n=1):
        self.cells[src].zone.ncols -= n
        self.cells[dst].zone.ncols += n
        self.transfers += 1
        return {"ncols": n}


def trace_rate(t: float) -> float:
    """Fluctuating load: base 200 req/s with bursts up to ~520 (paper trace)."""
    burst = 110 * (1 + np.sin(t / 90.0)) * (np.sin(t / 13.0) > 0.4)
    return 200 + 60 * np.sin(t / 37.0) + burst


def run_system(sys_name: str, duration=2250.0, dt=10.0, seed=0):
    sm = SYSTEMS[sys_name]
    sup = _SimSupervisor(server_cols=6, donor_cols=6)
    # the scheduler consumes one p99 observation per tick; median over the
    # last 6 ticks (1 min) decides moves, floor of 3 columns prevents
    # shrink-into-overload oscillation
    sched = ThresholdScheduler(
        sup, "server", "batch",
        ElasticPolicy(lt=0.160, ut=0.200, window=6, percentile=50.0,
                      cooldown=40.0, min_server_cols=3, min_donor_cols=2),
    )
    rng = np.random.default_rng(seed)
    batch_work = 0.0
    tails, t = [], 0.0
    resize_downtime = 0.0
    can_resize = sm.resize_seconds > 0 or sys_name in ("lxc", "linux")
    while t < duration:
        rate = trace_rate(t)
        ncols = sup.cells["server"].zone.ncols
        colo = min(sup.cells["batch"].zone.ncols / 12.0, 1.0)
        # 8 service threads per column (real servers multiplex cores)
        lat = simulate_serving(
            sm, rate=rate, duration=dt, n_servers=ncols * 8,
            base_service=0.05, colo_load=colo if sys_name != "rainforest" else 0.25 * colo,
            seed=int(t) ^ seed,
        )
        tail = p99(lat)
        tails.append(tail)
        sched.observe(tail)
        if sys_name != "linux" and can_resize:     # linux: no partition control
            act = sched.maybe_act(now=t)
            if act:
                resize_downtime += sm.resize_seconds
        # batch progress: donor columns x time (minus resize pauses)
        batch_work += sup.cells["batch"].zone.ncols * dt
        t += dt
    return {
        "p99_ms": float(np.mean(tails) * 1e3),
        "p99_worst_ms": float(np.max(tails) * 1e3),
        "batch_work": batch_work,
        "transfers": sup.transfers,
        "resize_downtime_s": resize_downtime,
    }


def run(rows: List[dict]):
    base_work = None
    for name in ("rainforest", "lxc", "xen", "linux-2.6.35M"):
        r = run_system(name)
        if name == "rainforest":
            base_work = r["batch_work"]
        rows.append({
            "name": f"table5_elastic/{name}/p99_ms",
            "us_per_call": r["p99_ms"] * 1e3,
            "derived": f"worst={r['p99_worst_ms']:.0f}ms transfers={r['transfers']} MODELED",
        })
        rows.append({
            "name": f"table5_elastic/{name}/batch_progress",
            "us_per_call": r["batch_work"],
            "derived": f"vs_rf={r['batch_work']/base_work:.2f}x paper: rf beats lxc/xen MODELED",
        })
