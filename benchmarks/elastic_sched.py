"""Paper Figs 10/11 + Table 5 — (lt,ut) elastic scheduling under a trace.

Replays a fluctuating request-rate trace against a serving cell co-located
with a batch cell (12 "columns" total), driven by the DECLARATIVE control
plane: desired state is a ClusterSpec (server bounded [3,10] cols, batch
[2,10]); each tick the modeled p99 is recorded into the server cell's
real ``CellAccounting`` and a :class:`ReconcilePolicy` pulls it, rescales
the spec, and ``apply``s — the real :class:`Reconciler` plans the column
``transfer``s against a bookkeeping-only supervisor (instant primitives;
the resize *cost* is charged per the calibrated SystemModel).  Outputs
the Table-5 analogue: batch progress, p99, throughput, #transfers.
MODELED (latencies) + the policy/spec/reconciler code paths exercised for
real — zero direct ``transfer_columns`` calls in this file.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.simlib import SYSTEMS, SimCell, SimSupervisor, p99, simulate_serving
from repro.core.elastic import ElasticPolicy, ReconcilePolicy
from repro.core.spec import CellSpec, ClusterSpec


def trace_rate(t: float) -> float:
    """Fluctuating load: base 200 req/s with bursts up to ~520 (paper trace)."""
    burst = 110 * (1 + np.sin(t / 90.0)) * (np.sin(t / 13.0) > 0.4)
    return 200 + 60 * np.sin(t / 37.0) + burst


def run_system(sys_name: str, duration=2250.0, dt=10.0, seed=0):
    sm = SYSTEMS[sys_name]
    sup = SimSupervisor(SimCell("server", 6, "serve"),
                        SimCell("batch", 6, "train"))
    # desired state: the policy may move the server within [3,10] columns
    # (floor of 3 prevents shrink-into-overload oscillation), the batch
    # donor keeps at least 2
    spec = ClusterSpec(cells=(
        CellSpec("server", None, "serve", ncols=6, min_ncols=3, max_ncols=10),
        CellSpec("batch", None, "train", ncols=6, min_ncols=2, max_ncols=10),
    ))
    plan = sup.apply(spec)
    assert plan.empty                  # observed already matches desired
    # the policy consumes one p99 observation per tick via the server
    # cell's accounting; median over the last 6 ticks (1 min) decides moves
    sched = ReconcilePolicy(
        sup, "server", "batch",
        ElasticPolicy(lt=0.160, ut=0.200, window=6, percentile=50.0,
                      cooldown=40.0, metric="ttft"),
    )
    batch_work = 0.0
    tails, t = [], 0.0
    rid = 0
    resize_downtime = 0.0
    can_resize = sm.resize_seconds > 0 or sys_name in ("lxc", "linux")
    while t < duration:
        rate = trace_rate(t)
        ncols = sup.cells["server"].zone.ncols
        colo = min(sup.cells["batch"].zone.ncols / 12.0, 1.0)
        # 8 service threads per column (real servers multiplex cores)
        lat = simulate_serving(
            sm, rate=rate, duration=dt, n_servers=ncols * 8,
            base_service=0.05, colo_load=colo if sys_name != "rainforest" else 0.25 * colo,
            seed=int(t) ^ seed,
        )
        tail = p99(lat)
        tails.append(tail)
        # live accounting feed: the tick's tail lands in the server cell's
        # CellAccounting; sched.maybe_act() pulls it from there
        sup.cells["server"].accounting.record_request(rid, ttft=tail)
        rid += 1
        if sys_name != "linux" and can_resize:     # linux: no partition control
            act = sched.maybe_act(now=t)
            if act:
                resize_downtime += sm.resize_seconds
        # batch progress: donor columns x time (minus resize pauses)
        batch_work += sup.cells["batch"].zone.ncols * dt
        t += dt
    return {
        "p99_ms": float(np.mean(tails) * 1e3),
        "p99_worst_ms": float(np.max(tails) * 1e3),
        "batch_work": batch_work,
        "transfers": sup.transfers,
        "resize_downtime_s": resize_downtime,
    }


def run(rows: List[dict]):
    base_work = None
    for name in ("rainforest", "lxc", "xen", "linux-2.6.35M"):
        r = run_system(name)
        if name == "rainforest":
            base_work = r["batch_work"]
        rows.append({
            "name": f"table5_elastic/{name}/p99_ms",
            "us_per_call": r["p99_ms"] * 1e3,
            "derived": f"worst={r['p99_worst_ms']:.0f}ms transfers={r['transfers']} MODELED",
        })
        rows.append({
            "name": f"table5_elastic/{name}/batch_progress",
            "us_per_call": r["batch_work"],
            "derived": f"vs_rf={r['batch_work']/base_work:.2f}x paper: rf beats lxc/xen MODELED",
        })
