"""Multi-tenant QoS benchmark: a victim tenant under an adversarial
co-tenant flood.

The scenario every "isolate first, then share" mechanism exists for: a
latency-sensitive VICTIM tenant (weight 4, half the KV pool as its
private pocket) shares a serving surface with an ADVERSARY tenant
(weight 1, commons pocket) that floods the queue with many long,
cache-polluting prompts.  With working bulkheads the flood saturates
only the adversary's own resources — the commons pocket and its
weighted slot share — while the victim's admissions, pages, and cached
prefix are untouched.

Phases (programs compiled before anything is timed):

  0. compile     — throwaway victim + adversary waves (pays every jit)
  1. solo        — a victim wave alone: the baseline TTFT tail
  2. contended   — the adversary submits its whole flood FIRST, then
                   the same-shaped victim wave lands behind it

Reported per phase: victim TTFT p50/p99, per-tenant pool blocks, pocket
occupancy.  The ``--smoke`` gate (CI) asserts the isolation contract:

  * victim p99 TTFT under attack <= 1.2x solo,
  * the adversary's exhaustion never blocks a victim allocation the
    victim's own pocket covers (zero victim pool-blocks),
  * the attack was real (the adversary itself DID block on the pool),
  * every request from both tenants is eventually served — isolation
    degrades the flood, it never drops it.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.core import DeviceGrid, Supervisor
from repro.core.spec import TenantSpec
from repro.serve.batcher import Request

VICTIM, ADV = "victim", "adv"


def _victim_wave(cfg, sysp, n, suffix_len, rid0, seed):
    """Victim traffic: one shared system prompt + short user suffixes
    (the prefix-cache-friendly shape production victims have)."""
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n):
        tail = rng.randint(1, cfg.vocab, size=suffix_len).astype(np.int32)
        out.append(Request(rid=rid0 + i, prompt=np.concatenate([sysp, tail]),
                           max_new_tokens=4, tenant=VICTIM))
    return out


def _adv_flood(cfg, n, prompt_len, rid0, seed):
    """Adversary traffic: many DISTINCT max-entropy prompts of one
    length — no shareable prefix, maximal pocket pressure, every
    admission wants fresh pages."""
    rng = np.random.RandomState(seed)
    return [Request(rid=rid0 + i,
                    prompt=rng.randint(1, cfg.vocab,
                                       size=prompt_len).astype(np.int32),
                    max_new_tokens=4, tenant=ADV)
            for i in range(n)]


def _phase(srv, reqs, measure_rids):
    """Submit one wave (in list order), drain, report victim-tenant
    latency plus per-tenant pressure counters as PHASE DELTAS."""
    before = srv.stats()
    blocked_before = dict(before["blocked_by_tenant"])
    t0 = time.monotonic()
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained(max_steps=50_000)
    wall = time.monotonic() - t0
    served = [r for r in srv.done if r.rid in measure_rids]
    assert len(served) == len(measure_rids), "a measured request was lost"
    ttfts = sorted(r.ttft for r in served)
    st = srv.stats()
    blocked = {t: st["blocked_by_tenant"].get(t, 0) - blocked_before.get(t, 0)
               for t in set(st["blocked_by_tenant"]) | set(blocked_before)}
    return {
        "wall_s": wall,
        "ttft_p50": float(np.percentile(ttfts, 50)),
        "ttft_p99": float(np.percentile(ttfts, 99)),
        "blocked_by_tenant": blocked,
        "prefix_hit_tokens": (st["prefix_hit_tokens"]
                              - before["prefix_hit_tokens"]),
        "pool_occupancy": st["pool_occupancy"],
    }


def run(arch: str = "qwen3-4b", *, max_len: int = 128, chunk: int = 16,
        page_size: int = 16, system_len: int = 64, suffix_len: int = 12,
        victim_requests: int = 6, adv_requests: int = 24,
        adv_prompt_len: int = 100, batch_slots: int = 4,
        smoke: bool = False):
    cfg = smoke_config(get_arch(arch))
    if cfg.sliding_window is not None and cfg.sliding_window < max_len:
        cfg = cfg.replace(sliding_window=max_len)
    from repro.serve.disagg import DisaggServer

    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=3,
                                allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("prefill", cfg, "serve", ncols=1)
    dec = sup.create_cell("dec0", cfg, "serve", ncols=1)
    dec.init_serve(rng=jax.random.PRNGKey(0))
    sup.create_cell("dec1", cfg, "serve", ncols=1)
    # the victim wave is sized to its DRR share of one tick's slots
    # (weight 4 of 5 over 8 slots -> 6), and the quantum is small enough
    # that the adversary cannot pre-book more than its share in a single
    # round — this is the QoS contract the gate verifies, not a trick:
    # a tenant is only promised ITS weighted share of the surface
    srv = DisaggServer(sup, "prefill", ["dec0", "dec1"],
                       batch_slots=batch_slots, max_len=max_len, chunk=chunk,
                       page_size=page_size, quantum=64,
                       tenants=[TenantSpec(VICTIM, weight=4.0,
                                           page_quota=0.5),
                                TenantSpec(ADV, weight=1.0)])
    assert srv.worker is not None and srv.worker.pool is not None, \
        "multitenant benchmark needs a shareable cache plane (paged or snapshot)"

    rng = np.random.RandomState(0)
    sysp = rng.randint(1, cfg.vocab, size=system_len).astype(np.int32)

    # phase 0: compile both tenants' program shapes AND warm the victim's
    # system prefix, so solo and contended both measure warm steady state
    _phase(srv, _victim_wave(cfg, sysp, victim_requests, suffix_len, 1000,
                             seed=1), {1000 + i for i in range(victim_requests)})
    _phase(srv, _adv_flood(cfg, 8, adv_prompt_len, 2000, seed=2),
           {2000 + i for i in range(8)})

    solo = _phase(srv, _victim_wave(cfg, sysp, victim_requests, suffix_len,
                                    3000, seed=3),
                  {3000 + i for i in range(victim_requests)})

    # worst case: the whole flood is queued BEFORE the victim arrives
    flood = _adv_flood(cfg, adv_requests, adv_prompt_len, 5000, seed=5)
    wave = _victim_wave(cfg, sysp, victim_requests, suffix_len, 4000, seed=4)
    contended = _phase(srv, flood + wave,
                       {4000 + i for i in range(victim_requests)})

    ratio = contended["ttft_p99"] / max(solo["ttft_p99"], 1e-9)
    st = srv.stats()
    out = {
        "arch": cfg.name, "max_len": max_len, "page_size": page_size,
        "victim_requests": victim_requests, "adv_requests": adv_requests,
        "solo": solo, "contended": contended,
        "contended_over_solo_ttft_p99": ratio,
        "per_tenant": st["per_tenant"],
        "served_cost_by_tenant": st["served_cost_by_tenant"],
    }
    print(f"== multitenant [{cfg.name}] victim x{victim_requests} "
          f"(w=4, quota=0.5) vs adversary x{adv_requests} (w=1, commons) ==")
    for phase in ("solo", "contended"):
        p = out[phase]
        print(f"  {phase:9s} victim ttft p50 {p['ttft_p50'] * 1e3:8.1f} ms  "
              f"p99 {p['ttft_p99'] * 1e3:8.1f} ms  "
              f"blocked {p['blocked_by_tenant']}  "
              f"occupancy {p['pool_occupancy']:.2f}")
    print(f"  contended/solo victim ttft p99 = {ratio:.3f}")

    if smoke:
        assert ratio <= 1.2, (
            f"victim p99 TTFT under attack must stay <= 1.2x solo, "
            f"got {ratio:.3f}")
        assert contended["blocked_by_tenant"].get(VICTIM, 0) == 0, (
            "the adversary's exhaustion blocked a victim allocation the "
            f"victim's pocket covers: {contended['blocked_by_tenant']}")
        assert contended["blocked_by_tenant"].get(ADV, 0) > 0, (
            "the flood never hit the pool — the adversarial phase is "
            "not exercising the bulkhead")
        assert contended["prefix_hit_tokens"] > 0, (
            "cache pollution evicted the victim's prefix — the quota "
            "pocket failed to protect it")
        print("SMOKE OK")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + the CI acceptance gate")
    ap.add_argument("--victim-requests", type=int, default=None)
    ap.add_argument("--adv-requests", type=int, default=None)
    ap.add_argument("--max-len", type=int, default=None)
    args = ap.parse_args()
    kw = {}
    if args.smoke:
        kw = dict(smoke=True)
    for k in ("victim_requests", "adv_requests", "max_len"):
        v = getattr(args, k)
        if v is not None:
            kw[k] = v
    run(args.arch, **kw)


if __name__ == "__main__":
    main()
