"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  MEASURED rows are real timings on
this host; MODELED rows come from the calibrated simulator (see
benchmarks/simlib.py docstring for the calibration anchors).  The roofline
tables live in ``benchmarks/roofline.py`` (run separately: they need 512
host devices, while these benches must see the real single device).
"""
from __future__ import annotations

import traceback
from typing import List


def main() -> None:
    from benchmarks import (
        channels,
        elastic_sched,
        elasticity,
        isolation,
        tail_latency,
    )

    rows: List[dict] = []
    for mod in (tail_latency, isolation, elasticity, elastic_sched, channels):
        try:
            mod.run(rows)
        except Exception:
            traceback.print_exc()
            rows.append({
                "name": f"{mod.__name__}/ERROR",
                "us_per_call": -1,
                "derived": "crashed",
            })

    print("name,us_per_call,derived")
    for r in rows:
        d = str(r["derived"]).replace(",", ";")
        print(f"{r['name']},{r['us_per_call']:.3f},{d}")


if __name__ == "__main__":
    main()
