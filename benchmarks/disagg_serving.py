"""Disaggregated prefill/decode serving — TTFT/TPOT, colocated vs split.

MEASURED on this host (single CPU device; cells are logical zones over it):

  * ``token_at_a_time`` — the old prompt loop: every prompt token is one
    decode-program invocation, so TTFT ~ prompt_len x decode_step_latency.
  * ``colocated_chunked`` — chunked prefill inside one serving cell: one
    bucket-padded prefill invocation per prompt.
  * ``disaggregated``   — prefill cell -> ArrayChannel(kind="kv") -> decode
    cell (the RainForest share-on-demand pattern applied to inference),
    with per-request KV rows streamed into free batcher slots.

Also exercises the declarative elastic loop between the two cells: the
decode cell's live TTFT accounting feeds a ``ReconcilePolicy``; when the
tail crosses the upper threshold the policy rescales the ClusterSpec and
``Supervisor.apply`` turns the diff into a column transfer from the
prefill cell to the decode cell (live reshard on both) — the Fig 10/11
elasticity loop applied to the serving split.

Run:  PYTHONPATH=src python benchmarks/disagg_serving.py [--smoke] [--arch NAME]

``--arch`` accepts any registered config (smoke-reduced here); every family
— dense, moe, ssm, hybrid, encdec — runs the same chunked/disaggregated
path, and ``make bench-smoke`` sweeps one config per family.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List

import numpy as np


def _make_requests(vocab: int, lens, max_new: int, seed=0):
    from repro.serve.batcher import Request
    rng = np.random.RandomState(seed)
    return [
        Request(rid=i, prompt=rng.randint(1, vocab, size=L).astype(np.int32),
                max_new_tokens=max_new)
        for i, L in enumerate(lens)
    ]


def _summarize(reqs) -> dict:
    ttfts = np.array([r.ttft for r in reqs if r.ttft is not None])
    tpots = np.array([r.tpot for r in reqs if r.tpot is not None])
    return {
        "ttft_p50_ms": float(np.percentile(ttfts, 50) * 1e3) if len(ttfts) else -1,
        "ttft_p99_ms": float(np.percentile(ttfts, 99) * 1e3) if len(ttfts) else -1,
        "tpot_p50_ms": float(np.percentile(tpots, 50) * 1e3) if len(tpots) else -1,
    }


def run(rows: List[dict], smoke: bool = True, arch: str = "qwen3-4b"):
    import jax

    from repro.configs.base import smoke_config
    from repro.configs.registry import get_arch
    from repro.core import (
        CellSpec,
        ClusterSpec,
        DeviceGrid,
        ElasticPolicy,
        ReconcilePolicy,
        Supervisor,
    )
    from repro.serve.batcher import ContinuousBatcher
    from repro.serve.disagg import DisaggServer

    cfg = smoke_config(get_arch(arch))
    tag = f"disagg_serving[{arch}]" if arch != "qwen3-4b" else "disagg_serving"
    max_len, chunk, max_new = (64, 16, 4) if smoke else (256, 32, 16)
    lens = [33, 40, 35, 48] if smoke else [64, 100, 80, 120, 90, 64, 110, 72]
    slots = 4

    # 2x4 grid when the host has 8 (virtual) devices — the standalone entry
    # point forces that, so resize/transfer are real; under run.py's single
    # real device the cells collapse onto it and the elastic section skips
    # (a 2-column zone would put the same device in the mesh twice).
    devs = jax.devices()
    if len(devs) >= 8:
        grid = DeviceGrid.from_flat(devs, pods=1, rows=2, cols=4)
    else:
        grid = DeviceGrid.from_flat(devs[:1], pods=1, rows=1, cols=4,
                                    allow_reuse=True)
    can_resize = len({id(d) for d in grid.devices.flat}) == grid.devices.size
    sup = Supervisor(grid)
    spec = ClusterSpec(cells=(CellSpec("solo", cfg, "serve", ncols=1),))
    sup.apply(spec)
    solo = sup.cells["solo"]
    solo.init_serve(rng=jax.random.PRNGKey(0))

    # -- baseline: token-at-a-time prompt loop --------------------------
    reqs = _make_requests(cfg.vocab, lens, max_new)
    bat = ContinuousBatcher(solo.model, solo.serve_params, batch_slots=slots,
                            max_len=max_len, prefill_chunk=None)
    for r in reqs:
        bat.submit(r)
    t0 = time.perf_counter()
    bat.run_until_drained()
    base_wall = time.perf_counter() - t0
    base_prompt_invocations = sum(len(r.prompt) for r in reqs)  # 1/token
    s = _summarize(reqs)
    rows.append({
        "name": f"{tag}/token_at_a_time/ttft_p99",
        "us_per_call": s["ttft_p99_ms"] * 1e3,
        "derived": (
            f"p50={s['ttft_p50_ms']:.1f}ms tpot={s['tpot_p50_ms']:.1f}ms "
            f"invocations/prompt={base_prompt_invocations / len(reqs):.1f} MEASURED"
        ),
    })

    # -- colocated chunked prefill --------------------------------------
    reqs = _make_requests(cfg.vocab, lens, max_new)
    bat = ContinuousBatcher(solo.model, solo.serve_params, batch_slots=slots,
                            max_len=max_len, prefill_chunk=chunk)
    for r in reqs:
        bat.submit(r)
    t0 = time.perf_counter()
    bat.run_until_drained()
    chunk_wall = time.perf_counter() - t0
    inv_per_prompt = bat.prefill_invocations / len(reqs)
    reduction = (base_prompt_invocations / len(reqs)) / inv_per_prompt
    s = _summarize(reqs)
    rows.append({
        "name": f"{tag}/colocated_chunked/ttft_p99",
        "us_per_call": s["ttft_p99_ms"] * 1e3,
        "derived": (
            f"p50={s['ttft_p50_ms']:.1f}ms tpot={s['tpot_p50_ms']:.1f}ms "
            f"invocations/prompt={inv_per_prompt:.1f} "
            f"({reduction:.1f}x fewer) MEASURED"
        ),
    })
    assert reduction >= 4.0, (
        f"chunked prefill must cut prompt-phase invocations >=4x, got {reduction:.1f}x"
    )

    # -- paged vs dense decode step time (the gather-tax gate) ----------
    # The native paged step feeds the arena + width-trimmed block table
    # straight into Model.decode; it must be no slower than the legacy
    # dense per-slot cache step.  Prompt depth and step count are chosen
    # so the pow2 width bucket stays constant over the timed window (no
    # recompile mid-measurement).  Skipped for families without a
    # pageable cache (ssm/hybrid state, rolling SWA).
    # the gate runs at its own cache depth: the dense step streams the
    # whole (B, gate_len) allocation every token while the paged step
    # walks ~2 pages/row, so gate_len sets the size of the tax being
    # measured (at toy depths per-op dispatch noise drowns it out)
    from repro.serve.kvpool import KVPool
    gate_len = max(max_len, 512)

    def _decode_step_time(kv_pool, accounting=None, reps=1):
        """Mean decode-step time, min over ``reps`` timed windows (the
        min filters scheduler noise so close-ratio gates stay stable)."""
        gate_reqs = _make_requests(cfg.vocab, [17] * slots,
                                   3 + 8 * reps + 2, seed=1)
        b = ContinuousBatcher(solo.model, solo.serve_params,
                              batch_slots=slots, max_len=gate_len,
                              prefill_chunk=chunk, kv_pool=kv_pool,
                              pool_pages=gate_len // 16,
                              accounting=accounting)
        for r in gate_reqs:
            b.submit(r)
        for _ in range(3):       # admit + prefill + warm the decode jit
            b.step()
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            for _ in range(8):
                b.step()
            jax.block_until_ready(b.pool.arena if b.pool is not None
                                  else b.cache)
            best = min(best, (time.perf_counter() - t0) / 8)
        return best

    if KVPool.capability(solo.model, gate_len, 16) == "paged":
        dense_t = _decode_step_time(None)
        paged_t = _decode_step_time("auto")
        ratio = paged_t / dense_t
        rows.append({
            "name": f"{tag}/paged_vs_dense_decode",
            "us_per_call": paged_t * 1e6,
            "derived": (
                f"dense={dense_t*1e3:.2f}ms paged={paged_t*1e3:.2f}ms "
                f"ratio={ratio:.2f} GATE<=1.0 MEASURED"
            ),
        })
        assert ratio <= 1.0, (
            f"paged decode step must not exceed the dense baseline: "
            f"paged={paged_t*1e3:.2f}ms dense={dense_t*1e3:.2f}ms "
            f"({ratio:.2f}x)"
        )

    # -- telemetry overhead gate ----------------------------------------
    # The flight recorder sits on the decode hot path (one add_complete +
    # one histogram record per step, span helpers per request).  Enabled
    # vs disabled must stay within 5%; min-of-reps on both sides so the
    # gate measures the instrumentation, not the CI scheduler.
    from repro.core.accounting import CellAccounting
    off_t = _decode_step_time(None, accounting=None, reps=3)
    acc = CellAccounting("telemetry-gate")
    on_t = _decode_step_time(None, accounting=acc, reps=3)
    overhead = on_t / off_t
    rows.append({
        "name": f"{tag}/telemetry_overhead",
        "us_per_call": on_t * 1e6,
        "derived": (
            f"recorder_off={off_t*1e3:.2f}ms recorder_on={on_t*1e3:.2f}ms "
            f"ratio={overhead:.3f} GATE<=1.05 MEASURED"
        ),
    })
    assert overhead <= 1.05, (
        f"flight recorder must cost <=5% on the decode step: "
        f"on={on_t*1e3:.2f}ms off={off_t*1e3:.2f}ms ({overhead:.3f}x)"
    )
    assert acc.recorder.hists["decode_step_s"].count >= 8, (
        "recorder-on run must actually have recorded decode steps"
    )

    # -- disaggregated: prefill cell -> decode cell ---------------------
    spec = (spec
            .with_cell(CellSpec("prefill", cfg, "serve",
                                ncols=2 if can_resize else 1, min_ncols=1))
            .with_cell(CellSpec("decode", cfg, "serve", ncols=1,
                                min_ncols=1, max_ncols=2)))
    sup.apply(spec)
    dec = sup.cells["decode"]
    dec.init_serve(rng=jax.random.PRNGKey(0))
    srv = DisaggServer(sup, "prefill", "decode", batch_slots=slots,
                       max_len=max_len, chunk=chunk)
    reqs = _make_requests(cfg.vocab, lens, max_new)
    for r in reqs:
        srv.submit(r)
    t0 = time.perf_counter()
    srv.run_until_drained()
    disagg_wall = time.perf_counter() - t0
    st = srv.stats()
    s = _summarize(reqs)
    rows.append({
        "name": f"{tag}/disaggregated/ttft_p99",
        "us_per_call": s["ttft_p99_ms"] * 1e3,
        "derived": (
            f"p50={s['ttft_p50_ms']:.1f}ms tpot={s['tpot_p50_ms']:.1f}ms "
            f"kv={st['kv_bytes'] / 1e6:.2f}MB/"
            f"{st['kv_transfers']}xfers MEASURED"
        ),
    })
    rows.append({
        "name": f"{tag}/wall_clock",
        "us_per_call": disagg_wall * 1e6,
        "derived": (
            f"token_at_a_time={base_wall:.2f}s chunked={chunk_wall:.2f}s "
            f"disagg={disagg_wall:.2f}s MEASURED"
        ),
    })

    # -- elastic loop: decode cell grows off the prefill cell -----------
    if can_resize:
        sched = ReconcilePolicy(
            sup, "decode", "prefill",
            ElasticPolicy(lt=1e-4, ut=5e-3, window=10, cooldown=0.0,
                          metric="ttft"),
        )
        # maybe_act() pulls the disagg run's TTFTs straight out of the
        # decode cell's CellAccounting; top up if the window is short
        sched.pull()
        while len(sched.samples) < 10:
            sched.observe(s["ttft_p50_ms"] / 1e3)
        t0 = time.perf_counter()
        act = sched.maybe_act()
        dt = time.perf_counter() - t0
        rows.append({
            "name": f"{tag}/elastic_transfer",
            "us_per_call": dt * 1e6,
            "derived": (
                f"action={act['kind'] if act else 'none'} "
                f"prefill_cols={sup.cells['prefill'].zone.ncols} "
                f"decode_cols={sup.cells['decode'].zone.ncols} MEASURED"
            ),
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + short prompts for CI")
    ap.add_argument("--arch", default="qwen3-4b",
                    help="registered arch to serve (smoke-reduced); the CI "
                         "smoke sweeps one config per family so a "
                         "reintroduced family gate fails fast")
    args = ap.parse_args(argv)
    # standalone entry: 8 virtual host devices so multi-column cells and
    # the elastic transfer are real (must be set before jax initializes)
    import os
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    rows: List[dict] = []
    run(rows, smoke=args.smoke, arch=args.arch)
    print("name,us_per_call,derived")
    for r in rows:
        d = str(r["derived"]).replace(",", ";")
        print(f"{r['name']},{r['us_per_call']:.3f},{d}")


if __name__ == "__main__":
    sys.exit(main())
