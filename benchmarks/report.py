"""Fill EXPERIMENTS.md tables from dry-run / roofline JSONs."""
from __future__ import annotations

import json
import os
import re
from glob import glob

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def dryrun_table() -> str:
    rows = []
    for mesh in ("single", "multi"):
        for path in sorted(glob(os.path.join(ROOT, "experiments/dryrun", mesh, "*.json"))):
            with open(path) as f:
                d = json.load(f)
            rows.append(d)
    if not rows:
        return "(run the dry-run sweep first)"
    out = ["| arch | shape | mesh | devices | params | compile s | mem/dev GiB | coll MiB/step* |",
           "|---|---|---|---|---|---|---|---|"]
    for d in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        out.append(
            "| {arch} | {shape} | {mesh} | {devices} | {p:.1f}B | {c:.0f} | {m:.2f} | {coll:.0f} |".format(
                arch=d["arch"], shape=d["shape"], mesh=d["mesh"],
                devices=d["devices"], p=d["n_params"] / 1e9,
                c=d["compile_s"],
                m=d["memory"]["peak_estimate_bytes"] / 2**30,
                coll=sum(d["collective_bytes_per_device"].values()) / 2**20,
            ))
    out.append("")
    out.append("*coll = whole-program HLO parse; loop bodies counted once "
               "(see §Roofline for trip-count-correct terms).  mem/dev = CPU-"
               "backend upper bound.")
    return "\n".join(out)


def roofline_table(level: str) -> str:
    rows = []
    for path in sorted(glob(os.path.join(ROOT, "experiments/roofline", f"*__{level}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    if not rows:
        return "(run benchmarks.roofline first)"
    out = ["| arch | shape | C ms | M ms (hlo) | X ms | dominant | fraction | useful |",
           "|---|---|---|---|---|---|---|---|"]
    for d in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(
            "| {arch} | {shape} | {c:.1f} | {m:.1f} ({mh:.0f}) | {x:.1f} | {dom} | {f:.3f} | {u:.2f} |".format(
                arch=d["arch"], shape=d["shape"],
                c=d["t_compute_s"] * 1e3, m=d["t_memory_s"] * 1e3,
                mh=d["t_memory_hlo_s"] * 1e3, x=d["t_collective_s"] * 1e3,
                dom=d["dominant"], f=d["roofline_fraction"],
                u=d["useful_ratio"],
            ))
    return "\n".join(out)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    for marker, content in [
        ("<!-- DRYRUN_TABLE -->", dryrun_table()),
        ("<!-- ROOFLINE_BASELINE -->", roofline_table("baseline")),
        ("<!-- ROOFLINE_OPTIMIZED -->", roofline_table("optimized")),
    ]:
        block = f"{marker}\n{content}\n<!-- /{marker[5:]}"
        # replace marker (and any previously generated block after it)
        pat = re.compile(re.escape(marker) + r"(?:.*?<!-- /" + re.escape(marker[5:]) + r")?",
                         re.S)
        text = pat.sub(lambda _m: block, text, count=1)
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
