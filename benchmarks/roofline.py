import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Roofline analysis per (arch x shape) on the single-pod production mesh.

``cost_analysis`` on a compiled program counts while-loop bodies ONCE, so a
scanned-layer program under-reports FLOPs by the trip count.  This harness
therefore accounts **compositionally**: each cell is decomposed into its
repeated components (layer bodies, head, optimizer), every component is
lowered+compiled standalone on the production mesh with all internal loops
unrolled (attention scans included), and totals are

    total = sum_over_components(count x per-device cost)

Train layer cost models the remat schedule explicitly: fwd + (fwd + bwd)
(the backward recomputes the forward).  Collective bytes are parsed from
each component's post-SPMD HLO.  Hardware: v5e-class — 197 TF/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.

Roofline terms (seconds, per step):
    compute    = flops_dev / 197e12
    memory     = bytes_dev / 819e9
    collective = coll_bytes_dev / 50e9
"""
__doc__ = globals().get("__doc__") or ""

import argparse
import json
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig, shapes_for, with_opt_level
from repro.configs.registry import ARCHS, get_arch
from repro.core.accounting import collective_bytes
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm
from repro.models import zamba2 as zmb
from repro.models.model import build_model
from repro.models.param import abstract_params, is_pspec
from repro.sharding.rules import make_ctx
from repro.train.optimizer import OptConfig, adamw_update, abstract_adam_state
from repro.train.train_step import resolve_microbatch


def _ns(mesh, pspec_tree):
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )


def _cost(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):        # older jax returns [dict] per device
        ca = ca[0] if ca else {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": float(sum(coll.values())),
    }


def _lower_cost(fn, arg_sds, arg_shardings) -> Dict[str, float]:
    jitted = jax.jit(fn, in_shardings=arg_shardings)
    return _cost(jitted.lower(*arg_sds).compile())


class CellAccountant:
    """Compositional per-device cost accounting for one (arch, shape)."""

    def __init__(self, arch: ArchConfig, shape: ShapeConfig, mesh):
        kv_chunk = 4096 if shape.seq_len >= 32768 else 1024
        self.arch = arch.replace(
            unroll_attn=True,
            attn_q_chunk=kv_chunk,
            attn_kv_chunk=kv_chunk,
        )
        self.shape = shape
        self.mesh = mesh
        zero3_ok = (shape.kind == "train" and arch.train_layout == "zero3"
                    and shape.global_batch % int(mesh.devices.size) == 0)
        self.ctx = make_ctx(
            mesh,
            fsdp=True if shape.kind == "train" else arch.serve_fsdp,
            dp_over_model=zero3_ok,
        )
        self.model = build_model(self.arch, self.ctx)
        self.cfg = self.model.cfg
        self.dp = self.ctx.dp_size()
        self.n_micro = (
            resolve_microbatch(max(arch.microbatch, 1), shape.global_batch, self.dp)
            if shape.kind == "train" else 1
        )
        self.B = shape.global_batch // self.n_micro   # per-microbatch batch
        self.S = shape.seq_len

    # -- shared input makers -------------------------------------------
    def _x_sds(self, B, S):
        return jax.ShapeDtypeStruct((B, S, self.cfg.d_model), self.model.dtype)

    def _x_shard(self, B, S):
        mode = self.cfg.activation_shard
        logical = (
            ("batch", "act_seq", None) if mode == "seq"
            else ("batch", None, "act_embed") if mode == "embed"
            else ("batch", None, None)
        )
        return jax.sharding.NamedSharding(self.mesh, self.ctx.pspec(logical, (B, S, self.cfg.d_model)))

    def _layer_param_sds(self, specs):
        return abstract_params(specs, self.cfg.dtype)

    def _layer_param_shardings(self, specs):
        return _ns(self.mesh, self.ctx.params_pspecs(specs))

    # -- component cost helpers ----------------------------------------
    def _train_component(self, layer_fn, specs, B, S) -> Dict[str, float]:
        """fwd + (fwd+bwd) per the remat schedule."""
        x_sds = self._x_sds(B, S)
        lp_sds = self._layer_param_sds(specs)
        x_sh = self._x_shard(B, S)
        lp_sh = self._layer_param_shardings(specs)

        def fwd(x, lp):
            y, _, aux = layer_fn(x, lp)
            return y

        def train(x, lp):
            y, _, aux = layer_fn(x, lp)
            return y.astype(jnp.float32).sum() + aux

        c_f = _lower_cost(fwd, (x_sds, lp_sds), (x_sh, lp_sh))
        c_g = _lower_cost(
            jax.grad(train, argnums=(0, 1)), (x_sds, lp_sds), (x_sh, lp_sh)
        )
        return {k: c_f[k] + c_g[k] for k in c_f}

    def _fwd_component(self, layer_fn, specs, B, S, extra_sds=(), extra_sh=()) -> Dict[str, float]:
        x_sds = self._x_sds(B, S)
        lp_sds = self._layer_param_sds(specs)
        x_sh = self._x_shard(B, S)
        lp_sh = self._layer_param_shardings(specs)

        def fwd(x, lp, *extra):
            y, _, _ = layer_fn(x, lp, *extra)
            return y

        return _lower_cost(fwd, (x_sds, lp_sds) + tuple(extra_sds),
                           (x_sh, lp_sh) + tuple(extra_sh))

    # -- family decomposition ------------------------------------------
    def _components(self):
        """[(name, layer_fn, specs, count, decode_cache_kind)] per family."""
        cfg, ctx = self.cfg, self.ctx
        fam = cfg.family
        out = []
        if fam in ("dense", "vlm"):
            out.append(("dense", tfm.dense_layer_specs(cfg), cfg.num_layers, "kv"))
        elif fam == "moe":
            fd = cfg.moe.first_dense_layers
            if fd:
                out.append(("dense", tfm.dense_layer_specs(cfg, d_ff=cfg.moe.dense_d_ff), fd, "kv"))
            out.append(("moe", tfm.moe_layer_specs(cfg, ctx), cfg.num_layers - fd, "kv"))
        elif fam == "ssm":
            out.append(("mamba", zmb.mamba_layer_specs(cfg), cfg.num_layers, "mamba"))
        elif fam == "hybrid":
            out.append(("mamba", zmb.mamba_layer_specs(cfg), cfg.num_layers, "mamba"))
            out.append(("shared", zmb.shared_block_specs(cfg),
                        cfg.num_layers // cfg.hybrid_attn_every, "kv"))
        elif fam == "encdec":
            out.append(("enc", encdec_mod.enc_layer_specs(cfg), cfg.encoder_layers, None))
            out.append(("dec", encdec_mod.dec_layer_specs(cfg), cfg.num_layers, "dec"))
        return out

    def _layer_fn(self, name, mode, cache_sds=None, pos=None, memory_sds=None):
        cfg, ctx = self.cfg, self.ctx
        if name == "dense":
            return lambda x, lp, *e: tfm.dense_layer(
                lp, x, cfg, ctx, mode=mode,
                cache=e[0] if e else None, pos=e[1] if len(e) > 1 else None)
        if name == "moe":
            return lambda x, lp, *e: tfm.moe_layer(
                lp, x, cfg, ctx, mode=mode,
                cache=e[0] if e else None, pos=e[1] if len(e) > 1 else None)
        if name == "mamba":
            return lambda x, lp, *e: zmb.mamba_layer(
                lp, x, cfg, mode=mode, state=e[0] if e else None)
        if name == "shared":
            def f(x, lp, *e):
                y, nc = zmb.shared_block(
                    lp, x, x, cfg, ctx, mode=mode,
                    cache=e[0] if e else None, pos=e[1] if len(e) > 1 else None)
                return y, nc, jnp.float32(0.0)
            return f
        if name == "enc":
            return lambda x, lp, *e: encdec_mod.enc_layer(lp, x, cfg, ctx)
        if name == "dec":
            return lambda x, lp, *e: encdec_mod.dec_layer(
                lp, x, cfg, ctx, mode=mode,
                memory=e[0] if (e and mode == "train") else None,
                cache=e[0] if (e and mode != "train") else None,
                pos=e[1] if len(e) > 1 else None)
        raise ValueError(name)

    def _cache_slice_specs(self, kind, B, S):
        from repro.models.layers import kv_slice_specs
        if kind == "kv":
            return kv_slice_specs(self.cfg, B, S)
        if kind == "mamba":
            return self.model._mamba_state_specs(B)
        if kind == "dec":
            s_src = self.model.source_len(S)
            hkv, dh = self.cfg.num_kv_heads, self.cfg.resolved_head_dim
            from repro.models.param import PSpec
            return encdec_mod.DecCache(
                self_kv=kv_slice_specs(self.cfg, B, S),
                cross_k=PSpec((B, s_src, hkv, dh), ("batch", "kv_seq", None, None), ("const", 0.0)),
                cross_v=PSpec((B, s_src, hkv, dh), ("batch", "kv_seq", None, None), ("const", 0.0)),
            )
        raise ValueError(kind)

    # -- head & optimizer ------------------------------------------------
    def _head_cost(self, mode: str) -> Dict[str, float]:
        model, cfg = self.model, self.cfg
        B = self.B
        S = self.S if mode == "train" else (self.S if mode == "prefill" else 1)
        tok_sds = jax.ShapeDtypeStruct((B, S), jnp.int32)
        tok_sh = jax.sharding.NamedSharding(self.mesh, self.ctx.pspec(("batch", None), (B, S)))
        p_specs = {"embed": model.param_specs()["embed"],
                   "final_norm": model.param_specs()["final_norm"]}
        if "out" in model.param_specs():
            p_specs["out"] = model.param_specs()["out"]
        p_sds = abstract_params(p_specs, cfg.dtype)
        p_sh = _ns(self.mesh, self.ctx.params_pspecs(p_specs))

        from repro.models.layers import rms_norm, softmax_xent

        def head_train(p, tokens, labels):
            x = model._embed_tokens(p, tokens)
            x = rms_norm(x, p["final_norm"], cfg.rms_eps)
            logits = model._logits(p, x)
            return softmax_xent(logits, labels)

        def head_fwd(p, tokens):
            x = model._embed_tokens(p, tokens)
            x = rms_norm(x[:, -1:], p["final_norm"], cfg.rms_eps)
            return model._logits(p, x)

        if mode == "train":
            return _lower_cost(
                jax.grad(head_train), (p_sds, tok_sds, tok_sds),
                (p_sh, tok_sh, tok_sh))
        return _lower_cost(head_fwd, (p_sds, tok_sds), (p_sh, tok_sh))

    def _opt_cost(self) -> Dict[str, float]:
        opt_cfg = OptConfig(m_dtype=self.cfg.optimizer_m_dtype)
        params = self.model.abstract_params()
        state = abstract_adam_state(params, opt_cfg)
        grads = params
        p_sh = _ns(self.mesh, self.model.params_pspecs())
        from repro.train.optimizer import adam_state_pspecs
        s_sh = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(self.mesh, s),
            adam_state_pspecs(self.model.params_pspecs()))

        def step(p, g, s):
            np_, ns, _ = adamw_update(p, g, s, opt_cfg)
            return np_, ns

        return _lower_cost(step, (params, grads, state), (p_sh, p_sh, s_sh))

    # -- public -----------------------------------------------------------
    def account(self) -> Dict[str, float]:
        shape = self.shape
        total = {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
        detail = {}

        def add(name, cost, count):
            detail[name] = {"count": count, **cost}
            for k in total:
                total[k] += cost[k] * count

        if shape.kind == "train":
            for name, specs, L, _ck in self._components():
                specs_only = specs
                fn = self._layer_fn(name, "train")
                S = self.S if name != "enc" else self.model.source_len(self.S)
                if name == "dec":
                    s_src = self.model.source_len(self.S)
                    mem_sds = self._x_sds(self.B, s_src)
                    mem_sh = self._x_shard(self.B, s_src)
                    fn2 = self._layer_fn("dec", "train")
                    x_sds = self._x_sds(self.B, self.S)
                    x_sh = self._x_shard(self.B, self.S)
                    lp_sds = self._layer_param_sds(specs_only)
                    lp_sh = self._layer_param_shardings(specs_only)

                    def train(x, lp, mem):
                        y, _, aux = fn2(x, lp, mem)
                        return y.astype(jnp.float32).sum() + aux

                    def fwd(x, lp, mem):
                        return fn2(x, lp, mem)[0]

                    c_f = _lower_cost(fwd, (x_sds, lp_sds, mem_sds), (x_sh, lp_sh, mem_sh))
                    c_g = _lower_cost(jax.grad(train, argnums=(0, 1, 2)),
                                      (x_sds, lp_sds, mem_sds), (x_sh, lp_sh, mem_sh))
                    cost = {k: c_f[k] + c_g[k] for k in c_f}
                else:
                    cost = self._train_component(fn, specs_only, self.B, S)
                add(f"layer:{name}", cost, L * self.n_micro)
            add("head", self._head_cost("train"), self.n_micro)
            add("optimizer", self._opt_cost(), 1)
        else:
            mode = "prefill" if shape.kind == "prefill" else "decode"
            B = shape.global_batch
            S_x = self.S if mode == "prefill" else 1
            for name, specs, L, ck in self._components():
                if name == "enc":
                    if mode == "decode":
                        continue
                    cost = self._fwd_component(
                        self._layer_fn("enc", "train"), specs,
                        B, self.model.source_len(self.S))
                    add("layer:enc", cost, L)
                    continue
                extra_sds, extra_sh = [], []
                if ck is not None:
                    cs = self._cache_slice_specs(ck, B, self.S)
                    extra_sds.append(abstract_params(cs, self.cfg.dtype))
                    extra_sh.append(_ns(self.mesh, self.ctx.params_pspecs(cs)))
                    if ck in ("kv", "dec") and mode == "decode":
                        pos_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
                        pos_sh = jax.sharding.NamedSharding(
                            self.mesh, self.ctx.pspec(("batch",), (B,)))
                        extra_sds.append(pos_sds)
                        extra_sh.append(pos_sh)
                elif name == "dec" and mode == "prefill":
                    pass
                if name == "dec" and mode == "prefill":
                    # prefill dec layer consumes memory not cache
                    s_src = self.model.source_len(self.S)
                    extra_sds = [self._x_sds(B, s_src)]
                    extra_sh = [self._x_shard(B, s_src)]
                    fn = lambda x, lp, mem: encdec_mod.dec_layer(
                        lp, x, self.cfg, self.ctx, mode="prefill",
                        memory=mem,
                        cache=None, pos=None)
                    # dec prefill needs a cache arg; give it one
                    cs = self._cache_slice_specs("dec", B, self.S)
                    extra_sds.append(abstract_params(cs, self.cfg.dtype))
                    extra_sh.append(_ns(self.mesh, self.ctx.params_pspecs(cs)))
                    fn = lambda x, lp, mem, cache: encdec_mod.dec_layer(
                        lp, x, self.cfg, self.ctx, mode="prefill",
                        memory=mem, cache=cache, pos=None)
                    cost = self._fwd_component(fn, specs, B, S_x, extra_sds, extra_sh)
                else:
                    if ck == "kv" and mode == "prefill":
                        fn = self._layer_fn(name, "prefill")
                        # prefill consumes (cache,) only
                        extra_sds = extra_sds[:1]
                        extra_sh = extra_sh[:1]
                    else:
                        fn = self._layer_fn(name, mode)
                    cost = self._fwd_component(fn, specs, B, S_x, extra_sds, extra_sh)
                add(f"layer:{name}", cost, L)
            add("head", self._head_cost(mode), 1)

        return {"total": total, "detail": detail,
                "n_micro": self.n_micro}


# ---------------------------------------------------------------------------
# analytic ideal memory traffic (per device per step)
#
# ``bytes accessed`` from a CPU-backend compile systematically overestimates
# TPU HBM traffic: the CPU pipeline fuses less (every elementwise op in a
# norm/rope/softmax chain re-reads its operand) and scatter ops are counted
# as full-tensor read+write.  We therefore report BOTH the HLO-derived bound
# and this analytic lower bound assuming perfect fusion:
#   * params streamed once per pass (fwd, remat-fwd, bwd) + optimizer rw
#   * residual-stream tensors: ~12 reads+writes per layer pass
#   * flash attention streams q/k/v twice, never materializes scores
#   * decode streams the KV cache once and writes one slot
# ---------------------------------------------------------------------------
def ideal_bytes_per_device(arch: ArchConfig, shape: ShapeConfig, model, ctx,
                           n_micro: int) -> float:
    cfg = arch
    n_dev = ctx.mesh.devices.size
    dp = ctx.dp_size()
    msz = max(ctx.model_size(), 1)
    P_all = model.n_params()
    P_dev = P_all * 2 / n_dev                       # bf16 weights, fully sharded
    d, L = cfg.d_model, cfg.num_layers
    B_loc = max(shape.global_batch // max(dp, 1), 1)
    V_loc = model.vocab_padded / msz

    if shape.kind == "train":
        B_mloc = max(B_loc // n_micro, 1)
        A = B_mloc * shape.seq_len * d * 2          # residual bf16 (per dev, seq/embed-sharded dims cancel vs gathers; keep full)
        act = 24 * A * L * n_micro                  # 12 rw fwd + 12 rw bwd
        if cfg.d_ff:
            act += 6 * B_mloc * shape.seq_len * (cfg.d_ff / msz) * 2 * L * n_micro
        weights = 3 * P_dev * n_micro               # fwd + remat fwd + bwd
        opt = P_all * 28 / n_dev                    # g rw f32 + m rw + v rw + p rw
        logits = 4 * B_mloc * shape.seq_len * V_loc * 4 * n_micro
        return weights + act + opt + logits

    if shape.kind == "prefill":
        A = B_loc * shape.seq_len * d * 2
        act = 12 * A * L
        weights = P_dev
        kv_write = 0.0
        if cfg.num_kv_heads:
            s_c = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
            kv_write = (2 * B_loc * s_c * cfg.num_kv_heads
                        * (cfg.resolved_head_dim or 0) * 2 * L / msz)
        return weights + act + kv_write

    # decode: weights once + KV/state streamed once + slot write
    kv = 0.0
    cs = model.cache_specs(shape.global_batch, shape.seq_len)
    kv_total = sum(
        np.prod(s.shape) * (2 if (s.dtype or "bf") != "float32" else 4)
        for s in jax.tree.leaves(cs, is_leaf=is_pspec)
    )
    kv = kv_total / n_dev
    act = 30 * shape.global_batch * d * 2 * L / max(dp, 1)
    return P_dev + kv + act


def paged_decode_bytes_per_device(arch: ArchConfig, shape: ShapeConfig, model,
                                  ctx, page_size: int = 16,
                                  kv_elt: int = 2) -> float | None:
    """Analytic HBM traffic for the native paged decode step.

    The dense decode model above streams the whole ``(B, max_len)`` cache
    allocation; the paged kernel instead walks each row's block-table
    entries and streams KV at **page granularity** — ``ceil(kv_len / P)``
    pages per row per attention layer — plus the int32 block-table row and
    per-slot position metadata the kernel prefetches, plus the one slot it
    writes.  Weights and residual-stream activations match the dense
    model.  Returns ``None`` when the paged pool would not engage (no
    pageable KV: ssm/hybrid state, rolling-SWA slot reuse).  ``kv_elt`` is
    the arena element size — pass 1 for an int8 arena (the per-(page,
    layer) scales are counted separately).
    """
    cfg = arch
    w = cfg.sliding_window
    from repro.serve.kvpool import KVPool
    if (shape.kind != "decode" or not cfg.num_kv_heads
            or KVPool.capability(model, page_size * -(-shape.seq_len // page_size),
                                 page_size) != "paged"):
        return None
    n_dev = ctx.mesh.devices.size
    dp = max(ctx.dp_size(), 1)
    P_dev = model.n_params() * 2 / n_dev
    d, L = cfg.d_model, cfg.num_layers
    B = shape.global_batch
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim or 0
    n_attn = L
    pages = -(-shape.seq_len // page_size)
    kv_read = 2 * B * pages * page_size * hkv * dh * kv_elt * n_attn
    meta = B * pages * 4 * n_attn                    # block-table row
    meta += B * pages * page_size * 4 * n_attn       # slot_pos validity
    if kv_elt == 1:
        meta += 2 * B * pages * 4 * n_attn           # k/v per-page scales
    kv_write = 2 * B * hkv * dh * kv_elt * n_attn
    cross = 0.0
    if cfg.family == "encdec":                       # cross memory is dense
        s_src = model.source_len(shape.seq_len)
        cross = 2 * B * s_src * hkv * dh * kv_elt * L
    act = 30 * B * d * 2 * L / dp
    return P_dev + (kv_read + meta + kv_write + cross) / n_dev + act


# ---------------------------------------------------------------------------
# analytic model flops (usefulness ratio)
# ---------------------------------------------------------------------------
def model_flops(arch: ArchConfig, shape: ShapeConfig, model) -> float:
    """6*N_active*T train / 2*N_active*T fwd, + attention context flops."""
    cfg = arch
    n_total = model.n_params()
    n_active = n_total
    if cfg.moe is not None:
        e, k = cfg.moe.num_experts, cfg.moe.top_k
        routed = (cfg.num_layers - cfg.moe.first_dense_layers) * (
            3 * cfg.d_model * cfg.moe.d_expert * e
        )
        n_active = n_total - routed + routed * (k / e)
    T = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    core = mult * n_active * T

    # attention context term
    dh = cfg.resolved_head_dim or 0
    hq = cfg.num_heads
    if cfg.family == "hybrid":
        n_attn = cfg.num_layers // cfg.hybrid_attn_every
    elif cfg.family == "ssm":
        n_attn = 0
    elif cfg.family == "encdec":
        n_attn = cfg.encoder_layers + 2 * cfg.num_layers
    else:
        n_attn = cfg.num_layers
    if n_attn and hq:
        if shape.kind == "decode":
            s_kv = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
            attn = 4 * hq * dh * s_kv * shape.global_batch * n_attn
        else:
            s_kv = shape.seq_len
            w = cfg.sliding_window
            per_q = (min(w, s_kv) if w else s_kv / 2)
            attn = 4 * hq * dh * per_q * shape.global_batch * shape.seq_len * n_attn
            attn *= (3 if shape.kind == "train" else 1)
    else:
        attn = 0.0
    return core + attn


# ---------------------------------------------------------------------------
def roofline_row(arch_name: str, shape_name: str, dryrun_dir: str = "experiments/dryrun",
                 level: str = "optimized") -> dict:
    arch = with_opt_level(get_arch(arch_name), level == "optimized")
    shape = next(s for s in shapes_for(arch) if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=False)
    n_dev = int(mesh.devices.size)
    acc = CellAccountant(arch, shape, mesh)
    out = acc.account()
    tot = out["total"]

    t_compute = tot["flops"] / PEAK_FLOPS_BF16
    t_memory_hlo = tot["bytes"] / HBM_BW
    ideal_b = ideal_bytes_per_device(arch, shape, acc.model, acc.ctx, out["n_micro"])
    t_memory = ideal_b / HBM_BW
    paged_b = paged_decode_bytes_per_device(arch, shape, acc.model, acc.ctx)
    paged_b_int8 = paged_decode_bytes_per_device(
        arch, shape, acc.model, acc.ctx, kv_elt=1)
    t_coll = tot["coll"] / ICI_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = model_flops(arch, shape, acc.model)
    mem = None
    p = os.path.join(dryrun_dir, "single", f"{arch_name}__{shape_name}.json")
    if os.path.exists(p):
        with open(p) as f:
            mem = json.load(f)["memory"]["peak_estimate_bytes"]
    row = {
        "arch": arch_name,
        "shape": shape_name,
        "flops_dev": tot["flops"],
        "bytes_dev_hlo": tot["bytes"],
        "bytes_dev_ideal": ideal_b,
        "bytes_dev_paged": paged_b,
        "bytes_dev_paged_int8": paged_b_int8,
        "t_memory_paged_s": paged_b / HBM_BW if paged_b else None,
        "coll_dev": tot["coll"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_hlo_s": t_memory_hlo,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_total": mf,
        "useful_ratio": mf / max(tot["flops"] * n_dev, 1.0),
        "roofline_fraction": t_compute / max(t_compute, t_memory, t_coll),
        "mem_dev_bytes": mem,
        "detail": out["detail"],
        "n_micro": out["n_micro"],
    }
    return row


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--out", default="experiments/roofline")
    p.add_argument("--level", default="baseline", choices=["baseline", "optimized"])
    args = p.parse_args(argv)
    archs = [args.arch] if args.arch else sorted(ARCHS)
    os.makedirs(args.out, exist_ok=True)
    for a in archs:
        for s in shapes_for(get_arch(a)):
            if args.shape and s.name != args.shape:
                continue
            try:
                row = roofline_row(a, s.name, level=args.level)
            except Exception as e:
                import traceback; traceback.print_exc()
                print(f"[roofline] {a} {s.name} FAILED: {e}")
                continue
            path = os.path.join(args.out, f"{a}__{s.name}__{args.level}.json")
            with open(path, "w") as f:
                json.dump(row, f, indent=1)
            paged = (
                f" Mp={row['t_memory_paged_s']*1e3:9.2f}ms"
                if row.get("t_memory_paged_s") else ""
            )
            print(
                f"[roofline] {a:24s} {s.name:12s} "
                f"C={row['t_compute_s']*1e3:9.2f}ms M={row['t_memory_s']*1e3:9.2f}ms "
                f"(hlo {row['t_memory_hlo_s']*1e3:9.2f}ms) "
                f"X={row['t_collective_s']*1e3:9.2f}ms dom={row['dominant']:10s} "
                f"frac={row['roofline_fraction']:.3f} useful={row['useful_ratio']:.2f}"
                f"{paged}"
            )


if __name__ == "__main__":
    main()
