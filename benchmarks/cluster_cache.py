"""Cluster cache plane benchmark: prefix locality across replicas + drain.

Two claims from the PR 7 tentpole (``repro.serve.cacheplane``), measured:

1. **Prefix-locality routing** — K distinct system prompts served by N
   decode replicas.  A cold wave scatters the prefixes (each replica
   interns a disjoint subset); warm waves then carry one new suffix per
   prefix.  Blind most-free routing would land a warm request on the
   replica holding its prefix ~1/N of the time; digest routing through
   the supervisor-held index sends it where the prefix lives, so the
   AGGREGATE hit rate stays at the single-replica level.
2. **Drain-before-detach** — with ``migrate=True`` a spec-driven
   scale-down (3 -> 2) fires the supervisor drain hook: the victim's hot
   pages and mid-decode slots move to survivors, nothing requeues, and
   the disrupted wave's TTFT tail is indistinguishable from steady state
   (a requeue would re-prefill from scratch and blow the p99).

Reported per phase: TTFT p50/p99, prefix hit rate (phase delta), warm/
cold routing counts, pages migrated, drain handoffs.  ``--smoke`` gates
(CI): multi-replica warm hit rate >= 0.9x single-replica warm hit rate;
scale-down requeues NOTHING (``drain_handoffs`` > 0, ``pages_migrated``
> 0); disrupted-wave TTFT p99 <= 1.3x steady-wave TTFT p99; migrated
prefixes still hit afterwards.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax

from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.core import CellSpec, ChannelSpec, ClusterSpec, DeviceGrid, Supervisor
from repro.serve.batcher import Request
from repro.serve.disagg import DisaggServer

_RID = [0]


def _wave(cfg, prefixes, suffix_len, seed, max_new=6):
    """One request per distinct system prompt, each with a fresh suffix."""
    rng = np.random.RandomState(seed)
    out = []
    for sysp in prefixes:
        tail = rng.randint(1, cfg.vocab, size=suffix_len).astype(np.int32)
        out.append(Request(rid=_RID[0], prompt=np.concatenate([sysp, tail]),
                           max_new_tokens=max_new))
        _RID[0] += 1
    return out


def _phase(srv, reqs, *, mid_wave=None):
    """Run one wave; counters are PHASE DELTAS (the ledgers are
    cumulative).  ``mid_wave`` runs after every request has its first
    token but while decode is still in flight — the scale-down hook."""
    before = srv.stats()
    t0 = time.monotonic()
    for r in reqs:
        srv.submit(r)
    if mid_wave is not None:
        srv.step()
        srv.step()
        mid_wave()
    srv.run_until_drained(max_steps=20_000)
    wall = time.monotonic() - t0
    rids = {r.rid for r in reqs}
    ttfts = sorted(r.ttft for r in srv.done if r.rid in rids)
    assert len(ttfts) == len(reqs), "wave lost requests"
    st = srv.stats()
    hits = st["prefix_hit_tokens"] - before["prefix_hit_tokens"]
    miss = st["prefix_miss_tokens"] - before["prefix_miss_tokens"]
    return {
        "wall_s": wall,
        "ttft_p50": float(np.percentile(ttfts, 50)),
        "ttft_p99": float(np.percentile(ttfts, 99)),
        "hit_rate": hits / max(hits + miss, 1),
        "prefix_hit_tokens": hits,
        "routed_warm": st["routed_warm"] - before["routed_warm"],
        "requeued": st["requeued"] - before["requeued"],
    }


def _server(cfg, n_replicas, *, batch_slots, max_len, chunk, page_size,
            migrate):
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1,
                                cols=1 + n_replicas, allow_reuse=True)
    sup = Supervisor(grid)
    spec = ClusterSpec(
        cells=(CellSpec("prefill", cfg, "serve", ncols=1),
               CellSpec("decode", cfg, "serve", ncols=1,
                        replicas=n_replicas, min_replicas=1,
                        max_replicas=n_replicas)),
        channels=(ChannelSpec("prefill", "decode", kind="kv"),),
    )
    sup.apply(spec)
    first = spec.cell("decode").instances()[0]
    sup.cells[first].init_serve(rng=jax.random.PRNGKey(0))
    srv = DisaggServer(sup, "prefill", spec.cell("decode").instances(),
                       batch_slots=batch_slots, max_len=max_len,
                       chunk=chunk, page_size=page_size, migrate=migrate)
    assert srv.worker is not None and srv.worker.pool is not None, \
        "cluster-cache benchmark needs a shareable cache plane (paged or snapshot)"
    return sup, srv


def run(arch: str = "qwen3-4b", *, max_len: int = 128, chunk: int = 16,
        page_size: int = 16, system_len: int = 96, suffix_len: int = 12,
        n_prefixes: int = 4, batch_slots: int = 4, smoke: bool = False):
    cfg = smoke_config(get_arch(arch))
    if cfg.sliding_window is not None and cfg.sliding_window < max_len:
        cfg = cfg.replace(sliding_window=max_len)
    rng = np.random.RandomState(0)
    prefixes = [rng.randint(1, cfg.vocab, size=system_len).astype(np.int32)
                for _ in range(n_prefixes)]

    # -- baseline: ONE replica holds every prefix; its warm hit rate is
    #    the ceiling the cluster must match
    sup1, srv1 = _server(cfg, 1, batch_slots=batch_slots, max_len=max_len,
                         chunk=chunk, page_size=page_size, migrate=False)
    _phase(srv1, _wave(cfg, prefixes, suffix_len, seed=1))   # compile+cold
    single = _phase(srv1, _wave(cfg, prefixes, suffix_len, seed=2))

    # -- cluster: prefixes scatter across 3 replicas on the cold wave;
    #    warm waves must find them through the supervisor-held index
    sup3, srv3 = _server(cfg, 3, batch_slots=batch_slots, max_len=max_len,
                         chunk=chunk, page_size=page_size, migrate=True)
    _phase(srv3, _wave(cfg, prefixes, suffix_len, seed=1))   # compile+cold
    multi = _phase(srv3, _wave(cfg, prefixes, suffix_len, seed=2))
    steady = _phase(srv3, _wave(cfg, prefixes, suffix_len, seed=3))

    # -- live scale-down mid-wave: drain decode/2 into the survivors
    def shrink():
        sup3.apply(sup3.desired.with_cell(dataclasses.replace(
            sup3.desired.cell("decode"), replicas=2)))
        srv3.sync(sup3.desired)

    disrupted = _phase(srv3, _wave(cfg, prefixes, suffix_len, seed=4),
                       mid_wave=shrink)
    post = _phase(srv3, _wave(cfg, prefixes, suffix_len, seed=5))
    st = srv3.stats()

    rate_ratio = multi["hit_rate"] / max(single["hit_rate"], 1e-9)
    ttft_ratio = disrupted["ttft_p99"] / max(steady["ttft_p99"], 1e-9)
    out = {
        "arch": cfg.name, "max_len": max_len, "page_size": page_size,
        "system_len": system_len, "n_prefixes": n_prefixes,
        "single": single, "multi": multi, "steady": steady,
        "disrupted": disrupted, "post": post,
        "multi_over_single_hit_rate": rate_ratio,
        "disrupted_over_steady_ttft_p99": ttft_ratio,
        "pages_migrated": st["pages_migrated"],
        "drain_handoffs": st["drain_handoffs"],
    }
    print(f"== cluster_cache [{cfg.name}] {n_prefixes} prefixes "
          f"x {system_len} tok, 3 replicas ==")
    for name in ("single", "multi", "steady", "disrupted", "post"):
        p = out[name]
        print(f"  {name:9s} ttft p50 {p['ttft_p50'] * 1e3:8.1f} ms   "
              f"p99 {p['ttft_p99'] * 1e3:8.1f} ms   "
              f"hit rate {p['hit_rate']:.3f}   warm-routed "
              f"{p['routed_warm']}   requeued {p['requeued']}")
    print(f"  aggregate/single hit rate = {rate_ratio:.3f}   "
          f"disrupted/steady ttft p99 = {ttft_ratio:.3f}   "
          f"migrated {st['pages_migrated']} pages, "
          f"{st['drain_handoffs']} slot handoffs")

    if smoke:
        assert single["hit_rate"] > 0, "single-replica warm wave missed"
        assert multi["routed_warm"] > 0, "index routed nothing warm"
        assert rate_ratio >= 0.9, (
            f"aggregate hit rate must be >= 0.9x single-replica, "
            f"got {rate_ratio:.3f}")
        assert st["drain_handoffs"] > 0 and st["pages_migrated"] > 0, \
            "scale-down migrated nothing"
        assert disrupted["requeued"] == 0, \
            "drain-before-detach must not requeue"
        assert ttft_ratio <= 1.3, (
            f"scale-down TTFT p99 must stay <= 1.3x steady, "
            f"got {ttft_ratio:.3f}")
        assert post["prefix_hit_tokens"] > 0, \
            "migrated prefixes stopped hitting after the scale-down"
        print("SMOKE OK")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + the CI acceptance gates")
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--system-len", type=int, default=None)
    ap.add_argument("--n-prefixes", type=int, default=None)
    args = ap.parse_args()
    kw = {}
    if args.smoke:
        kw = dict(max_len=128, system_len=96, suffix_len=12, n_prefixes=4,
                  smoke=True)
    for k in ("max_len", "system_len", "n_prefixes"):
        v = getattr(args, k)
        if v is not None:
            kw[k] = v
    run(args.arch, **kw)


if __name__ == "__main__":
    main()
