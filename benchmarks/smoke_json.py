"""Fold bench-smoke CSV outputs into one machine-readable JSON artifact.

Every benchmark entry point prints ``name,us_per_call,derived`` rows to
stdout; ``make bench-smoke`` captures each run under ``artifacts/`` and
this converter merges them into a single JSON document that CI uploads
as a workflow artifact (alongside the Perfetto demo trace from
``make trace-demo``).

Run:  python benchmarks/smoke_json.py artifacts/*.csv -o artifacts/bench_smoke.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List


def parse_csv(path: str) -> List[dict]:
    """Rows from one captured benchmark log.  Non-row lines (headers,
    progress prints) are skipped: a row is ``name,float,derived``."""
    rows: List[dict] = []
    with open(path) as f:
        for line in f:
            parts = line.rstrip("\n").split(",", 2)
            if len(parts) != 3:
                continue
            name, val, derived = parts
            try:
                us = float(val)
            except ValueError:
                continue
            rows.append({"source": os.path.basename(path), "name": name,
                         "us_per_call": us, "derived": derived})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("csvs", nargs="+", help="captured benchmark CSV logs")
    ap.add_argument("-o", "--out", required=True, help="output JSON path")
    args = ap.parse_args(argv)
    rows: List[dict] = []
    for path in args.csvs:
        rows.extend(parse_csv(path))
    doc = {"schema": "bench-smoke/v1", "n_rows": len(rows), "rows": rows}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"{args.out}: {len(rows)} rows from {len(args.csvs)} logs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
