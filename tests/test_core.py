"""IFTS core units: control plane, guard, elastic policy, accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.simlib import SimCell, SimSupervisor
from repro.core.accounting import CellAccounting, collective_bytes
from repro.core.channels import ChannelError, ControlPlane
from repro.core.elastic import ElasticPolicy, ReconcilePolicy
from repro.core.guard import BoundaryGuard, BoundaryViolation
from repro.core.spec import CellSpec, ClusterSpec


# ---------------------------------------------------------------------------
# control plane (FICM analogue)
# ---------------------------------------------------------------------------
def test_control_plane_unicast_multicast_broadcast():
    cp = ControlPlane()
    for n in ("sup", "a", "b", "c"):
        cp.register(n)
    cp.unicast("sup", "a", "resize", {"ncols": 3})
    m = cp.poll("a")
    assert m.kind == "resize" and m.payload["ncols"] == 3 and m.src == "sup"
    assert cp.poll("a") is None

    cp.multicast("sup", ["a", "b"], "ping")
    assert cp.poll("a").kind == "ping" and cp.poll("b").kind == "ping"
    assert cp.poll("c") is None

    cp.broadcast("a", "hello")
    assert {n for n in ("sup", "b", "c") if cp.poll(n)} == {"sup", "b", "c"}
    assert cp.poll("a") is None          # no self-delivery

    with pytest.raises(ChannelError):
        cp.unicast("sup", "ghost", "x")

    cp.unregister("b")
    with pytest.raises(ChannelError):
        cp.unicast("sup", "b", "x")


# ---------------------------------------------------------------------------
# collective-bytes HLO parser
# ---------------------------------------------------------------------------
SAMPLE_HLO = """
  %ag = bf16[8,128]{1,0} all-gather(%p0), replica_groups={{0,1}}, dimensions={0}
  %ar.1 = f32[1024]{0} all-reduce(%x), to_apply=%add
  %rs = bf16[4,64]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = s32[16]{0} all-to-all(%z), dimensions={0}
  %cp = bf16[2,2]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %ars = (f32[512]{0}, f32[512]{0}) all-reduce-start(%v), to_apply=%add
  %ard = f32[512]{0} all-reduce-done(%ars)
"""


def test_collective_bytes_parser():
    out = collective_bytes(SAMPLE_HLO)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 1024 * 4 + 2 * 512 * 4   # start counted once
    assert out["reduce-scatter"] == 4 * 64 * 2
    assert out["all-to-all"] == 16 * 4
    assert out["collective-permute"] == 2 * 2 * 2


def test_collective_bytes_on_real_compile():
    """A single-device program has no collectives."""
    f = jax.jit(lambda x: (x @ x).sum())
    hlo = f.lower(jnp.ones((8, 8))).compile().as_text()
    assert collective_bytes(hlo) == {}


def test_accounting_totals():
    acc = CellAccounting("c")
    f = jax.jit(lambda x: (x @ x).sum())
    compiled = f.lower(jnp.ones((16, 16))).compile()
    pc = acc.register_program("step", compiled)
    assert pc.flops_per_device > 0
    acc.record_invocation("step", 10)
    t = acc.totals()
    assert t["flops"] == pc.flops_per_device * 10


# ---------------------------------------------------------------------------
# boundary guard
# ---------------------------------------------------------------------------
class _FakeSharding:
    def __init__(self, ids):
        self.mesh = type("M", (), {"devices": np.array(
            [type("D", (), {"id": i})() for i in ids], dtype=object)})()


class _FakeCompiled:
    def __init__(self, ids):
        self.input_shardings = ([_FakeSharding(ids)], {})
        self.output_shardings = [_FakeSharding(ids)]


def test_guard_accepts_confined_executable():
    g = BoundaryGuard(lambda: None)
    g.validate_devices(_FakeCompiled([0, 1, 2]), [0, 1, 2, 3], "cell")


def test_guard_rejects_out_of_zone_executable():
    g = BoundaryGuard(lambda: None)
    with pytest.raises(BoundaryViolation):
        g.validate_devices(_FakeCompiled([0, 7]), [0, 1, 2, 3], "cell")


def test_guard_rejects_stale_epoch():
    class Cell:
        name = "c"
        bound_epoch = 3
        zone_epoch = 5     # zone changed since compile
        mesh = type("M", (), {"devices": np.array([], dtype=object)})()

    g = BoundaryGuard(lambda: None)
    with pytest.raises(BoundaryViolation):
        g.validate(Cell(), _FakeCompiled([]))


# ---------------------------------------------------------------------------
# elastic reconcile policy (spec-driven, fed by CellAccounting)
# ---------------------------------------------------------------------------
def _mock_sup():
    return SimSupervisor(SimCell("srv", 2, "serve"),
                         SimCell("don", 4, "train"))


def _mock_spec():
    return ClusterSpec(cells=(
        CellSpec("srv", None, "serve", ncols=2, min_ncols=1, max_ncols=6),
        CellSpec("don", None, "train", ncols=4, min_ncols=1, max_ncols=6),
    ))


def test_reconcile_policy_grow_shrink_cooldown():
    sup = _mock_sup()
    assert sup.apply(_mock_spec()).empty          # observed matches desired
    sched = ReconcilePolicy(
        sup, "srv", "don",
        ElasticPolicy(lt=0.1, ut=0.2, window=10, cooldown=100.0),
    )
    for _ in range(10):
        sched.observe(0.5)                       # way above ut
    act = sched.maybe_act(now=0.0)
    assert act and act["kind"] == "grow_server"
    # the policy rewrote the spec; the reconciler executed one transfer
    assert sup.desired.cell("srv").ncols == 3
    assert sup.desired.cell("don").ncols == 3
    assert sup.log == [("transfer", "don", "srv", 1)]
    assert sup.cells["srv"].zone.ncols == 3

    for _ in range(10):
        sched.observe(0.5)
    assert sched.maybe_act(now=50.0) is None     # cooldown holds

    for _ in range(10):
        sched.observe(0.01)                      # below lt
    act = sched.maybe_act(now=200.0)
    assert act and act["kind"] == "shrink_server"
    assert sup.cells["srv"].zone.ncols == 2

    # respect the spec's min_ncols: pin srv at 1 and try to shrink below
    sup.apply(sup.desired.scale("srv", 1).scale("don", 5))
    for _ in range(10):
        sched.observe(0.01)
    assert sched.maybe_act(now=400.0) is None
    assert sup.cells["srv"].zone.ncols == 1


def test_reconcile_policy_conserves_columns_with_replicas():
    """Regression: growing a replicated server by 1 col/replica must take
    exactly replicas * 1 columns from the donor — or do nothing at all."""
    sup = SimSupervisor(SimCell("dec/0", 1, "serve"),
                        SimCell("dec/1", 1, "serve"),
                        SimCell("don", 4, "train"))
    spec = ClusterSpec(cells=(
        CellSpec("dec", None, "serve", ncols=1, min_ncols=1, max_ncols=3,
                 replicas=2),
        CellSpec("don", None, "train", ncols=4, min_ncols=1, max_ncols=6),
    ))
    assert sup.apply(spec).empty
    sched = ReconcilePolicy(
        sup, "dec", "don",
        ElasticPolicy(lt=0.1, ut=0.2, window=10, cooldown=0.0),
    )
    for _ in range(10):
        sched.observe(0.5)
    act = sched.maybe_act(now=0.0)
    assert act and act["kind"] == "grow_server"
    # 2 replicas x +1 col, donor funded both: 1+1+4 == 2+2+2
    assert sup.desired.cell("dec").ncols == 2
    assert sup.desired.cell("don").ncols == 2
    assert [c.zone.ncols for c in sup.cells.values()] == [2, 2, 2]
    assert sup.reconcile().empty

    # donor too small to fund a whole replica set: no action, spec untouched
    for _ in range(10):
        sched.observe(0.5)
    before = sup.desired
    assert sched.maybe_act(now=10.0) is None     # don at 2, min 1: can give 1 < 2
    assert sup.desired is before


def test_reconcile_policy_pulls_live_accounting():
    """The policy reads TTFT samples straight out of CellAccounting —
    no manual observe() feed."""
    sup = _mock_sup()
    sup.apply(_mock_spec())
    sched = ReconcilePolicy(
        sup, "srv", "don",
        ElasticPolicy(lt=0.1, ut=0.2, window=10, cooldown=0.0, metric="ttft"),
    )
    acc = sup.cells["srv"].accounting
    for rid in range(10):
        acc.record_request(rid, ttft=0.5, tpot=0.01)
    act = sched.maybe_act(now=0.0)
    assert act and act["kind"] == "grow_server"
    # samples were consumed exactly once (cursor advanced)
    assert sched.pull() == 0
    # a recovered cell restarts with a fresh, shorter log: its samples
    # must be read from the beginning, not skipped past the stale cursor
    sup.cells["srv"].accounting = CellAccounting("srv")
    sup.cells["srv"].accounting.record_request(0, ttft=0.7)
    assert sched.pull() == 1
    # tpot metric path reads the other field
    sched2 = ReconcilePolicy(
        sup, "srv", "don",
        ElasticPolicy(lt=0.1, ut=0.2, window=10, cooldown=0.0, metric="tpot"),
    )
    sched2.pull()
    assert all(v == 0.01 for v in sched2.samples)
