"""Deterministic snapshot cache-plane tests (no hypothesis dep).

The payload-polymorphic ``KVPool`` plane for recurrent families
(ssm/hybrid): the capability gate, eviction reaping of interned
payloads, and the headline exactness guarantee — a warm request whose
prefix is restored from an interned chunk-boundary snapshot chain (and
whose suffix is prefill-extended) decodes TOKEN-IDENTICALLY to a cold
run of the same prompt, colocated and disaggregated.  The randomized
tree/migration invariants live in ``test_snapshot_properties.py``.
"""
import numpy as np
import pytest

import jax

from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.models.model import build_model
from repro.serve.batcher import ContinuousBatcher, Request
from repro.serve.kvpool import KVPool
from repro.sharding.rules import single_device_ctx

MAX_LEN = 32
PAGE = 8
SNAP_ARCHS = ["mamba2-2.7b", "zamba2-2.7b"]

_CACHE = {}


def _model(name):
    if name not in _CACHE:
        cfg = smoke_config(get_arch(name))
        model = build_model(cfg, single_device_ctx())
        _CACHE[name] = (model, model.init(jax.random.PRNGKey(0)))
    return _CACHE[name]


def _payloads(tag, n):
    """n fake chunk payloads with distinguishable states — the pool
    never inspects payload contents, only stores/returns them."""
    return [{"state": np.asarray([tag, lp], np.int64), "pages": []}
            for lp in range(n)]


def test_capability_three_way():
    """``KVPool.capability`` is the single payload gate: paged for
    attention KV, snapshot for ssm/hybrid, none for misaligned configs."""
    model, _ = _model("mamba2-2.7b")
    assert KVPool.capability(model, MAX_LEN, PAGE) == "snapshot"
    assert KVPool.capability(model, MAX_LEN + 1, PAGE) == "none"
    paged, _ = _model("qwen3-4b")
    assert KVPool.capability(paged, MAX_LEN, PAGE) == "paged"


def test_snapshot_eviction_reaps_payloads():
    """Handle pressure evicts refs-0 leaves AND their payloads: the
    ``_snaps`` map never orphans an entry, occupancy never exceeds the
    handle supply, and a live (leased) chain survives the squeeze."""
    model, _ = _model("mamba2-2.7b")
    pool = KVPool(model, max_len=MAX_LEN, page_size=PAGE, slots=0,
                  num_pages=4)
    a = np.asarray([1] * MAX_LEN, np.int32)
    pool.intern_snapshots(a, None, _payloads(0, MAX_LEN // PAGE))
    lease = pool.lease(a, None)
    assert len(lease.nodes) == (MAX_LEN - 1) // PAGE  # pinned below
    # a second full chain cannot fit: only the unpinned tail is evictable
    b = np.asarray([2] * MAX_LEN, np.int32)
    pool.intern_snapshots(b, None, _payloads(1, MAX_LEN // PAGE))
    assert pool.pages_in_use <= pool.num_pages
    assert set(n.page for n in pool.tree._walk()) == set(pool._snaps)
    # the leased chain is untouched and still materializes
    state, stacks = pool.snapshot_chain(lease)
    assert stacks == []
    assert np.array_equal(state, np.asarray([0, len(lease.nodes) - 1],
                                            np.int64))
    pool.release_lease(lease)
    assert all(n.refs == 0 for n in pool.tree._walk())


# ---------------------------------------------------------------------------
# end-to-end exactness: warm restored decode == cold decode
# ---------------------------------------------------------------------------
E2E_LEN = 64
E2E_CHUNK = 8


def _e2e_prompts(cfg):
    rng = np.random.RandomState(0)
    sysp = rng.randint(1, cfg.vocab, size=40).astype(np.int32)
    t1 = rng.randint(1, cfg.vocab, size=5).astype(np.int32)
    t2 = rng.randint(1, cfg.vocab, size=7).astype(np.int32)
    return np.concatenate([sysp, t1]), np.concatenate([sysp, t2])


@pytest.mark.parametrize("arch", SNAP_ARCHS)
def test_snapshot_restore_exact_colocated(arch):
    """A warm request (prefix restored from an interned snapshot chain,
    suffix prefill-extended) decodes token-identically to a cold run of
    the same prompt, and the lease's pins return to 0 after drain."""
    model, params = _model(arch)
    p1, p2 = _e2e_prompts(model.cfg)

    def run(prompts, fresh_each=False):
        out = {}
        bat = None
        for i, p in enumerate(prompts):
            if bat is None or fresh_each:
                bat = ContinuousBatcher(model, params, batch_slots=2,
                                        max_len=E2E_LEN,
                                        prefill_chunk=E2E_CHUNK,
                                        page_size=PAGE)
                assert bat.pool is not None
                assert bat.pool.payload_kind == "snapshot"
            bat.submit(Request(rid=i, prompt=p, max_new_tokens=4))
            for r in bat.run_until_drained():
                out[r.rid] = r.output
        return out, bat

    cold, _ = run([p1, p2], fresh_each=True)        # independent servers
    warm, bat = run([p1, p2])                       # p2 hits p1's chain
    assert warm == cold
    st = bat.pool.stats()
    assert st["snapshot_hit_tokens"] > 0 and st["snapshot_bytes_saved"] > 0
    assert all(n.refs == 0 for n in bat.pool.tree._walk())


@pytest.mark.parametrize("arch", SNAP_ARCHS)
def test_snapshot_restore_exact_disagg(arch):
    """Disaggregated twin of the colocated exactness test: the warm
    prefill->decode handoff (one dense row, chain elided) decodes
    token-identically to cold, and the decode-side pool records the
    snapshot hit."""
    from repro.core import DeviceGrid, Supervisor
    from repro.serve.disagg import DisaggServer

    model, _ = _model(arch)
    cfg = model.cfg
    p1, p2 = _e2e_prompts(cfg)

    def srv_new():
        grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1,
                                    cols=2, allow_reuse=True)
        sup = Supervisor(grid)
        sup.create_cell("prefill", cfg, "serve", ncols=1)
        dec = sup.create_cell("decode", cfg, "serve", ncols=1)
        dec.init_serve(rng=jax.random.PRNGKey(0))
        return DisaggServer(sup, "prefill", "decode", batch_slots=2,
                            max_len=E2E_LEN, chunk=E2E_CHUNK,
                            page_size=PAGE)

    def run(srv, prompts, rid0=0):
        for i, p in enumerate(prompts):
            srv.submit(Request(rid=rid0 + i, prompt=p, max_new_tokens=4))
        return {r.rid: r.output for r in srv.run_until_drained()}

    ref1 = run(srv_new(), [p1])[0]
    ref2 = run(srv_new(), [p2])[0]
    srv = srv_new()
    assert run(srv, [p1])[0] == ref1                # cold
    assert run(srv, [p2], rid0=1)[1] == ref2        # warm, same prefix
    st = srv.stats()
    assert st["snapshot_hit_tokens"] > 0 and st["snapshot_bytes_saved"] > 0
