"""Model-level attention: chunked-jnp baseline vs naive, masks, decode."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import (
    chunked_attention,
    decode_attention_ref,
)


def naive(q, k, v, causal=True, window=None):
    B, Sq, Hq, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kf = jnp.repeat(k, G, axis=2)
    vf = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kf.astype(jnp.float32))
    s = s / math.sqrt(Dh)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    m = jnp.ones((Sq, Skv), bool)
    if causal:
        m &= qp >= kp
    if window is not None:
        m &= kp > qp - window
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("causal,window,qc,kc", [
    (True, None, 32, 32),
    (True, None, 128, 16),
    (True, 24, 16, 16),
    (False, None, 32, 64),
])
def test_chunked_attention_vs_naive(causal, window, qc, kc):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            q_chunk=qc, kv_chunk=kc)
    ref = naive(q, k, v, causal, window)
    rel = float(jnp.abs(out - ref).max() / jnp.abs(ref).max())
    assert rel < 1e-5, rel


def test_chunked_attention_unroll_equals_scan():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    a = chunked_attention(q, k, v, q_chunk=16, kv_chunk=16, unroll=False)
    b = chunked_attention(q, k, v, q_chunk=16, kv_chunk=16, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_decode_ref_respects_rolling_slot_positions():
    """SWA rolling buffer: only positions within the window attend."""
    B, S, H, Dh = 1, 8, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, 1, H, Dh))
    k = jax.random.normal(ks[1], (B, S, H, Dh))
    v = jax.random.normal(ks[2], (B, S, H, Dh))
    # slots hold absolute positions 8..15 (a full rolling window of 8)
    slot_pos = jnp.arange(8, 16)[None, :]
    kv_len = jnp.array([16])
    out_win = decode_attention_ref(q, k, v, kv_len, window=4, slot_pos=slot_pos)
    # mask manually: positions > 15-4=11 -> slots 4..7
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) / 4.0
    s = jnp.where((slot_pos > 11)[:, None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v.astype(jnp.float32))
    rel = float(jnp.abs(out_win - ref).max() / jnp.abs(ref).max())
    assert rel < 1e-5
