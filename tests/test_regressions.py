"""Regression pins for the seed-suite failure clusters.

Each test pins one of the version-compat / correctness bugs fixed alongside
the disaggregated-serving PR so they cannot silently reappear:
  * Pallas TPU compiler-params rename (CompilerParams vs TPUCompilerParams)
  * ``cost_analysis()`` returning a per-device list on older jax
  * ``jax.sharding.AxisType`` absent on older jax (mesh construction)
  * ``ArrayChannel.map`` silently allowing disjoint-device zero-copy
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp


def test_tpu_compiler_params_resolves_on_this_jax():
    from jax.experimental.pallas import tpu as pltpu

    from repro.kernels._compat import tpu_compiler_params

    cp = tpu_compiler_params(dimension_semantics=("parallel", "arbitrary"))
    expected = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    assert isinstance(cp, expected)


def test_kernels_run_under_interpret_mode():
    """The four kernels construct their compiler params through the shim;
    one representative call proves the pallas_call wiring still works."""
    from repro.kernels.flash_attention import flash_attention

    q = jnp.zeros((1, 8, 1, 8), jnp.float32)      # (B, S, H, Dh)
    out = flash_attention(q, q, q, block_q=8, block_k=8)
    assert out.shape == q.shape


def test_cost_analysis_list_and_dict_normalized():
    from repro.core.accounting import CellAccounting, _normalize_cost_analysis

    assert _normalize_cost_analysis(None) == {}
    assert _normalize_cost_analysis([]) == {}
    assert _normalize_cost_analysis({"flops": 5.0}) == {"flops": 5.0}
    assert _normalize_cost_analysis([{"flops": 5.0}]) == {"flops": 5.0}

    class FakeCompiled:
        def cost_analysis(self):
            return [{"flops": 7.0, "bytes accessed": 3.0}]   # per-device list

        def memory_analysis(self):
            return None

        def as_text(self):
            return ""

    pc = CellAccounting("c").register_program("p", FakeCompiled())
    assert pc.flops_per_device == 7.0 and pc.bytes_per_device == 3.0


def test_cell_accounting_is_exact_after_training():
    """The old ``try/except: pass`` around register_program hid the crash
    and silently disabled exact accounting; now training must register the
    step program's real cost."""
    from repro.configs.base import ShapeConfig, smoke_config
    from repro.configs.registry import get_arch
    from repro.core import DeviceGrid, Supervisor
    from repro.data.pipeline import DataConfig, SyntheticPipeline

    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=1)
    sup = Supervisor(grid)
    arch = smoke_config(get_arch("qwen3-4b"))
    cell = sup.create_cell("t", arch, "train", ncols=1)
    pipe = SyntheticPipeline(DataConfig(kind="bigram", vocab=arch.vocab), arch,
                             ShapeConfig("t", "train", 2, 16))
    cell.train_steps(pipe.get_batch, 2)
    pc = cell.accounting.programs["train_step"]
    assert pc.flops_per_device > 0 and pc.invocations == 2
    assert cell.accounting.totals()["flops"] > 0


def test_mesh_helpers_work_without_axis_type():
    """mesh.py must construct meshes whether or not jax.sharding.AxisType
    exists (it is absent on jax 0.4.x)."""
    from repro.launch.mesh import _axis_types_kwargs, make_mesh_for_devices

    kw = _axis_types_kwargs(2)
    if hasattr(jax.sharding, "AxisType"):
        assert kw == {"axis_types": (jax.sharding.AxisType.Auto,) * 2}
    else:
        assert kw == {}
    mesh = make_mesh_for_devices(1, 1)
    assert mesh.axis_names == ("data", "model")


def test_channel_map_requires_shared_devices():
    from repro.core.channels import ArrayChannel, ChannelError

    class FakeCell:
        def __init__(self, devices):
            self.mesh = type("M", (), {"devices": np.array(devices, dtype=object)})()

    d0, d1 = object(), object()
    shared = ArrayChannel(FakeCell([d0]), FakeCell([d0]))
    assert shared.map({"x": 1})["zero_copy"]
    assert shared.recv() == {"x": 1}

    disjoint = ArrayChannel(FakeCell([d0]), FakeCell([d1]))
    with pytest.raises(ChannelError):
        disjoint.map({"x": 1})


def test_collection_never_aborts_on_missing_hypothesis():
    """test_partition / test_train importorskip hypothesis instead of
    crashing collection (which killed the whole tier-1 -x run)."""
    import ast
    import os

    here = os.path.dirname(__file__)
    for mod in ("test_partition.py", "test_train.py"):
        src = open(os.path.join(here, mod)).read()
        tree = ast.parse(src)
        calls = [
            n for n in ast.walk(tree)
            if isinstance(n, ast.Call)
            and isinstance(n.func, ast.Attribute)
            and n.func.attr == "importorskip"
        ]
        assert calls, f"{mod} must importorskip hypothesis"
