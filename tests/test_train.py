"""Training substrate: optimizer math, grad accumulation, compression."""
import pytest

pytest.importorskip("hypothesis")  # keep collection alive without the dep

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.configs.base import ShapeConfig, smoke_config
from repro.configs.registry import get_arch
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.models.model import build_model
from repro.sharding.rules import single_device_ctx
from repro.train import grad_compress
from repro.train.optimizer import OptConfig, adamw_update, init_adam_state
from repro.train.train_step import (
    build_train_step,
    init_train_state,
    resolve_microbatch,
)


def _tiny_model():
    cfg = smoke_config(get_arch("qwen3-4b")).replace(
        num_layers=2, d_model=64, d_ff=128, num_heads=2, num_kv_heads=1,
        head_dim=32, vocab=128)
    return cfg, build_model(cfg, single_device_ctx())


def test_adamw_matches_numpy_reference():
    """One AdamW step vs a straight numpy implementation."""
    cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10**9,
                    weight_decay=0.1, grad_clip=1e9, min_lr_ratio=1.0)
    p = {"w": jnp.array([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
    g = {"w": jnp.array([[0.1, 0.2], [-0.3, 0.4]], jnp.float32)}
    st_ = init_adam_state(p, cfg)
    new_p, new_st, m = adamw_update(p, g, st_, cfg)

    gw = np.asarray(g["w"])
    m1 = 0.1 * gw
    v1 = 0.05 * gw**2
    mh = m1 / (1 - 0.9)
    vh = v1 / (1 - 0.95)
    delta = mh / (np.sqrt(vh) + cfg.eps) + 0.1 * np.asarray(p["w"])
    ref = np.asarray(p["w"]) - 1e-2 * delta
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5)
    assert int(new_st.step) == 1


def test_grad_clip():
    from repro.train.optimizer import clip_by_global_norm
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


def test_microbatch_equivalence():
    """mb=1 and mb=4 produce (nearly) the same training trajectory."""
    cfg, model = _tiny_model()
    opt = OptConfig(lr=1e-3, warmup_steps=0, total_steps=100)
    shape = ShapeConfig("t", "train", 16, 8)
    pipe = SyntheticPipeline(DataConfig(kind="bigram", vocab=64), cfg, shape)

    losses = {}
    for mb in (1, 4):
        m2 = build_model(cfg.replace(microbatch=mb), single_device_ctx())
        state = init_train_state(m2, jax.random.PRNGKey(0), opt)
        step = jax.jit(build_train_step(m2, opt))
        for i in range(3):
            state, metrics = step(state, pipe.get_batch(i))
        losses[mb] = float(metrics["xent"])
    # bf16 params: accumulation-order effects allow small drift
    assert abs(losses[1] - losses[4]) < 0.05, losses


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 64), st.integers(1, 512), st.integers(1, 64))
def test_resolve_microbatch_properties(want, B, dp):
    n = resolve_microbatch(want, B, dp)
    assert 1 <= n <= max(want, 1)
    assert B % n == 0
    if B % dp == 0:
        assert (B // n) % dp == 0


def test_compressed_psum_with_error_feedback_converges():
    """EF compression: single-step error is bounded; the EF buffer carries
    the residual so the *sum over steps* stays unbiased."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    ef = jnp.zeros_like(x)
    total_c = jnp.zeros_like(x)
    total_t = jnp.zeros_like(x)
    for i in range(20):
        g = x * (1 + 0.1 * i)
        q, s = grad_compress.quantize_int8(g + ef)
        deq = grad_compress.dequantize_int8(q, s)
        ef = (g + ef) - deq
        total_c = total_c + deq
        total_t = total_t + g
    # the unreduced residual is exactly `ef`
    np.testing.assert_allclose(
        np.asarray(total_c + ef), np.asarray(total_t), rtol=1e-4, atol=1e-4)


def test_train_step_with_compression_learns():
    cfg, model = _tiny_model()
    opt = OptConfig(lr=3e-3, warmup_steps=5, total_steps=200)
    shape = ShapeConfig("t", "train", 16, 16)
    pipe = SyntheticPipeline(DataConfig(kind="bigram", vocab=64), cfg, shape)
    state = init_train_state(model, jax.random.PRNGKey(0), opt, compress=True)
    step = jax.jit(build_train_step(model, opt, compress=True), donate_argnums=(0,))
    first = last = None
    for i in range(40):
        state, m = step(state, pipe.get_batch(i))
        if first is None:
            first = float(m["xent"])
        last = float(m["xent"])
    assert last < first - 0.5, (first, last)


def test_determinism():
    cfg, model = _tiny_model()
    opt = OptConfig()
    shape = ShapeConfig("t", "train", 16, 4)
    pipe = SyntheticPipeline(DataConfig(kind="bigram", vocab=64), cfg, shape)
    outs = []
    for _ in range(2):
        state = init_train_state(model, jax.random.PRNGKey(0), opt)
        step = jax.jit(build_train_step(model, opt))
        for i in range(2):
            state, m = step(state, pipe.get_batch(i))
        outs.append(float(m["xent"]))
    assert outs[0] == outs[1]
