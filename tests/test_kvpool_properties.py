"""Property-based (hypothesis) invariants for the paged KV cache plane.

  * page-indexed gather/scatter roundtrips across dense / moe / encdec
    cache layouts — ``write_arena_pages`` / ``read_arena_pages`` /
    ``extract_row_pages`` / ``load_pages_into_row`` are mutually inverse;
  * PrefixTree intern/lookup/evict invariants over random op sequences —
    refcounts never negative, matches are exact full-chunk prefixes,
    eviction only reclaims refcount-0 leaves, page ids never duplicate.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # keep collection alive without the dep

import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import smoke_config  # noqa: E402
from repro.configs.registry import get_arch  # noqa: E402
from repro.models.cache_utils import (  # noqa: E402
    extract_row_pages,
    kv_node_axes,
    load_pages_into_row,
    page_arena,
    read_arena_pages,
    write_arena_pages,
)
from repro.models.model import build_model  # noqa: E402
from repro.serve.kvpool import PrefixTree  # noqa: E402
from repro.sharding.rules import single_device_ctx  # noqa: E402

MAX_LEN = 32
PAGE = 8
N_LOG = MAX_LEN // PAGE
FAMILY_ARCHS = ["qwen3-4b", "mixtral-8x7b", "seamless-m4t-large-v2"]

_CACHE = {}


def _model(name):
    if name not in _CACHE:
        cfg = smoke_config(get_arch(name))
        if cfg.sliding_window is not None and cfg.sliding_window < MAX_LEN:
            cfg = cfg.replace(sliding_window=64)
        model = build_model(cfg, single_device_ctx())
        _CACHE[name] = (model, model.init(jax.random.PRNGKey(0)))
    return _CACHE[name]


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_page_roundtrip_property(arch, data):
    """write_arena_pages / read_arena_pages / extract_row_pages /
    load_pages_into_row are mutually inverse for every family's cache
    layout (layer-stacked, moe-split, encdec DecCache)."""
    model, _ = _model(arch)
    num_pages = 6
    arena = page_arena(model, num_pages, PAGE)
    axes = kv_node_axes(model, 1, MAX_LEN)
    cache = model.init_cache(2, MAX_LEN)
    # fill a row with recognizable values
    row = data.draw(st.integers(0, 1), label="row")
    seed = data.draw(st.integers(0, 2**16), label="seed")
    rng = np.random.RandomState(seed)
    cache = jax.tree.map(
        lambda x: jax.numpy.asarray(
            rng.standard_normal(x.shape).astype(np.float32)).astype(x.dtype),
        cache)
    start = data.draw(st.integers(0, N_LOG - 1), label="start")
    n = data.draw(st.integers(1, N_LOG - start), label="n")
    stacks = extract_row_pages(cache, axes, row, start, n, PAGE)
    ids = data.draw(
        st.lists(st.integers(0, num_pages - 1), min_size=n, max_size=n,
                 unique=True), label="ids")
    arena = write_arena_pages(arena, ids, stacks)
    back = read_arena_pages(arena, ids)
    for s, b in zip(stacks, back):
        for leaf_s, leaf_b in zip(s, b):
            assert np.array_equal(np.asarray(leaf_s, np.float32),
                                  np.asarray(leaf_b, np.float32))
    # loading those pages into the other row reproduces the source slice
    other = 1 - row
    cache2 = load_pages_into_row(cache, model.cache_specs(1, MAX_LEN), axes,
                                 other, back, start, PAGE)
    got = extract_row_pages(cache2, axes, other, start, n, PAGE)
    for s, g in zip(stacks, got):
        for leaf_s, leaf_g in zip(s, g):
            assert np.array_equal(np.asarray(leaf_s, np.float32),
                                  np.asarray(leaf_g, np.float32))




@settings(max_examples=200, deadline=None)
@given(data=st.data())
def test_prefix_tree_invariants(data):
    """Intern/match/acquire/release/evict over random prompts from a tiny
    alphabet (maximal prefix collisions): refcounts never go negative,
    match always returns the longest exact full-chunk prefix, eviction
    only reclaims refcount-0 leaves, and page ids are never duplicated."""
    P = 4
    tree = PrefixTree(P)
    next_page = [0]
    live_pages = set()
    leased = []

    def intern(tokens):
        parent = tree.root(None)
        for lp in range(len(tokens) // P):
            key = tuple(tokens[lp * P:(lp + 1) * P])
            node = parent.children.get(key)
            if node is None:
                node = tree.insert(parent, key, next_page[0])
                live_pages.add(next_page[0])
                next_page[0] += 1
            parent = node

    for _ in range(data.draw(st.integers(1, 30), label="ops")):
        op = data.draw(st.sampled_from(["intern", "match", "lease",
                                        "release", "evict"]), label="op")
        tokens = data.draw(st.lists(st.integers(0, 2), min_size=0,
                                    max_size=14), label="tokens")
        if op == "intern":
            intern(tokens)
        elif op == "match":
            nodes = tree.match(np.asarray(tokens, np.int32), None)
            # exact full-chunk prefix; capped to leave >= 1 suffix token
            assert len(nodes) <= max(len(tokens) - 1, 0) // P
            for lp, n in enumerate(nodes):
                assert n.key == tuple(tokens[lp * P:(lp + 1) * P])
                assert n.refs >= 0
        elif op == "lease":
            nodes = tree.match(np.asarray(tokens, np.int32), None)
            tree.acquire(nodes)
            leased.append(nodes)
        elif op == "release" and leased:
            tree.release(leased.pop())
        elif op == "evict":
            out = tree.evict_lru()
            if out is not None:
                node, page = out
                assert node.refs == 0 and not node.children
                live_pages.discard(page)
    # global invariants
    pages = [n.page for n in tree._walk()]
    assert len(pages) == len(set(pages)) == tree.interned
    assert all(n.refs >= 0 for n in tree._walk())
    pinned = sum(n.refs for n in tree._walk())
    assert pinned == sum(len(ns) for ns in leased)
    # releasing everything makes the whole tree evictable
    for ns in leased:
        tree.release(ns)
    assert tree.evictable_pages() == tree.interned


# ---------------------------------------------------------------------------
# cluster cache plane: export/import round-trips the tree AND the pages
# ---------------------------------------------------------------------------
def _tree_paths(pool):
    """Canonical view of a pool's interned state: (ctx_key, key-path) ->
    node.  Paths, not record sequences — export order is DFS-stack, so a
    round-trip legitimately reorders siblings."""
    out = {}
    for ck, root in pool.tree._roots.items():
        stack = [(root, ())]
        while stack:
            node, path = stack.pop()
            for key, child in node.children.items():
                p = path + (key,)
                out[(ck, p)] = child
                stack.append((child, p))
    return out


def _page_data(pool, node):
    return [np.asarray(s.k, np.float32)
            for s in pool.read_pages(jax.numpy.asarray([node.page]))]


@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_export_import_subtree_roundtrip(data):
    """``KVPool.export_subtree`` / ``import_subtree`` (the migration
    path) round-trip exactly: the destination reproduces the source's
    key-paths, owners and page DATA; imported nodes arrive refs-0
    (reclaimable cache); the source is untouched; re-import is
    idempotent; and a too-small destination degrades best-effort without
    breaking tree invariants."""
    from repro.serve.kvpool import KVPool

    model, _ = _model("qwen3-4b")
    cfg = model.cfg
    src = KVPool(model, max_len=MAX_LEN, page_size=PAGE, slots=2)
    seed = data.draw(st.integers(0, 2**16), label="seed")
    rng = np.random.RandomState(seed)
    ctx_keys = [None, ("tenant", "a")]
    for _ in range(data.draw(st.integers(1, 4), label="prompts")):
        n_tok = data.draw(st.integers(PAGE + 1, MAX_LEN - 1), label="len")
        # tiny alphabet -> maximal prefix collisions across prompts
        prompt = np.asarray(data.draw(
            st.lists(st.integers(1, 3), min_size=n_tok, max_size=n_tok),
            label="prompt"), np.int32)
        ck = data.draw(st.sampled_from(ctx_keys), label="ctx")
        cache = jax.tree.map(
            lambda x: jax.numpy.asarray(
                rng.standard_normal(x.shape).astype(np.float32)
            ).astype(x.dtype), model.init_cache(1, MAX_LEN))
        src.intern_rows(prompt, ck, cache, 0)
    before_paths = _tree_paths(src)
    before_in_use = src.pages_in_use

    dst = KVPool(model, max_len=MAX_LEN, page_size=PAGE, slots=2)
    imported = 0
    for ck in list(src.tree._roots):
        records, stacks = src.export_subtree(ck)
        assert len(records) == (len(stacks[0].k) if stacks else 0)
        imported += dst.import_subtree(ck, records, stacks)
    got_paths = _tree_paths(dst)
    assert set(got_paths) == set(before_paths)
    assert imported == len(before_paths) == dst.tree.interned
    for key, node in got_paths.items():
        ref = before_paths[key]
        assert node.refs == 0 and node.owner == ref.owner
        for a, b in zip(_page_data(dst, node), _page_data(src, ref)):
            assert np.array_equal(a, b)
    # the source is untouched
    assert _tree_paths(src).keys() == before_paths.keys()
    assert src.pages_in_use == before_in_use
    # idempotent: everything already present imports nothing
    for ck in list(src.tree._roots):
        records, stacks = src.export_subtree(ck)
        assert dst.import_subtree(ck, records, stacks) == 0
    # best-effort under pressure: a pool with barely one request's worth
    # of pages imports at most its capacity and keeps invariants
    tiny = KVPool(model, max_len=MAX_LEN, page_size=PAGE, slots=0,
                  num_pages=N_LOG)
    for ck in list(src.tree._roots):
        records, stacks = src.export_subtree(ck)
        tiny.import_subtree(ck, records, stacks)
    # refs-0 imports are ordinary reclaimable cache, so a later chain may
    # evict an earlier one — LIVE state must still fit and stay sound
    assert tiny.pages_in_use <= tiny.num_pages
    pages = [n.page for n in tiny.tree._walk()]
    assert len(pages) == len(set(pages)) == tiny.tree.interned
    assert all(n.refs == 0 for n in tiny.tree._walk())
    # every surviving path is a path the source holds, with equal data
    src_paths = _tree_paths(src)
    for key, node in _tree_paths(tiny).items():
        for a, b in zip(_page_data(tiny, node),
                        _page_data(src, src_paths[key])):
            assert np.array_equal(a, b)


