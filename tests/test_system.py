"""End-to-end system tests.

Single-device: full cell lifecycle on the 1x1x1 logical grid.
Multi-device: subprocess scripts under 8 virtual host devices exercising
real resharding, preemption transfer, failure recovery, EP equality, and
a reduced-mesh multi-pod dry-run.
"""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(script: str, timeout=540) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=ROOT, env=env, timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-2500:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
def test_single_device_cell_lifecycle():
    from repro.configs.base import ShapeConfig, smoke_config
    from repro.configs.registry import get_arch
    from repro.core import Supervisor, single_device_grid
    from repro.data.pipeline import DataConfig, SyntheticPipeline
    from repro.train.optimizer import OptConfig

    sup = Supervisor(single_device_grid())
    cfg = smoke_config(get_arch("qwen3-4b")).replace(
        num_layers=2, d_model=64, d_ff=128, num_heads=2, num_kv_heads=1,
        head_dim=32, vocab=128)
    cell = sup.create_cell("c", cfg, "train", ncols=1, opt_cfg=OptConfig())
    pipe = SyntheticPipeline(DataConfig(kind="bigram", vocab=64), cfg,
                             ShapeConfig("t", "train", 16, 4))
    m = cell.train_steps(pipe.get_batch, 2)
    assert m["xent"] > 0 and cell.step == 2
    assert sup.table.epoch == 1
    sup.destroy_cell("c")
    assert not sup.cells and sup.table.epoch == 2


LIFECYCLE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, tempfile
import jax, numpy as np
from repro.configs.base import smoke_config, ShapeConfig
from repro.configs.registry import get_arch
from repro.core import DeviceGrid, Supervisor
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.train.optimizer import OptConfig
import repro.checkpoint.checkpoint as ckpt

grid = DeviceGrid.from_flat(jax.devices(), pods=1, rows=2, cols=4)
sup = Supervisor(grid)
cfg = smoke_config(get_arch("qwen3-4b")).replace(num_layers=2, d_model=64,
    d_ff=128, num_heads=2, num_kv_heads=2, head_dim=32, vocab=256)
cell = sup.create_cell("tr", cfg, "train", ncols=2, opt_cfg=OptConfig())
pipe = SyntheticPipeline(DataConfig(kind="bigram", vocab=128), cfg,
                         ShapeConfig("t", "train", 16, 16))
out = {}
m = cell.train_steps(pipe.get_batch, 2)
out["xent0"] = m["xent"]

# live resize (grow) preserves learned state exactly
params_before = jax.tree.leaves(cell.state.params)[0].copy()
sup.resize_cell("tr", 3)
params_after = jax.tree.leaves(cell.state.params)[0]
out["resize_exact"] = bool(np.allclose(np.asarray(params_before, np.float32),
                                       np.asarray(params_after, np.float32)))
m = cell.train_steps(pipe.get_batch, 1)

# serving cell + preemption transfer
srv = sup.create_cell("srv", cfg, "serve", ncols=1)
srv.init_serve()
sup.transfer_columns("tr", "srv", 1)
out["tr_cols"] = sup.cells["tr"].zone.ncols
out["srv_cols"] = sup.cells["srv"].zone.ncols
m = cell.train_steps(pipe.get_batch, 1)

# checkpoint -> column failure -> degraded recovery -> resume
with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, cell.step, cell.state)
    affected = sup.fail_column(0, sup.cells["tr"].zone.c0)
    out["affected"] = affected
    rec = sup.recover_cell("tr", ckpt_dir=d)
    out["recovered_step"] = rec.step
    m = rec.train_steps(pipe.get_batch, 1)
    out["xent_after_recovery"] = m["xent"]
out["epoch"] = sup.table.epoch
out["events"] = [e["op"] for e in sup.events]
print(json.dumps(out))
"""


def test_multidevice_lifecycle():
    out = _run_subprocess(LIFECYCLE)
    assert out["resize_exact"], "resize must preserve state bit-exactly"
    assert out["tr_cols"] == 2 and out["srv_cols"] == 2
    assert out["affected"] == ["tr"]
    assert out["recovered_step"] == 4
    assert out["xent_after_recovery"] > 0
    assert "recover" in out["events"]


EP_EQUALITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ArchConfig, MoEConfig
from repro.models.moe import moe_block, moe_specs, use_ep
from repro.models.param import init_params
from repro.sharding.rules import make_ctx
from repro.launch.mesh import make_mesh_for_devices

cfg = ArchConfig(name="t", family="moe", num_layers=1, d_model=32,
    num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=48, capacity_factor=8.0))

# mesh A: (2 data, 4 model) -> EP (8 % 4 == 0); mesh B: (8 data, 1 model)
mesh_a = make_mesh_for_devices(2, 4)
mesh_b = make_mesh_for_devices(8, 1)
ctx_a, ctx_b = make_ctx(mesh_a), make_ctx(mesh_b)
assert use_ep(cfg, ctx_a) and use_ep(cfg, ctx_b)

p = init_params(moe_specs(cfg, ctx_a), jax.random.PRNGKey(0), "float32")
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32), jnp.float32)

outs = []
for ctx in (ctx_a, ctx_b):
    y, aux = jax.jit(lambda p, x: moe_block(p, x, cfg, ctx, train=True))(p, x)
    outs.append((np.asarray(y), float(aux)))
rel = np.abs(outs[0][0] - outs[1][0]).max() / np.abs(outs[1][0]).max()
print(json.dumps({"rel": float(rel), "aux_a": outs[0][1], "aux_b": outs[1][1]}))
"""


def test_moe_ep_layout_equality():
    """EP over 4-way model axis == pure-DP layout (same math, diff comms)."""
    out = _run_subprocess(EP_EQUALITY)
    assert out["rel"] < 1e-4, out
    assert abs(out["aux_a"] - out["aux_b"]) < 1e-3


TINY_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs.base import smoke_config, ShapeConfig
from repro.configs.registry import get_arch
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_mesh_for_devices
from repro.core.accounting import collective_bytes

mesh = make_mesh_for_devices(2, 2, pods=2)      # reduced multi-pod mesh
arch = smoke_config(get_arch("mixtral-8x7b")).replace(microbatch=1)
shape = ShapeConfig("t", "train", 64, 8)
model, lowered = lower_cell(arch, shape, mesh)
compiled = lowered.compile()
ma = compiled.memory_analysis()
colls = collective_bytes(compiled.as_text())
print(json.dumps({
    "devices": int(mesh.devices.size),
    "temp_mb": ma.temp_size_in_bytes / 2**20,
    "has_collectives": bool(colls),
    "colls": {k: int(v) for k, v in colls.items()},
}))
"""


def test_reduced_multipod_dryrun():
    """The dry-run machinery on a 2x2x2 'multi-pod' mesh: lower+compile a
    MoE train step, collectives present across the pod axis."""
    out = _run_subprocess(TINY_DRYRUN)
    assert out["devices"] == 8
    assert out["has_collectives"], out


DISTRIBUTED_DECODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.models.model import build_model
from repro.sharding.rules import make_ctx, single_device_ctx
from repro.launch.mesh import make_mesh_for_devices

cfg = smoke_config(get_arch("qwen3-4b")).replace(num_layers=2, vocab=256)
mesh = make_mesh_for_devices(2, 4)
ctx = make_ctx(mesh)
model = build_model(cfg, ctx)
params = model.init(jax.random.PRNGKey(0))
B, S = 4, 32
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

# distributed: KV cache sharded (batch over data, kv_seq over model)
cache_ps = model.cache_pspecs(B, S)
cache = jax.tree.map(
    lambda c, s: jax.device_put(c, jax.sharding.NamedSharding(mesh, s)),
    model.init_cache(B, S), cache_ps)
_, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :-1]}, cache)
dec = {"tokens": toks[:, -1:], "pos": jnp.full((B,), S - 1, jnp.int32)}
logits_dist, _ = jax.jit(model.decode)(params, cache, dec)

# single-device reference
ctx1 = single_device_ctx()
model1 = build_model(cfg, ctx1)
cache1 = model1.init_cache(B, S)
_, cache1 = jax.jit(model1.prefill)(params, {"tokens": toks[:, :-1]}, cache1)
logits_ref, _ = jax.jit(model1.decode)(params, cache1, dec)

a = np.asarray(logits_dist, np.float32)[:, :cfg.vocab]
b = np.asarray(logits_ref, np.float32)[:, :cfg.vocab]
rel = np.abs(a - b).max() / np.abs(b).max()
print(json.dumps({"rel": float(rel)}))
"""


def test_distributed_decode_matches_single_device():
    """Sequence-sharded KV decode == single-device decode."""
    out = _run_subprocess(DISTRIBUTED_DECODE)
    assert out["rel"] < 5e-2, out
