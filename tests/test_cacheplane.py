"""Cluster cache plane — prefix-locality routing + live KV page migration.

Covers the PR 7 tentpole (``repro.serve.cacheplane``):

  * digest compatibility — a replica's advert names exactly the chunk
    digests a router computes for the same prompt/namespace;
  * :class:`PrefixIndex` routing — deepest advertised prefix wins,
    deterministic candidate-order tie-break, drop forgets a replica;
  * MIGRATION EXACTNESS — a pool warmed only by ``export_subtree`` /
    ``import_subtree`` serves token-for-token what a cold re-intern
    serves, for dense + moe + encdec;
  * warm routing in ``DisaggServer.pump`` — repeat prompts route to the
    replica already holding the prefix (``routed_warm``) and hit its
    interned pages instead of re-interning per replica;
  * drain-before-detach (``migrate=True``) — a spec-driven scale-down
    hands the victim's hot prefixes AND in-flight slotted requests to
    survivors: nothing requeues, decode output is identical to a server
    that never scaled.
"""
import dataclasses

import numpy as np
import pytest

import jax

from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.models.model import build_model
from repro.serve.batcher import ContinuousBatcher, Request
from repro.serve.cacheplane import (
    PrefixIndex,
    advertise,
    chunk_digests,
    migrate_prefixes,
)
from repro.sharding.rules import single_device_ctx

MAX_LEN = 32
CHUNK = 8
PAGE = 8
N_LOG = MAX_LEN // PAGE
FAMILY_ARCHS = ["qwen3-4b", "mixtral-8x7b", "seamless-m4t-large-v2"]

_CACHE = {}


def _model(name):
    if name not in _CACHE:
        cfg = smoke_config(get_arch(name))
        if cfg.sliding_window is not None and cfg.sliding_window < MAX_LEN:
            cfg = cfg.replace(sliding_window=64)
        model = build_model(cfg, single_device_ctx())
        _CACHE[name] = (model, model.init(jax.random.PRNGKey(0)))
    return _CACHE[name]


def _requests(cfg, lens, *, shared=0, max_new=4, seed=0, rid0=0):
    srng = np.random.RandomState(1234)
    sysp = srng.randint(1, cfg.vocab, size=shared).astype(np.int32)
    rng = np.random.RandomState(seed)
    out = []
    for i, L in enumerate(lens):
        tail = rng.randint(1, cfg.vocab, size=L).astype(np.int32)
        src = None
        if cfg.family == "encdec":
            src = np.random.RandomState(99).randn(
                9, cfg.d_model).astype(np.float32)
        out.append(Request(rid=rid0 + i, prompt=np.concatenate([sysp, tail]),
                           max_new_tokens=max_new, src=src))
    return out


# ---------------------------------------------------------------------------
# digests + index (pure python, no model)
# ---------------------------------------------------------------------------
def test_advert_matches_chunk_digests():
    """What a replica advertises for an interned prompt is EXACTLY what
    the router computes for that prompt — same bytes, same namespace
    seed — so warm routing needs no token exchange, only digests."""
    model, _ = _model("qwen3-4b")
    from repro.serve.kvpool import KVPool
    pool = KVPool(model, max_len=MAX_LEN, page_size=PAGE, slots=2)
    rng = np.random.RandomState(0)
    prompt = rng.randint(1, model.cfg.vocab, size=MAX_LEN - 2).astype(np.int32)
    cache = model.init_cache(1, MAX_LEN)
    pool.intern_rows(prompt, None, cache, 0)
    entries = advertise(pool)
    want = chunk_digests(prompt, None, PAGE)
    assert want and {e["digest"] for e in entries} == set(want)
    assert sorted(e["depth"] for e in entries) == list(
        range(1, len(want) + 1))
    # a different namespace seed must NOT collide
    other = chunk_digests(prompt, ("tenant", "a"), PAGE)
    assert set(other).isdisjoint(want)


def test_prefix_index_routing_deterministic():
    idx = PrefixIndex()
    d = [f"d{i}" for i in range(4)]
    idx.update("r0", [{"digest": d[0], "depth": 1, "refs": 0}])
    idx.update("r1", [{"digest": d[0], "depth": 1, "refs": 0},
                      {"digest": d[1], "depth": 2, "refs": 1}])
    # deepest advertised prefix wins over shallower holders
    assert idx.best(d, ["r0", "r1"]) == ("r1", 2)
    # tie at equal depth: FIRST candidate in caller order wins — routing
    # is a pure function of (index, candidate order)
    assert idx.best(d[:1], ["r0", "r1"]) == ("r0", 1)
    assert idx.best(d[:1], ["r1", "r0"]) == ("r1", 1)
    # adverts are snapshots: an update replaces, a drop forgets
    idx.update("r1", [{"digest": d[0], "depth": 1, "refs": 0}])
    assert idx.best(d, ["r0", "r1"]) == ("r0", 1)
    idx.drop("r0")
    idx.drop("r1")
    assert len(idx) == 0 and idx.best(d, ["r0", "r1"]) == (None, 0)


# ---------------------------------------------------------------------------
# migration exactness: imported pages serve like locally interned ones
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_migrated_prefix_exact(arch):
    """A batcher whose pool was warmed ONLY by page migration serves the
    same tokens as a cold batcher — and actually hits the imported pages
    (the migrated prefix is real cache, not dead weight)."""
    model, params = _model(arch)
    cfg = model.cfg

    def bat():
        return ContinuousBatcher(model, params, batch_slots=2,
                                 max_len=MAX_LEN, prefill_chunk=CHUNK,
                                 page_size=PAGE)

    def run(b, seed, rid0):
        for r in _requests(cfg, [4, 6], shared=17, seed=seed, rid0=rid0):
            b.submit(r)
        return {r.rid: r.output for r in b.run_until_drained(max_steps=2_000)}

    warm_src = bat()
    run(warm_src, seed=0, rid0=0)               # interns the shared prefix
    assert warm_src.pool.tree.interned > 0

    dst = bat()
    # export EVERY namespace root (encdec prompts intern under a
    # src-keyed root, not the default one)
    n = 0
    for ck in list(warm_src.pool.tree._roots):
        records, stacks = warm_src.pool.export_subtree(ck)
        n += dst.pool.import_subtree(ck, records, stacks)
    assert n == warm_src.pool.tree.interned > 0
    # source untouched; destination holds the subtree refs-0 (evictable)
    assert warm_src.pool.pages_in_use >= n

    got = run(dst, seed=5, rid0=10)
    assert dst.pool.stats()["prefix_hit_tokens"] > 0    # imported pages HIT
    ref = run(bat(), seed=5, rid0=10)
    assert got == ref, arch


def test_migrate_prefixes_over_pages_channel():
    """End-to-end helper: export -> ``kind="pages"`` channel ->
    re-intern, through supervisor-opened cells; re-migration of an
    already-present subtree imports nothing (idempotent)."""
    from repro.core import DeviceGrid, Supervisor
    from repro.serve.kvpool import KVPool

    model, params = _model("qwen3-4b")
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=2,
                                allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("a", model.cfg, "serve", ncols=1)
    sup.create_cell("b", model.cfg, "serve", ncols=1)

    src = KVPool(model, max_len=MAX_LEN, page_size=PAGE, slots=2)
    dst = KVPool(model, max_len=MAX_LEN, page_size=PAGE, slots=2)
    rng = np.random.RandomState(3)
    prompt = rng.randint(1, model.cfg.vocab, size=MAX_LEN - 1).astype(np.int32)
    src.intern_rows(prompt, None, model.init_cache(1, MAX_LEN), 0)

    ch = sup.open_channel("a", "b", kind="pages")
    n = migrate_prefixes(src, dst, ch)
    assert n == src.tree.interned > 0
    assert ch.transfers >= 1 and ch.bytes_sent > 0
    assert migrate_prefixes(src, dst, ch) == 0          # idempotent
    # imported chains advertise identically to the source's
    assert ({e["digest"] for e in advertise(dst)}
            == {e["digest"] for e in advertise(src)})


# ---------------------------------------------------------------------------
# warm routing through the supervisor-held index
# ---------------------------------------------------------------------------
def _fresh_server(sup_cols=3, names=("dec0", "dec1"), **kw):
    from repro.core import DeviceGrid, Supervisor
    from repro.serve.disagg import DisaggServer

    model, _ = _model("qwen3-4b")
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1,
                                cols=sup_cols, allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("prefill", cfg, "serve", ncols=1)
    first = sup.create_cell(names[0], cfg, "serve", ncols=1)
    first.init_serve(rng=jax.random.PRNGKey(0))
    for nm in names[1:]:
        sup.create_cell(nm, cfg, "serve", ncols=1)
    srv = DisaggServer(sup, "prefill", list(names), batch_slots=2,
                       max_len=MAX_LEN, chunk=CHUNK, page_size=PAGE, **kw)
    return sup, srv


def test_warm_routing_concentrates_prefix():
    """Repeat prompts under one prefix route to the replica that already
    interned it: ``routed_warm`` counts them, the index is populated,
    and decode-side hit tokens land on ONE replica instead of being
    re-interned once per replica."""
    model, _ = _model("qwen3-4b")
    cfg = model.cfg
    sup, srv = _fresh_server()
    for r in _requests(cfg, [3, 4], shared=18):
        srv.submit(r)
    srv.run_until_drained(max_steps=2_000)
    for r in _requests(cfg, [5, 3, 4], shared=18, seed=7, rid0=10):
        srv.submit(r)
    done = [r for r in srv.run_until_drained(max_steps=2_000)
            if r.rid >= 10]
    assert len(done) == 3
    st = srv.stats()
    assert len(srv.cacheplane.index) > 0                # adverts ingested
    assert st["routed_warm"] > 0
    assert st["prefix_hit_rate"] > 0
    # decode-side hits concentrate where the prefix lives: exactly one
    # replica served warm traffic (the other would be all-miss)
    per = st["per_replica_prefix_hit_rate"]
    assert len(per) == 2 and max(per) > 0


def test_route_deterministic_without_traffic_history():
    """Same capacity state -> same pick, every time (no hidden cursor):
    cold routing is reproducible run-to-run."""
    sup, srv = _fresh_server()
    cap = {0: 2, 1: 2}
    assert [srv._route(dict(cap)) for _ in range(3)] == [0, 0, 0]
    assert srv._route({0: 1, 1: 2}) == 1
    assert srv._route({0: 0, 1: 0}) is None


# ---------------------------------------------------------------------------
# drain-before-detach: live subOS resize with no cold restart
# ---------------------------------------------------------------------------
def test_scale_down_drains_to_survivors():
    """``migrate=True``: a spec-driven 3 -> 2 scale-down migrates the
    victim's slotted requests and hot pages to survivors — zero
    requeues, decode continues mid-stream, and every token matches a
    server that never scaled at all."""
    from repro.core import (CellSpec, ChannelSpec, ClusterSpec,
                            DeviceGrid, Supervisor)
    from repro.serve.disagg import DisaggServer

    model, _ = _model("qwen3-4b")
    cfg = model.cfg

    def build(migrate):
        grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1,
                                    cols=4, allow_reuse=True)
        sup = Supervisor(grid)
        spec = ClusterSpec(
            cells=(CellSpec("prefill", cfg, "serve", ncols=1),
                   CellSpec("decode", cfg, "serve", ncols=1, replicas=3,
                            min_replicas=1, max_replicas=3)),
            channels=(ChannelSpec("prefill", "decode", kind="kv"),),
        )
        sup.apply(spec)
        sup.cells["decode/0"].init_serve(rng=jax.random.PRNGKey(0))
        srv = DisaggServer(sup, "prefill", spec.cell("decode").instances(),
                           batch_slots=2, max_len=MAX_LEN, chunk=CHUNK,
                           page_size=PAGE, migrate=migrate)
        return sup, srv

    # prompts long enough that every request interns a page UNIQUE to it
    # (page 1 mixes shared tokens 8..11 with its own tail), so whichever
    # replica is drained holds pages no survivor has yet
    reqs = lambda: _requests(cfg, [9, 10, 11, 12], shared=12, max_new=6)  # noqa: E731

    sup, srv = build(migrate=True)
    for r in reqs():
        srv.submit(r)
    srv.step()                          # spread slots across replicas
    victim = srv.replicas[2]
    held = sum(1 for s in victim.batcher.slot_req if s is not None)
    assert held >= 1

    sup.apply(sup.desired.with_cell(
        dataclasses.replace(sup.desired.cell("decode"), replicas=2)))
    out = srv.sync(sup.desired)
    assert out["detached"] == ["decode/2"]
    assert out["requeued"] == 0                         # nothing restarted
    st = srv.stats()
    assert st["drain_handoffs"] == held
    assert st["pages_migrated"] > 0
    # the index forgot the detached replica
    assert set(srv.cacheplane.index.replicas()) <= {"decode/0", "decode/1"}

    done = {r.rid: r.output for r in srv.run_until_drained(max_steps=2_000)}
    assert set(done) == {0, 1, 2, 3}
    assert all(len(v) == 6 for v in done.values())

    # token-identical to a server that never scaled
    sup2, ref_srv = build(migrate=False)
    for r in reqs():
        ref_srv.submit(r)
    ref = {r.rid: r.output
           for r in ref_srv.run_until_drained(max_steps=2_000)}
    assert done == ref


def test_drain_hook_fires_from_reconciler():
    """The supervisor's drain hooks run from the reconciler's destroy
    branch — a DAEMON-driven scale-down (policy apply inside tick) still
    drains before the cell dies, without the server syncing first."""
    from repro.core import (CellSpec, ChannelSpec, ClusterSpec,
                            DeviceGrid, Supervisor)
    from repro.serve.disagg import DisaggServer

    model, _ = _model("qwen3-4b")
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1,
                                cols=4, allow_reuse=True)
    sup = Supervisor(grid)
    spec = ClusterSpec(
        cells=(CellSpec("prefill", cfg, "serve", ncols=1),
               CellSpec("decode", cfg, "serve", ncols=1, replicas=3,
                        min_replicas=1, max_replicas=3)),
        channels=(ChannelSpec("prefill", "decode", kind="kv"),),
    )
    sup.apply(spec)
    sup.cells["decode/0"].init_serve(rng=jax.random.PRNGKey(0))
    srv = DisaggServer(sup, "prefill", spec.cell("decode").instances(),
                       batch_slots=2, max_len=MAX_LEN, chunk=CHUNK,
                       page_size=PAGE, migrate=True)
    assert srv._drain_hook in sup.drain_hooks
    for r in _requests(cfg, [3, 5, 2, 4], shared=12, max_new=6):
        srv.submit(r)
    srv.step()
    held = sum(1 for s in srv.replicas[2].batcher.slot_req if s is not None)
    # the destroy op itself (as the reconciler executes it) triggers the
    # drain — BEFORE any sync detaches the replica
    sup.apply(sup.desired.with_cell(
        dataclasses.replace(sup.desired.cell("decode"), replicas=2)))
    assert srv.drain_handoffs == held
    assert srv.replicas[2].drained
    out = srv.sync(sup.desired)         # detach finds an already-empty rep
    assert out["requeued"] == 0
    done = srv.run_until_drained(max_steps=2_000)
    assert {r.rid for r in done} == {0, 1, 2, 3}
