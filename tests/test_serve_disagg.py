"""Disaggregated serving: chunked prefill + prefill->decode KV handoff.

Covers the tentpole of the disaggregated serving subsystem:
  * ``Model.prefill_ranged`` — padded-prompt prefill matches the exact-length
    prefill program at the last real token;
  * chunked-prefill batcher — outputs identical to the token-at-a-time
    prompt loop, with >= 4x fewer program invocations for prompts >= 32;
  * prefill-cell -> decode-cell KV handoff over an ArrayChannel — outputs
    identical to single-cell serving;
  * the prompt-overflow fix and TTFT/TPOT request accounting.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.core import DeviceGrid, Supervisor
from repro.core.accounting import CellAccounting
from repro.models.model import build_model
from repro.serve.batcher import ContinuousBatcher, Request
from repro.sharding.rules import single_device_ctx

MAX_LEN = 48
SLOTS = 3


@pytest.fixture(scope="module")
def model_and_params():
    cfg = smoke_config(get_arch("qwen3-4b"))
    model = build_model(cfg, single_device_ctx())
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(vocab, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, vocab, size=L).astype(np.int32) for L in lens]


def _requests(prompts, max_new=5):
    return [Request(rid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]


def test_bucket_len_cap_binds_last():
    """Regression: chunk > max_len must cap at max_len, never pad past the
    cache (which would silently discard the prompt KV via the rolling
    branch of prefill attention)."""
    from repro.serve.serve_step import bucket_len
    assert bucket_len(5, 32, 16) == 16
    assert bucket_len(5, 8, 64) == 8
    assert bucket_len(33, 16, 64) == 48
    assert bucket_len(63, 16, 64) == 64


# ---------------------------------------------------------------------------
# prefill program
# ---------------------------------------------------------------------------
def test_prefill_ranged_matches_exact_length_prefill(model_and_params):
    model, params = model_and_params
    (prompt,) = _prompts(model.cfg.vocab, [11])
    L, s_pad = len(prompt), 16

    # reference: the existing whole-prompt prefill at the exact length
    ref_logits, _ = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, model.init_cache(1, MAX_LEN)
    )

    padded = np.zeros((1, s_pad), np.int32)
    padded[0, :L] = prompt
    got_logits, cache = model.prefill_ranged(
        params,
        {"tokens": jnp.asarray(padded), "length": jnp.asarray([L], jnp.int32)},
        model.init_cache(1, MAX_LEN),
    )
    a, b = np.asarray(got_logits, np.float32), np.asarray(ref_logits, np.float32)
    rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-6)
    assert rel < 5e-2, rel

    # pad slots are invalidated so decode attention can never see them
    sp = np.asarray(cache["layers"].slot_pos)          # (layers, 1, S_c)
    assert (sp[:, 0, :L] == np.arange(L)).all()
    assert (sp[:, 0, L:] == -1).all()


def test_prefill_ranged_rejects_stateful_families():
    cfg = smoke_config(get_arch("mamba2-2.7b"))
    model = build_model(cfg, single_device_ctx())
    with pytest.raises(NotImplementedError):
        model.prefill_ranged(None, None, None)


# ---------------------------------------------------------------------------
# chunked-prefill batcher vs token-at-a-time
# ---------------------------------------------------------------------------
def test_chunked_prefill_matches_token_at_a_time(model_and_params):
    model, params = model_and_params
    prompts = _prompts(model.cfg.vocab, [3, 33, 40, 1, 17])

    base = ContinuousBatcher(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                             prefill_chunk=None)
    for r in _requests(prompts):
        base.submit(r)
    ref = {r.rid: r.output for r in base.run_until_drained()}
    assert base.prefill_invocations == 0

    chunked = ContinuousBatcher(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                                prefill_chunk=16)
    assert chunked.chunked
    for r in _requests(prompts):
        chunked.submit(r)
    got = {r.rid: r.output for r in chunked.run_until_drained()}

    assert got == ref
    # at most 1 invocation per prompt; same-bucket prompts admitted in one
    # tick share an invocation, so usually fewer
    assert 0 < chunked.prefill_invocations <= len(prompts)
    assert sum(chunked.prefill_batch_sizes) == len(prompts)
    # prompt phase: O(buckets) invocations instead of prompt_len
    assert chunked.decode_invocations < base.decode_invocations


def test_same_bucket_prompts_share_one_prefill_invocation(model_and_params):
    """Satellite: B same-bucket prompts admitted together -> ONE (B, S_pad)
    prefill invocation, outputs identical to per-prompt prefill."""
    model, params = model_and_params
    prompts = _prompts(model.cfg.vocab, [33, 35, 40])   # all bucket 48

    batched = ContinuousBatcher(model, params, batch_slots=3, max_len=MAX_LEN,
                                prefill_chunk=16)
    for r in _requests(prompts):
        batched.submit(r)
    got = {r.rid: r.output for r in batched.run_until_drained()}
    assert batched.prefill_invocations == 1
    assert batched.prefill_batch_sizes == [3]
    # batch dims pad to powers of two: bounded program variants + caches
    assert set(batched._scratch_caches) == {4}

    # reference: one slot at a time -> one invocation per prompt
    solo = ContinuousBatcher(model, params, batch_slots=1, max_len=MAX_LEN,
                             prefill_chunk=16)
    for r in _requests(prompts):
        solo.submit(r)
    ref = {r.rid: r.output for r in solo.run_until_drained()}
    assert solo.prefill_invocations == 3
    assert got == ref


def test_chunked_prefill_invocation_reduction(model_and_params):
    """Acceptance: >= 4x fewer program invocations per prompt for L >= 32."""
    model, params = model_and_params
    prompts = _prompts(model.cfg.vocab, [32, 40])

    def run(chunk):
        bat = ContinuousBatcher(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                                prefill_chunk=chunk)
        for r in _requests(prompts, max_new=2):
            bat.submit(r)
        bat.run_until_drained()
        return bat.prefill_invocations + bat.decode_invocations

    baseline, chunked = run(None), run(16)
    assert baseline >= 4 * chunked, (baseline, chunked)


def test_prompt_overflow_terminates(model_and_params):
    """Regression: a prompt longer than the cache used to spin forever in
    the token-at-a-time prompt loop (no pos cap check)."""
    model, params = model_and_params
    long_prompt = _prompts(model.cfg.vocab, [MAX_LEN + 20])[0]
    bat = ContinuousBatcher(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                            prefill_chunk=None)
    bat.submit(Request(rid=0, prompt=long_prompt, max_new_tokens=4))
    done = bat.run_until_drained(max_steps=MAX_LEN * 3)
    assert len(done) == 1 and done[0].finished_at is not None
    # chunked batchers route oversized prompts to the same guarded fallback
    bat2 = ContinuousBatcher(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                             prefill_chunk=16)
    bat2.submit(Request(rid=1, prompt=long_prompt, max_new_tokens=4))
    done2 = bat2.run_until_drained(max_steps=MAX_LEN * 3)
    assert len(done2) == 1 and bat2.prefill_invocations == 0


def test_request_metrics_recorded(model_and_params):
    model, params = model_and_params
    acc = CellAccounting("serve")
    bat = ContinuousBatcher(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                            prefill_chunk=16, accounting=acc)
    for r in _requests(_prompts(model.cfg.vocab, [5, 20]), max_new=3):
        bat.submit(r)
    done = bat.run_until_drained()
    assert len(acc.requests) == 2
    for r in done:
        assert r.ttft is not None and r.ttft >= 0
        assert r.tpot is not None and r.tpot >= 0
    s = acc.serving_summary()
    assert s["requests"] == 2 and "ttft_p50" in s and "tpot_p50" in s


# ---------------------------------------------------------------------------
# prefill cell -> decode cell handoff
# ---------------------------------------------------------------------------
def test_kv_handoff_roundtrip_matches_single_cell(model_and_params):
    from repro.serve.disagg import DisaggServer

    model, params = model_and_params
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=2,
                                allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("prefill", cfg, "serve", ncols=1)
    dec = sup.create_cell("decode", cfg, "serve", ncols=1)
    dec.init_serve(rng=jax.random.PRNGKey(0))

    srv = DisaggServer(sup, "prefill", "decode", batch_slots=SLOTS,
                       max_len=MAX_LEN, chunk=16)
    prompts = _prompts(cfg.vocab, [3, 33, 17, 40])
    for r in _requests(prompts):
        srv.submit(r)
    got = {r.rid: r.output for r in srv.run_until_drained()}

    # weight sync + KV handoff both went through supervisor-opened channels
    kinds = [e.get("kind") for e in sup.events if e["op"] == "open_channel"]
    assert kinds == ["array", "kv"]
    assert srv.channel.transfers == len(prompts)
    assert srv.channel.bytes_sent > 0

    # single-cell reference on the same weights (token-at-a-time)
    ref_bat = ContinuousBatcher(dec.model, dec.serve_params, batch_slots=SLOTS,
                                max_len=MAX_LEN, prefill_chunk=None)
    for r in _requests(prompts):
        ref_bat.submit(r)
    ref = {r.rid: r.output for r in ref_bat.run_until_drained()}
    assert got == ref

    # TTFT/TPOT land in the DECODE cell's accounting (it owns the slots)
    assert dec.accounting.serving_summary()["requests"] == len(prompts)


def test_decode_replica_fanout(model_and_params):
    """replicas=2 decode spec: one prefill cell fans requests out across
    two decode cells; every request is served and both replicas take load."""
    from repro.core import CellSpec, ChannelSpec, ClusterSpec
    from repro.serve.disagg import DisaggServer

    model, _ = model_and_params
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=3,
                                allow_reuse=True)
    sup = Supervisor(grid)
    spec = ClusterSpec(
        cells=(CellSpec("prefill", cfg, "serve", ncols=1),
               CellSpec("decode", cfg, "serve", ncols=1, replicas=2)),
        channels=(ChannelSpec("prefill", "decode", kind="kv"),),
    )
    plan = sup.apply(spec)
    assert {op.verb for op in plan.ops} == {"create", "open_channel"}
    assert set(sup.cells) == {"prefill", "decode/0", "decode/1"}
    sup.cells["decode/0"].init_serve(rng=jax.random.PRNGKey(0))

    names = spec.cell("decode").instances()
    srv = DisaggServer(sup, "prefill", names, batch_slots=2,
                       max_len=MAX_LEN, chunk=16)
    # kv channels were opened declaratively by reconcile; DisaggServer
    # reuses them instead of opening duplicates
    assert sup.find_channel("prefill", "decode/0", "kv") is srv.replicas[0].channel
    assert len([c for c in sup.channels if c.kind == "kv"]) == 2

    prompts = _prompts(cfg.vocab, [9, 33, 17, 21, 40, 12])
    for r in _requests(prompts, max_new=3):
        srv.submit(r)
    done = {r.rid: r for r in srv.run_until_drained()}
    assert set(done) == set(range(len(prompts)))
    assert all(len(done[i].output) == 3 for i in done)
    st = srv.stats()
    assert st["replicas"] == 2
    assert all(n > 0 for n in st["per_replica_requests"])  # both took load
    assert sum(st["per_replica_requests"]) == len(prompts)
    # replica weight fan-out went over an on-demand channel: decode/1 got
    # its params from decode/0, not from init
    kinds = [(e.get("kind"), e["src"], e["dst"]) for e in sup.events
             if e["op"] == "open_channel"]
    assert ("array", "decode/0", "decode/1") in kinds

    # outputs identical to a single-cell reference on the same weights
    dec = sup.cells["decode/0"]
    ref_bat = ContinuousBatcher(dec.model, dec.serve_params, batch_slots=2,
                                max_len=MAX_LEN, prefill_chunk=None)
    for r in _requests(prompts, max_new=3):
        ref_bat.submit(r)
    ref = {r.rid: r.output for r in ref_bat.run_until_drained()}
    assert {i: done[i].output for i in done} == ref


def test_disagg_unservable_prompts_do_not_stall_the_loop(model_and_params):
    """An empty or cache-overflowing prompt must finish (empty output)
    instead of raising mid-pump and starving every other request."""
    from repro.serve.disagg import DisaggServer

    model, _ = model_and_params
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=2,
                                allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("prefill", cfg, "serve", ncols=1)
    sup.create_cell("decode", cfg, "serve", ncols=1).init_serve(
        rng=jax.random.PRNGKey(0)
    )
    srv = DisaggServer(sup, "prefill", "decode", batch_slots=2,
                       max_len=32, chunk=8)
    good = _prompts(cfg.vocab, [5])[0]
    srv.submit(Request(rid=0, prompt=np.array([], np.int32), max_new_tokens=3))
    srv.submit(Request(rid=1, prompt=good, max_new_tokens=3))
    srv.submit(Request(rid=2, prompt=np.ones(40, np.int32), max_new_tokens=3))
    done = {r.rid: r.output for r in srv.run_until_drained()}
    assert set(done) == {0, 1, 2}
    assert done[0] == [] and done[2] == [] and len(done[1]) == 3
    # rejected requests never reached a replica: per-replica stats and the
    # decode cell's accounting only count routed traffic
    st = srv.stats()
    assert sum(st["per_replica_requests"]) == 1
    assert st["decode_serving"]["requests"] == 3   # front-door view keeps all
    assert len(srv.rejected) == 2
