"""Disaggregated serving: chunked prefill + prefill->decode KV handoff.

Covers the tentpole of the disaggregated serving subsystem:
  * ``Model.prefill_ranged`` — padded-prompt prefill matches the exact-length
    prefill program at the last real token;
  * chunked-prefill batcher — outputs identical to the token-at-a-time
    prompt loop, with >= 4x fewer program invocations for prompts >= 32;
  * prefill-cell -> decode-cell KV handoff over an ArrayChannel — outputs
    identical to single-cell serving;
  * the prompt-overflow fix and TTFT/TPOT request accounting.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.core import DeviceGrid, Supervisor
from repro.core.accounting import CellAccounting
from repro.models.model import build_model
from repro.serve.batcher import ContinuousBatcher, Request
from repro.sharding.rules import single_device_ctx

MAX_LEN = 48
SLOTS = 3


@pytest.fixture(scope="module")
def model_and_params():
    cfg = smoke_config(get_arch("qwen3-4b"))
    model = build_model(cfg, single_device_ctx())
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(vocab, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, vocab, size=L).astype(np.int32) for L in lens]


def _requests(prompts, max_new=5):
    return [Request(rid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]


def test_bucket_len_cap_binds_last():
    """Regression: chunk > max_len must cap at max_len, never pad past the
    cache (which would silently discard the prompt KV via the rolling
    branch of prefill attention)."""
    from repro.serve.serve_step import bucket_len
    assert bucket_len(5, 32, 16) == 16
    assert bucket_len(5, 8, 64) == 8
    assert bucket_len(33, 16, 64) == 48
    assert bucket_len(63, 16, 64) == 64


# ---------------------------------------------------------------------------
# prefill program
# ---------------------------------------------------------------------------
def test_prefill_ranged_matches_exact_length_prefill(model_and_params):
    model, params = model_and_params
    (prompt,) = _prompts(model.cfg.vocab, [11])
    L, s_pad = len(prompt), 16

    # reference: the existing whole-prompt prefill at the exact length
    ref_logits, _ = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, model.init_cache(1, MAX_LEN)
    )

    padded = np.zeros((1, s_pad), np.int32)
    padded[0, :L] = prompt
    got_logits, cache = model.prefill_ranged(
        params,
        {"tokens": jnp.asarray(padded), "length": jnp.asarray([L], jnp.int32)},
        model.init_cache(1, MAX_LEN),
    )
    a, b = np.asarray(got_logits, np.float32), np.asarray(ref_logits, np.float32)
    rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-6)
    assert rel < 5e-2, rel

    # pad slots are invalidated so decode attention can never see them
    sp = np.asarray(cache["layers"].slot_pos)          # (layers, 1, S_c)
    assert (sp[:, 0, :L] == np.arange(L)).all()
    assert (sp[:, 0, L:] == -1).all()


def test_stale_slot_state_reset_on_token_at_a_time_admit():
    """Regression: a request admitted token-at-a-time into a REUSED slot
    used to inherit the previous occupant's recurrent state (ssm/hybrid
    caches are not position-masked the way KV is) — its whole trajectory
    diverged from a fresh-slot run."""
    cfg = smoke_config(get_arch("mamba2-2.7b"))
    model = build_model(cfg, single_device_ctx())
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg.vocab, [3, 17, 1, 20, 9])

    solo = {}
    for i, p in enumerate(prompts):
        bat = ContinuousBatcher(model, params, batch_slots=1, max_len=32,
                                prefill_chunk=None)
        bat.submit(Request(rid=i, prompt=p, max_new_tokens=4))
        solo.update({r.rid: r.output for r in bat.run_until_drained()})

    multi = ContinuousBatcher(model, params, batch_slots=2, max_len=32,
                              prefill_chunk=None)
    for i, p in enumerate(prompts):
        multi.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    got = {r.rid: r.output for r in multi.run_until_drained()}
    assert got == solo


# ---------------------------------------------------------------------------
# chunked-prefill batcher vs token-at-a-time
# ---------------------------------------------------------------------------
def test_chunked_prefill_matches_token_at_a_time(model_and_params):
    model, params = model_and_params
    prompts = _prompts(model.cfg.vocab, [3, 33, 40, 1, 17])

    base = ContinuousBatcher(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                             prefill_chunk=None)
    for r in _requests(prompts):
        base.submit(r)
    ref = {r.rid: r.output for r in base.run_until_drained()}
    assert base.prefill_invocations == 0

    chunked = ContinuousBatcher(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                                prefill_chunk=16)
    assert chunked.chunked
    for r in _requests(prompts):
        chunked.submit(r)
    got = {r.rid: r.output for r in chunked.run_until_drained()}

    assert got == ref
    # at most 1 invocation per prompt; same-bucket prompts admitted in one
    # tick share an invocation, so usually fewer
    assert 0 < chunked.prefill_invocations <= len(prompts)
    assert sum(chunked.prefill_batch_sizes) == len(prompts)
    # prompt phase: O(buckets) invocations instead of prompt_len
    assert chunked.decode_invocations < base.decode_invocations


def test_same_bucket_prompts_share_one_prefill_invocation(model_and_params):
    """Satellite: B same-bucket prompts admitted together -> ONE (B, S_pad)
    prefill invocation, outputs identical to per-prompt prefill."""
    model, params = model_and_params
    prompts = _prompts(model.cfg.vocab, [33, 35, 40])   # all bucket 48

    batched = ContinuousBatcher(model, params, batch_slots=3, max_len=MAX_LEN,
                                prefill_chunk=16)
    for r in _requests(prompts):
        batched.submit(r)
    got = {r.rid: r.output for r in batched.run_until_drained()}
    assert batched.prefill_invocations == 1
    assert batched.prefill_batch_sizes == [3]
    # batch dims pad to powers of two: bounded program variants + caches
    assert set(batched._scratch_caches) == {4}

    # reference: one slot at a time -> one invocation per prompt
    solo = ContinuousBatcher(model, params, batch_slots=1, max_len=MAX_LEN,
                             prefill_chunk=16)
    for r in _requests(prompts):
        solo.submit(r)
    ref = {r.rid: r.output for r in solo.run_until_drained()}
    assert solo.prefill_invocations == 3
    assert got == ref


def test_chunked_prefill_invocation_reduction(model_and_params):
    """Acceptance: >= 4x fewer program invocations per prompt for L >= 32."""
    model, params = model_and_params
    prompts = _prompts(model.cfg.vocab, [32, 40])

    def run(chunk):
        bat = ContinuousBatcher(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                                prefill_chunk=chunk)
        for r in _requests(prompts, max_new=2):
            bat.submit(r)
        bat.run_until_drained()
        return bat.prefill_invocations + bat.decode_invocations

    baseline, chunked = run(None), run(16)
    assert baseline >= 4 * chunked, (baseline, chunked)


def test_prompt_overflow_terminates(model_and_params):
    """Regression: a prompt longer than the cache used to spin forever in
    the token-at-a-time prompt loop (no pos cap check)."""
    model, params = model_and_params
    long_prompt = _prompts(model.cfg.vocab, [MAX_LEN + 20])[0]
    bat = ContinuousBatcher(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                            prefill_chunk=None)
    bat.submit(Request(rid=0, prompt=long_prompt, max_new_tokens=4))
    done = bat.run_until_drained(max_steps=MAX_LEN * 3)
    assert len(done) == 1 and done[0].finished_at is not None
    # chunked batchers route oversized prompts to the same guarded fallback
    bat2 = ContinuousBatcher(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                             prefill_chunk=16)
    bat2.submit(Request(rid=1, prompt=long_prompt, max_new_tokens=4))
    done2 = bat2.run_until_drained(max_steps=MAX_LEN * 3)
    assert len(done2) == 1 and bat2.prefill_invocations == 0


def test_request_metrics_recorded(model_and_params):
    model, params = model_and_params
    acc = CellAccounting("serve")
    bat = ContinuousBatcher(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                            prefill_chunk=16, accounting=acc)
    for r in _requests(_prompts(model.cfg.vocab, [5, 20]), max_new=3):
        bat.submit(r)
    done = bat.run_until_drained()
    assert len(acc.requests) == 2
    for r in done:
        assert r.ttft is not None and r.ttft >= 0
        assert r.tpot is not None and r.tpot >= 0
    s = acc.serving_summary()
    assert s["requests"] == 2 and "ttft_p50" in s and "tpot_p50" in s


# ---------------------------------------------------------------------------
# prefill cell -> decode cell handoff
# ---------------------------------------------------------------------------
def test_kv_handoff_roundtrip_matches_single_cell(model_and_params):
    from repro.serve.disagg import DisaggServer

    model, params = model_and_params
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=2,
                                allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("prefill", cfg, "serve", ncols=1)
    dec = sup.create_cell("decode", cfg, "serve", ncols=1)
    dec.init_serve(rng=jax.random.PRNGKey(0))

    srv = DisaggServer(sup, "prefill", "decode", batch_slots=SLOTS,
                       max_len=MAX_LEN, chunk=16)
    prompts = _prompts(cfg.vocab, [3, 33, 17, 40])
    for r in _requests(prompts):
        srv.submit(r)
    got = {r.rid: r.output for r in srv.run_until_drained()}

    # weight sync + KV handoff both went through supervisor-opened channels
    kinds = [e.get("kind") for e in sup.events if e["op"] == "open_channel"]
    assert kinds == ["array", "kv"]
    assert srv.channel.transfers == len(prompts)
    assert srv.channel.bytes_sent > 0

    # single-cell reference on the same weights (token-at-a-time)
    ref_bat = ContinuousBatcher(dec.model, dec.serve_params, batch_slots=SLOTS,
                                max_len=MAX_LEN, prefill_chunk=None)
    for r in _requests(prompts):
        ref_bat.submit(r)
    ref = {r.rid: r.output for r in ref_bat.run_until_drained()}
    assert got == ref

    # TTFT/TPOT land in the DECODE cell's accounting (it owns the slots)
    assert dec.accounting.serving_summary()["requests"] == len(prompts)


def test_decode_replica_fanout(model_and_params):
    """replicas=2 decode spec: one prefill cell fans requests out across
    two decode cells; every request is served and both replicas take load."""
    from repro.core import CellSpec, ChannelSpec, ClusterSpec
    from repro.serve.disagg import DisaggServer

    model, _ = model_and_params
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=3,
                                allow_reuse=True)
    sup = Supervisor(grid)
    spec = ClusterSpec(
        cells=(CellSpec("prefill", cfg, "serve", ncols=1),
               CellSpec("decode", cfg, "serve", ncols=1, replicas=2)),
        channels=(ChannelSpec("prefill", "decode", kind="kv"),),
    )
    plan = sup.apply(spec)
    assert {op.verb for op in plan.ops} == {"create", "open_channel"}
    assert set(sup.cells) == {"prefill", "decode/0", "decode/1"}
    sup.cells["decode/0"].init_serve(rng=jax.random.PRNGKey(0))

    names = spec.cell("decode").instances()
    srv = DisaggServer(sup, "prefill", names, batch_slots=2,
                       max_len=MAX_LEN, chunk=16)
    # kv channels were opened declaratively by reconcile; DisaggServer
    # reuses them instead of opening duplicates
    assert sup.find_channel("prefill", "decode/0", "kv") is srv.replicas[0].channel
    assert len([c for c in sup.channels if c.kind == "kv"]) == 2

    prompts = _prompts(cfg.vocab, [9, 33, 17, 21, 40, 12])
    for r in _requests(prompts, max_new=3):
        srv.submit(r)
    done = {r.rid: r for r in srv.run_until_drained()}
    assert set(done) == set(range(len(prompts)))
    assert all(len(done[i].output) == 3 for i in done)
    st = srv.stats()
    assert st["replicas"] == 2
    assert all(n > 0 for n in st["per_replica_requests"])  # both took load
    assert sum(st["per_replica_requests"]) == len(prompts)
    # replica weight fan-out went over an on-demand channel: decode/1 got
    # its params from decode/0, not from init
    kinds = [(e.get("kind"), e["src"], e["dst"]) for e in sup.events
             if e["op"] == "open_channel"]
    assert ("array", "decode/0", "decode/1") in kinds

    # outputs identical to a single-cell reference on the same weights
    dec = sup.cells["decode/0"]
    ref_bat = ContinuousBatcher(dec.model, dec.serve_params, batch_slots=2,
                                max_len=MAX_LEN, prefill_chunk=None)
    for r in _requests(prompts, max_new=3):
        ref_bat.submit(r)
    ref = {r.rid: r.output for r in ref_bat.run_until_drained()}
    assert {i: done[i].output for i in done} == ref


def test_failed_replica_requeues_orphans(model_and_params):
    """Regression: a decode replica dying mid-flight used to leak its
    inflight/slotted requests — _busy() stayed true and
    run_until_drained spun to max_steps doing nothing.  The orphans must
    requeue onto pending and finish on the surviving replica."""
    from repro.serve.disagg import DisaggServer

    model, _ = model_and_params
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=3,
                                allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("prefill", cfg, "serve", ncols=1)
    sup.create_cell("dec0", cfg, "serve", ncols=1).init_serve(
        rng=jax.random.PRNGKey(0))
    sup.create_cell("dec1", cfg, "serve", ncols=1)
    srv = DisaggServer(sup, "prefill", ["dec0", "dec1"], batch_slots=2,
                       max_len=MAX_LEN, chunk=16)
    prompts = _prompts(cfg.vocab, [9, 33, 17, 21, 40, 12])
    for r in _requests(prompts, max_new=4):
        srv.submit(r)
    srv.step()
    srv.step()                           # both replicas now hold live slots
    victim = srv.replicas[1].cell
    assert any(s is not None for s in srv.replicas[1].batcher.slot_req)
    sup.fail_column(0, victim.zone.c0)   # kill dec1's column mid-decode
    done = {r.rid: r for r in srv.run_until_drained(max_steps=2_000)}
    assert set(done) == set(range(len(prompts)))          # nothing lost
    assert all(len(done[i].output) == 4 for i in done)    # fully served
    assert [rep.cell.name for rep in srv.replicas] == ["dec0"]
    assert srv.requeued >= 1             # the orphans went back to pending
    assert not srv.pending and not srv.replicas[0].inflight
    # stats keep the detached replica's history: every prefilled request
    # crossed a KV channel exactly once (originals + requeued re-sends)
    st = srv.stats()
    assert st["kv_transfers"] == len(prompts) + srv.requeued
    assert st["requests_detached"] + sum(st["per_replica_requests"]) == \
        len(prompts)


def test_sync_attach_detach_roundtrip(model_and_params):
    """Scale the decode spec 3 -> 2 -> 3: sync detaches the vanished
    instance (requeueing what it held) and re-attaches the recreated one
    (fresh KV channel + weight fan-out + batcher); every request
    finishes."""
    import dataclasses

    from repro.core import CellSpec, ChannelSpec, ClusterSpec
    from repro.serve.disagg import DisaggServer

    model, _ = model_and_params
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=4,
                                allow_reuse=True)
    sup = Supervisor(grid)
    spec = ClusterSpec(
        cells=(CellSpec("prefill", cfg, "serve", ncols=1),
               CellSpec("decode", cfg, "serve", ncols=1, replicas=3,
                        min_replicas=1, max_replicas=3)),
        channels=(ChannelSpec("prefill", "decode", kind="kv"),),
    )
    sup.apply(spec)
    sup.cells["decode/0"].init_serve(rng=jax.random.PRNGKey(0))
    srv = DisaggServer(sup, "prefill", spec.cell("decode").instances(),
                       batch_slots=2, max_len=MAX_LEN, chunk=16)
    for r in _requests(_prompts(cfg.vocab, [9, 33, 17, 21]), max_new=4):
        srv.submit(r)
    hb_before = srv.prefill_cell.last_heartbeat
    srv.step()                           # spread slots across replicas
    # a serving step keeps the PREFILL cell's heartbeat fresh too — else
    # a daemon would spuriously recover it during long decode phases
    assert srv.prefill_cell.last_heartbeat > hb_before
    held = sum(1 for s in srv.replicas[2].batcher.slot_req if s is not None)
    assert held >= 1                     # the victim holds live requests

    # scale down: reconcile destroys decode/2, sync detaches + requeues
    sup.apply(sup.desired.with_cell(
        dataclasses.replace(sup.desired.cell("decode"), replicas=2)))
    out = srv.sync(sup.desired)
    assert out["detached"] == ["decode/2"] and out["requeued"] == held
    assert sorted(r.cell.name for r in srv.replicas) == ["decode/0", "decode/1"]

    # scale back up: reconcile recreates decode/2, sync re-attaches it
    sup.apply(sup.desired.with_cell(
        dataclasses.replace(sup.desired.cell("decode"), replicas=3)))
    out = srv.sync(sup.desired)
    assert out["attached"] == ["decode/2"]
    rep = srv.replicas[-1]
    assert rep.cell is sup.cells["decode/2"]
    assert rep.cell.serve_params is not None          # weight fan-out ran
    assert sup.find_channel("prefill", "decode/2", "kv") is rep.channel
    done = {r.rid: r.output for r in srv.run_until_drained(max_steps=2_000)}
    assert set(done) == {0, 1, 2, 3}
    assert all(len(v) == 4 for v in done.values())


def test_recover_serve_role_restores_params(model_and_params, tmp_path):
    """Regression: recover_cell(ckpt_dir=...) used to build a TRAIN state
    target even for role='serve' cells (leaf-count mismatch), and an
    empty ckpt_dir skipped restore with no trace."""
    from repro.checkpoint import checkpoint as ckpt

    model, _ = model_and_params
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=2,
                                allow_reuse=True)
    sup = Supervisor(grid)
    cell = sup.create_cell("srv", cfg, "serve", ncols=1)
    cell.init_serve(rng=jax.random.PRNGKey(0))
    ref = [np.asarray(x) for x in jax.tree.leaves(cell.serve_params)]
    ckpt.save(str(tmp_path), 7, cell.serve_params)

    cell.status = "failed"
    rec = sup.recover_cell("srv", ckpt_dir=str(tmp_path))
    assert rec.status == "running" and rec.step == 7
    got = [np.asarray(x) for x in jax.tree.leaves(rec.serve_params)]
    assert len(ref) == len(got)
    assert all(np.array_equal(a, b) for a, b in zip(ref, got))
    assert any(e["op"] == "restore_ckpt" for e in sup.events)

    # no checkpoint at the declared dir: loud event, cell back empty
    rec.status = "failed"
    rec2 = sup.recover_cell("srv", ckpt_dir=str(tmp_path / "empty"))
    assert rec2.serve_params is None
    assert any(e["op"] == "recover_no_ckpt" for e in sup.events)


def test_daemon_e2e_kill_recover_reattach(model_and_params, tmp_path):
    """Acceptance: with traffic flowing, fail_column on a decode replica
    -> the daemon recovers the cell (checkpoint-restored via the spec's
    ckpt_dir), DisaggServer.sync re-attaches it, no request is lost and
    the SLO tail reconverges — zero direct primitive calls here."""
    from repro.checkpoint import checkpoint as ckpt
    from repro.core import (
        CellSpec,
        ChannelSpec,
        ClusterSpec,
        SLOTarget,
        SupervisorDaemon,
    )
    from repro.serve.disagg import DisaggServer

    model, _ = model_and_params
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=4,
                                allow_reuse=True)
    sup = Supervisor(grid)
    slo = SLOTarget(ttft_p99=60.0, tpot_p99=60.0)    # generous: CI wall-clock
    spec = ClusterSpec(
        cells=(CellSpec("prefill", cfg, "serve", ncols=1),
               CellSpec("decode", cfg, "serve", ncols=1, replicas=2,
                        min_replicas=2, max_replicas=2, slo=slo,
                        ckpt_dir=str(tmp_path))),
        channels=(ChannelSpec("prefill", "decode", kind="kv"),),
    )
    sup.apply(spec)                      # 1 spare column for recovery
    sup.cells["decode/0"].init_serve(rng=jax.random.PRNGKey(0))
    srv = DisaggServer(sup, "prefill", spec.cell("decode").instances(),
                       batch_slots=2, max_len=MAX_LEN, chunk=16)
    ckpt.save(str(tmp_path), 3, sup.cells["decode/0"].serve_params)

    daemon = SupervisorDaemon(sup)
    daemon.attach_server(srv)
    pol = daemon.add_slo_policy("decode", autoscale_replicas=True,
                                queue_depth=lambda: len(srv.pending),
                                queue_high=64)

    prompts = _prompts(cfg.vocab, [9, 33, 17, 21, 40, 12, 28, 35])
    for r in _requests(prompts, max_new=4):
        srv.submit(r)
    for _ in range(2):                   # traffic flows, daemon in the loop
        srv.step()
        daemon.tick()
    victim = srv.replicas[1].cell
    affected = sup.fail_column(0, victim.zone.c0)     # the fault, not an op
    assert victim.name in affected

    done = {r.rid: r for r in srv.run_until_drained(max_steps=2_000,
                                                    on_step=daemon.tick)}
    # no request lost, every one fully served
    assert set(done) == set(range(len(prompts)))
    assert all(len(done[i].output) == 4 for i in done)
    # daemon recovered the cell and sync re-attached it
    assert sorted(rep.cell.name for rep in srv.replicas) == \
        ["decode/0", "decode/1"]
    assert sup.cells[victim.name] is not victim       # fresh cell object
    assert all(rep.cell.status == "running" for rep in srv.replicas)
    ops = [e["op"] for e in sup.events]
    assert "recover" in ops or "create" in ops[ops.index("fail_column"):]
    # ...with its params restored from the declared ckpt_dir
    assert any(e["op"] == "restore_ckpt" and e["cell"] == victim.name
               for e in sup.events)
    # SLO tail reconverged: fresh post-recovery traffic lands inside the
    # declared objective
    for i, p in enumerate(_prompts(cfg.vocab, [11, 22], seed=1)):
        srv.submit(Request(rid=100 + i, prompt=p, max_new_tokens=4))
    srv.run_until_drained(max_steps=2_000, on_step=daemon.tick)
    pol.pull()
    tail = pol.replica_tail()
    assert tail is not None and tail < slo.tpot_p99
    # the whole episode ran through the declarative plane: reconcile is
    # converged and nothing outside core/ touched a primitive
    assert sup.reconcile().empty


def test_daemon_recovers_prefill_cell(model_and_params):
    """A recovered PREFILL cell must be rebound (weight fan-out + fresh
    worker), not left computing on the dead cell's released zone while
    the new cell never heartbeats and thrashes failed forever."""
    from repro.core import (
        CellSpec,
        ChannelSpec,
        ClusterSpec,
        SupervisorDaemon,
    )
    from repro.serve.disagg import DisaggServer

    model, _ = model_and_params
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=4,
                                allow_reuse=True)
    sup = Supervisor(grid)
    spec = ClusterSpec(
        cells=(CellSpec("prefill", cfg, "serve", ncols=1),
               CellSpec("decode", cfg, "serve", ncols=1, replicas=2,
                        min_replicas=2, max_replicas=2)),
        channels=(ChannelSpec("prefill", "decode", kind="kv"),),
    )
    sup.apply(spec)
    sup.cells["decode/0"].init_serve(rng=jax.random.PRNGKey(0))
    srv = DisaggServer(sup, "prefill", spec.cell("decode").instances(),
                       batch_slots=2, max_len=MAX_LEN, chunk=16)
    daemon = SupervisorDaemon(sup)
    daemon.attach_server(srv)
    prompts = _prompts(cfg.vocab, [9, 33, 17, 21])
    for r in _requests(prompts, max_new=4):
        srv.submit(r)
    srv.step()
    daemon.tick()
    old_prefill = srv.prefill_cell
    sup.fail_column(0, old_prefill.zone.c0)       # kill the PREFILL column
    done = {r.rid: r for r in srv.run_until_drained(max_steps=2_000,
                                                    on_step=daemon.tick)}
    assert set(done) == set(range(len(prompts)))
    assert all(len(done[i].output) == 4 for i in done)
    assert srv.prefill_cell is not old_prefill    # rebound to the new cell
    assert srv.prefill_cell is sup.cells["prefill"]
    assert srv.worker.cell is srv.prefill_cell
    assert srv.prefill_cell.serve_params is not None
    assert sorted(rep.cell.name for rep in srv.replicas) == \
        ["decode/0", "decode/1"]
    assert sup.reconcile().empty


def _family_requests(cfg, lens, max_new=4, seed=0):
    rng = np.random.RandomState(seed)
    out = []
    for i, L in enumerate(lens):
        src = (rng.randn(5 + 3 * i, cfg.d_model).astype(np.float32)
               if cfg.family == "encdec" else None)
        out.append(Request(rid=i, max_new_tokens=max_new, src=src,
                           prompt=rng.randint(1, cfg.vocab, size=L)
                           .astype(np.int32)))
    return out


@pytest.mark.parametrize(
    "arch", ["mamba2-2.7b", "zamba2-2.7b", "seamless-m4t-large-v2"])
def test_disagg_e2e_all_families(arch):
    """Acceptance: ssm / hybrid / encdec run the FULL disaggregated plane
    (chunked prefill cell -> KV/state handoff -> decode replicas, daemon
    ticking) with outputs identical to a single-cell token-at-a-time
    reference — no family gate, no NotImplementedError anywhere."""
    from repro.core import CellSpec, ChannelSpec, ClusterSpec, SupervisorDaemon
    from repro.serve.disagg import DisaggServer

    cfg = smoke_config(get_arch(arch))
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=3,
                                allow_reuse=True)
    sup = Supervisor(grid)
    spec = ClusterSpec(
        cells=(CellSpec("prefill", cfg, "serve", ncols=1),
               CellSpec("decode", cfg, "serve", ncols=1, replicas=2)),
        channels=(ChannelSpec("prefill", "decode", kind="kv"),),
    )
    sup.apply(spec)
    sup.cells["decode/0"].init_serve(rng=jax.random.PRNGKey(0))
    srv = DisaggServer(sup, "prefill", spec.cell("decode").instances(),
                       batch_slots=2, max_len=MAX_LEN, chunk=16)
    daemon = SupervisorDaemon(sup)
    daemon.attach_server(srv)
    lens = [3, 33, 17, 40, 9]
    for r in _family_requests(cfg, lens):
        srv.submit(r)
    done = {r.rid: r.output for r in srv.run_until_drained(
        max_steps=2_000, on_step=daemon.tick)}
    assert set(done) == set(range(len(lens)))
    st = srv.stats()
    assert st["prefill_chunked"] and srv.worker.invocations > 0
    assert st["prefill_fallback_requests"] == 0
    assert st["kv_transfers"] == len(lens)   # every request crossed a channel

    dec = sup.cells["decode/0"]
    ref_bat = ContinuousBatcher(dec.model, dec.serve_params, batch_slots=2,
                                max_len=MAX_LEN, prefill_chunk=None)
    for r in _family_requests(cfg, lens):
        ref_bat.submit(r)
    ref = {r.rid: r.output for r in ref_bat.run_until_drained()}
    assert done == ref


def test_swa_rolling_cache_falls_back_not_crashes():
    """Satellite: sliding_window < max_len has no exact chunked prefill
    (the rolling buffer would shift real tokens out behind the pad tail).
    The batcher silently degrades to token-at-a-time; DisaggServer used
    to CRASH in PrefillWorker.__init__ on the very same config.  It must
    now serve every request token-at-a-time with an accounting event,
    outputs identical to the colocated degraded reference."""
    from repro.serve.disagg import DisaggServer, PrefillWorker
    from repro.serve.serve_step import supports_chunked_prefill

    cfg = smoke_config(get_arch("mixtral-8x7b"))   # window=64 in smoke
    assert cfg.sliding_window == 64
    max_len = 96
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=2,
                                allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("prefill", cfg, "serve", ncols=1)
    dec = sup.create_cell("decode", cfg, "serve", ncols=1)
    dec.init_serve(rng=jax.random.PRNGKey(0))
    assert not supports_chunked_prefill(dec.model, max_len)
    with pytest.raises(ValueError):
        PrefillWorker(dec, max_len=max_len)        # the old crash, scoped
    srv = DisaggServer(sup, "prefill", "decode", batch_slots=2,
                       max_len=max_len, chunk=16)
    assert srv.worker is None                      # degraded, not dead
    prompts = _prompts(cfg.vocab, [9, 33, 70])
    for r in _requests(prompts, max_new=3):
        srv.submit(r)
    done = {r.rid: r.output for r in srv.run_until_drained(max_steps=5_000)}
    assert set(done) == {0, 1, 2}
    st = srv.stats()
    assert not st["prefill_chunked"] and st["prefill_invocations"] == 0
    assert st["prefill_fallback_requests"] == len(prompts)
    acc = sup.cells["prefill"].accounting.counters
    assert acc["prefill_fallback"] == 1

    ref_bat = ContinuousBatcher(dec.model, dec.serve_params, batch_slots=2,
                                max_len=max_len, prefill_chunk=16)
    assert not ref_bat.chunked                     # same silent degrade
    for r in _requests(prompts, max_new=3):
        ref_bat.submit(r)
    ref = {r.rid: r.output for r in ref_bat.run_until_drained()}
    assert done == ref


def test_prefill_dummy_row_waste_accounted(model_and_params):
    """Satellite: prefill batch dims pad to powers of two; the dummy rows
    are real prefill compute and must surface in CellAccounting."""
    from repro.serve.disagg import DisaggServer

    model, _ = model_and_params
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=2,
                                allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("prefill", cfg, "serve", ncols=1)
    sup.create_cell("decode", cfg, "serve", ncols=1).init_serve(
        rng=jax.random.PRNGKey(0))
    srv = DisaggServer(sup, "prefill", "decode", batch_slots=4,
                       max_len=MAX_LEN, chunk=16)
    for r in _requests(_prompts(cfg.vocab, [33, 35, 40]), max_new=2):
        srv.submit(r)                              # one bucket-48 group of 3
    srv.run_until_drained()
    assert srv.worker.invocations == 1             # batched into ONE program
    counters = sup.cells["prefill"].accounting.counters
    assert counters["prefill_dummy_rows"] == 1     # b_pad 4 - 3 real rows


def test_disagg_unservable_prompts_do_not_stall_the_loop(model_and_params):
    """An empty or cache-overflowing prompt must finish (empty output)
    instead of raising mid-pump and starving every other request."""
    from repro.serve.disagg import DisaggServer

    model, _ = model_and_params
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=2,
                                allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("prefill", cfg, "serve", ncols=1)
    sup.create_cell("decode", cfg, "serve", ncols=1).init_serve(
        rng=jax.random.PRNGKey(0)
    )
    srv = DisaggServer(sup, "prefill", "decode", batch_slots=2,
                       max_len=32, chunk=8)
    good = _prompts(cfg.vocab, [5])[0]
    srv.submit(Request(rid=0, prompt=np.array([], np.int32), max_new_tokens=3))
    srv.submit(Request(rid=1, prompt=good, max_new_tokens=3))
    srv.submit(Request(rid=2, prompt=np.ones(40, np.int32), max_new_tokens=3))
    done = {r.rid: r.output for r in srv.run_until_drained()}
    assert set(done) == {0, 1, 2}
    assert done[0] == [] and done[2] == [] and len(done[1]) == 3
    # rejected requests never reached a replica: per-replica stats and the
    # decode cell's accounting only count routed traffic
    st = srv.stats()
    assert sum(st["per_replica_requests"]) == 1
    assert st["decode_serving"]["requests"] == 3   # front-door view keeps all
    assert len(srv.rejected) == 2
