"""Layout switches: zero3, sharded decode, serve-fsdp, opt levels."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import smoke_config, with_opt_level
from repro.configs.registry import ARCHS, get_arch
from repro.models.model import build_model
from repro.sharding.rules import ShardCtx, single_device_ctx


def test_opt_level_roundtrip():
    a = get_arch("qwen3-4b")
    assert a.train_layout == "zero3"
    base = with_opt_level(a, False)
    assert base.train_layout == "tp" and not base.sharded_decode and base.serve_fsdp
    opt = with_opt_level(a, True)
    assert opt.sharded_decode and opt.train_layout == "zero3"


def test_zero3_rules_single_device():
    ctx = single_device_ctx()
    z = ShardCtx(mesh=ctx.mesh, batch_axes=("data",), model_axis="model",
                 dp_over_model=True)
    r = z.rules()
    assert r["heads"] == () and r["ffn"] == ()
    assert "model" in r["batch"]
    assert r["vocab"] == ("model",)
    # dp_size counts the model axis in zero3
    assert z.dp_size() == 1


def test_zero3_loss_matches_tp_single_device():
    """Layouts are semantics-preserving: same loss on one device."""
    cfg_tp = smoke_config(get_arch("qwen3-4b")).replace(train_layout="tp")
    ctx = single_device_ctx()
    model_tp = build_model(cfg_tp, ctx)
    ctx_z3 = ShardCtx(mesh=ctx.mesh, batch_axes=("data",), model_axis="model",
                      dp_over_model=True)
    model_z3 = build_model(cfg_tp, ctx_z3)
    params = model_tp.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg_tp.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg_tp.vocab),
    }
    l1, _ = jax.jit(model_tp.loss)(params, batch)
    l2, _ = jax.jit(model_z3.loss)(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-3, (float(l1), float(l2))


def test_chunked_xent_matches_full():
    """The seq-chunked remat'd head equals the monolithic head."""
    cfg = smoke_config(get_arch("qwen3-4b"))
    ctx = single_device_ctx()
    ctx_z3 = ShardCtx(mesh=ctx.mesh, batch_axes=("data",), model_axis="model",
                      dp_over_model=True)
    model = build_model(cfg, ctx_z3)
    params = model.init(jax.random.PRNGKey(0))
    # force the chunked path with a long-enough sequence
    B, S = 1, 2048
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab),
    }
    l_chunked, _ = jax.jit(model.loss)(params, batch)

    model_full = build_model(cfg, ctx)        # tp ctx -> monolithic head
    l_full, _ = jax.jit(model_full.loss)(params, batch)
    assert abs(float(l_chunked) - float(l_full)) < 1e-3


@pytest.mark.parametrize("name", ["mixtral-8x7b", "mamba2-2.7b"])
def test_optimized_smoke_all_families(name):
    """Optimized flags keep every family runnable on one device."""
    cfg = with_opt_level(smoke_config(ARCHS[name]), True)
    ctx = single_device_ctx()
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.zeros((2, 32), jnp.int32),
        "labels": jnp.zeros((2, 32), jnp.int32),
    }
    loss, _ = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss)
    # decode path with sharded_decode=True falls back gracefully on 1 device
    cache = model.init_cache(2, 32)
    logits, _ = jax.jit(model.decode)(
        params, cache,
        {"tokens": jnp.zeros((2, 1), jnp.int32), "pos": jnp.zeros((2,), jnp.int32)})
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
