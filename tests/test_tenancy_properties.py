"""Hypothesis drivers for the tenancy QoS property checkers.

The checkers themselves live in ``test_tenancy.py`` (where they also
run on a seeded driver without the dep); here hypothesis explores the
same invariants adversarially:

  * DRR weighted-service bound — no quantum/weight/cost/budget mix lets
    one backlogged tenant outrun another by more than one quantum plus
    one maximal request;
  * KVPool pocket accounting — charges balance the arena, quotas bind,
    covered allocations never fail, all charges drain to zero.
"""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from test_tenancy import (  # noqa: E402
    _quota_pool,
    check_drr_weighted_service_bound,
    check_pool_quota_accounting_balances,
)


def _draws(data):
    return (lambda lo, hi: data.draw(st.integers(lo, hi)),
            lambda seq: data.draw(st.sampled_from(list(seq))))


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_drr_weighted_service_bound(data):
    check_drr_weighted_service_bound(*_draws(data))


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_pool_quota_accounting_balances(data):
    check_pool_quota_accounting_balances(_quota_pool(), *_draws(data))
