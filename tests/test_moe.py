"""MoE block numerics vs a dense (no-capacity) reference."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.moe import moe_block, moe_specs, use_ep
from repro.models.param import init_params
from repro.sharding.rules import single_device_ctx


def _cfg(E=8, k=2, shared=0):
    return ArchConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
        moe=MoEConfig(num_experts=E, top_k=k, d_expert=48,
                      num_shared=shared, d_shared=48,
                      capacity_factor=8.0),   # ample: no drops
    )


def _dense_ref(p, x, cfg):
    """Route + compute every expert densely, weight by normalized top-k."""
    B, S, D = x.shape
    xf = x.reshape(-1, D).astype(jnp.float32)
    logits = xf @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(probs, cfg.moe.top_k)
    topv = topv / topv.sum(-1, keepdims=True)
    y = jnp.zeros_like(xf)
    for e in range(cfg.moe.num_experts):
        h = jax.nn.silu(xf @ p["w_gate"][e].astype(jnp.float32)) * (
            xf @ p["w_up"][e].astype(jnp.float32))
        o = h @ p["w_down"][e].astype(jnp.float32)
        w = jnp.where(topi == e, topv, 0.0).sum(-1)
        y = y + o * w[:, None]
    if cfg.moe.num_shared:
        h = jax.nn.silu(xf @ p["ws_gate"].astype(jnp.float32)) * (
            xf @ p["ws_up"].astype(jnp.float32))
        y = y + h @ p["ws_down"].astype(jnp.float32)
    return y.reshape(B, S, D)


@pytest.mark.parametrize("E,k,shared", [(8, 2, 0), (16, 6, 2), (4, 1, 1)])
def test_moe_matches_dense_reference(E, k, shared):
    cfg = _cfg(E, k, shared)
    ctx = single_device_ctx()
    p = init_params(moe_specs(cfg, ctx), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    y, aux = moe_block(p, x, cfg, ctx, train=True)
    ref = _dense_ref(p, x, cfg)
    rel = float(jnp.abs(y - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 1e-4, rel
    assert float(aux) >= 1.0 - 1e-3   # Switch LB loss lower bound is 1.0


def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor some tokens are dropped (output smaller
    in norm than the dropless reference) but nothing NaNs."""
    cfg = _cfg(8, 2).replace(moe=MoEConfig(8, 2, 48, capacity_factor=0.25))
    ctx = single_device_ctx()
    p = init_params(moe_specs(cfg, ctx), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32), jnp.float32)
    y, aux = moe_block(p, x, cfg, ctx, train=True)
    ref = _dense_ref(p, x, cfg)
    assert jnp.isfinite(y).all()
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(ref))


def test_moe_grad_flows_to_all_parts():
    cfg = _cfg(8, 2, shared=1)
    ctx = single_device_ctx()
    p = init_params(moe_specs(cfg, ctx), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)

    def loss(p):
        y, aux = moe_block(p, x, cfg, ctx, train=True)
        return (y ** 2).sum() + 0.01 * aux

    g = jax.grad(loss)(p)
    for k, v in g.items():
        assert float(jnp.abs(v).sum()) > 0, f"no grad for {k}"


def test_use_ep_divisibility():
    ctx = single_device_ctx()   # model_size == 1 -> EP trivially
    assert use_ep(_cfg(8, 2), ctx)
