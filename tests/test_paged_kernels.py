"""Parity tests for the native paged attention kernels (interpret mode).

The fixtures honour the absolute-position page layout the kernels rely
on: logical page ``j`` of a row holds positions ``[j*P, (j+1)*P)``, the
block table maps logical pages to physical arena pages, unmapped entries
are the sentinel (``>= N``), and spare physical pages stay clean
(``slot_pos == -1``) so the kernels' sentinel clamp-to-``N-1`` masks
them.  Model-level token identity for moe / encdec-cross layouts is
covered by ``tests/test_kvpool.py``; this file checks the kernels
directly against their pure-jnp refs and a dense oracle, across head
layouts (MHA / GQA / MQA), multi-layer arenas, ragged lengths, page
sizes that do not divide the sequence length, sentinel pages, and int8
per-(page, layer) scales.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import (
    decode_attention_ref,
    paged_decode_attention,
    paged_decode_attention_ref,
)
from repro.kernels.flash_attention import (
    attention_ref,
    paged_extend_attention,
    paged_extend_attention_ref,
)
from repro.models.cache_utils import dequantize_page, quantize_page


def _rel(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9))


def _build_arena(key, B, Hkv, Dh, L, P, n_log, kv_lens):
    """Layout-consistent arena: page j of row b holds positions
    [j*P, min((j+1)*P, kv_len)); the last physical page stays clean."""
    N = B * n_log + 2
    kk, vk = jax.random.split(key)
    k = jax.random.normal(kk, (N, P, L, Hkv, Dh), jnp.float32)
    v = jax.random.normal(vk, (N, P, L, Hkv, Dh), jnp.float32)
    sp = np.full((N, P, L), -1, np.int32)
    bt = np.full((B, n_log), N, np.int32)
    nxt = 0
    for b, kl in enumerate(kv_lens):
        for j in range(-(-kl // P)):
            ph = nxt
            nxt += 1
            fill = min(P, kl - j * P)
            sp[ph, :fill, :] = (j * P + np.arange(fill))[:, None]
            bt[b, j] = ph
    assert nxt < N - 1  # keep the clamp target page clean
    return k, v, jnp.asarray(sp), jnp.asarray(bt)


def _dense_view(k_arena, v_arena, bt, li):
    """Gather (B, n_log*P, Hkv, Dh) dense caches; by the absolute-position
    layout, slot index == position, so kv_len masking is exact."""
    N, P = k_arena.shape[0], k_arena.shape[1]
    B, n_log = bt.shape
    btc = jnp.minimum(bt, N - 1)
    kd = k_arena[:, :, li][btc].reshape(B, n_log * P, *k_arena.shape[3:])
    vd = v_arena[:, :, li][btc].reshape(B, n_log * P, *v_arena.shape[3:])
    return kd, vd


DECODE_CASES = [
    # (B, Hq, Hkv, Dh, L, P, n_log, kv_lens)
    (2, 4, 4, 64, 1, 8, 4, (32, 17)),   # MHA, ragged, P does not divide len
    (3, 8, 2, 32, 3, 8, 4, (8, 29, 1)),  # GQA, multi-layer, sentinel tails
    (2, 4, 1, 16, 2, 16, 2, (5, 32)),   # MQA
]


@pytest.mark.parametrize("B,Hq,Hkv,Dh,L,P,n_log,kv_lens", DECODE_CASES)
def test_paged_decode_matches_ref(B, Hq, Hkv, Dh, L, P, n_log, kv_lens):
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    k, v, sp, bt = _build_arena(keys[0], B, Hkv, Dh, L, P, n_log, kv_lens)
    q = jax.random.normal(keys[1], (B, 1, Hq, Dh), jnp.float32)
    kv_len = jnp.asarray(kv_lens, jnp.int32)
    li = jnp.int32(L - 1)
    out = paged_decode_attention(q, k, v, sp, bt, kv_len, li)
    ref = paged_decode_attention_ref(q[:, 0], k, v, sp, bt, kv_len, li)
    assert _rel(out[:, 0], ref) < 2e-5
    # dense oracle: gather the block table into a slot-indexed cache
    kd, vd = _dense_view(k, v, bt, L - 1)
    dense = decode_attention_ref(q[:, 0], kd, vd, kv_len)
    assert _rel(ref, dense) < 1e-5


@pytest.mark.parametrize("B,Hq,Hkv,Dh,L,P,n_log,kv_lens", DECODE_CASES)
def test_paged_decode_int8_matches_ref(B, Hq, Hkv, Dh, L, P, n_log, kv_lens):
    keys = jax.random.split(jax.random.PRNGKey(1), 2)
    k, v, sp, bt = _build_arena(keys[0], B, Hkv, Dh, L, P, n_log, kv_lens)
    kq, ks = quantize_page(k, keep_axes=(0, 2))
    vq, vs = quantize_page(v, keep_axes=(0, 2))
    q = jax.random.normal(keys[1], (B, 1, Hq, Dh), jnp.float32)
    kv_len = jnp.asarray(kv_lens, jnp.int32)
    li = jnp.int32(L - 1)
    out = paged_decode_attention(q, kq, vq, sp, bt, kv_len, li,
                                 k_scale=ks, v_scale=vs)
    ref = paged_decode_attention_ref(q[:, 0], kq, vq, sp, bt, kv_len, li,
                                     k_scale=ks, v_scale=vs)
    assert _rel(out[:, 0], ref) < 2e-4
    # dequantized attention stays close to the float arena's answer
    flt = paged_decode_attention_ref(q[:, 0], k, v, sp, bt, kv_len, li)
    assert _rel(ref, flt) < 0.15


def test_paged_decode_fully_sentinel_row_is_finite():
    # A freed / width-trimmed slot maps nothing; its (discarded) output
    # must still be finite so it cannot poison the batch.
    k, v, sp, bt = _build_arena(jax.random.PRNGKey(2), 2, 2, 16, 1, 8, 2,
                                (16, 16))
    bt = bt.at[1].set(jnp.full((2,), k.shape[0], jnp.int32))
    q = jax.random.normal(jax.random.PRNGKey(3), (2, 1, 2, 16), jnp.float32)
    out = paged_decode_attention(q, k, v, sp, bt,
                                 jnp.asarray([16, 1], jnp.int32), jnp.int32(0))
    assert bool(jnp.all(jnp.isfinite(out)))
    assert _rel(out[0], paged_decode_attention_ref(
        q[:, 0], k, v, sp, bt, jnp.asarray([16, 1], jnp.int32),
        jnp.int32(0))[0]) < 2e-5


EXTEND_CASES = [
    # (B, Hq, Hkv, Dh, L, P, n_log, S, pos)
    (2, 4, 4, 32, 1, 8, 4, 8, (0, 16)),   # MHA, page-aligned offsets
    (2, 8, 2, 32, 2, 8, 4, 4, (5, 13)),   # GQA, pos off page boundaries
    (1, 4, 1, 16, 2, 16, 2, 12, (7,)),    # MQA, P does not divide pos+S
]


@pytest.mark.parametrize("B,Hq,Hkv,Dh,L,P,n_log,S,pos", EXTEND_CASES)
def test_paged_extend_matches_ref(B, Hq, Hkv, Dh, L, P, n_log, S, pos):
    # Extend attends after its own suffix is written, so the arena holds
    # positions [0, pos+S) per row.
    kv_lens = tuple(p + S for p in pos)
    keys = jax.random.split(jax.random.PRNGKey(4), 2)
    k, v, sp, bt = _build_arena(keys[0], B, Hkv, Dh, L, P, n_log, kv_lens)
    q = jax.random.normal(keys[1], (B, S, Hq, Dh), jnp.float32)
    pos_a = jnp.asarray(pos, jnp.int32)
    li = jnp.int32(L - 1)
    out = paged_extend_attention(q, k, v, sp, bt, pos_a, li)
    ref = paged_extend_attention_ref(q.transpose(0, 2, 1, 3), k, v, sp, bt,
                                     pos_a, li)
    assert _rel(out, ref.transpose(0, 2, 1, 3)) < 2e-5
    # dense causal oracle per row (suffix queries against [0, pos+S))
    kd, vd = _dense_view(k, v, bt, L - 1)
    for b in range(B):
        kl = kv_lens[b]
        dense = attention_ref(
            q[b:b + 1].transpose(0, 2, 1, 3),
            kd[b:b + 1, :kl].transpose(0, 2, 1, 3),
            vd[b:b + 1, :kl].transpose(0, 2, 1, 3), causal=True)
        assert _rel(out[b], dense[0].transpose(1, 0, 2)) < 2e-5


@pytest.mark.parametrize("B,Hq,Hkv,Dh,L,P,n_log,S,pos", EXTEND_CASES)
def test_paged_extend_int8_matches_ref(B, Hq, Hkv, Dh, L, P, n_log, S, pos):
    kv_lens = tuple(p + S for p in pos)
    keys = jax.random.split(jax.random.PRNGKey(5), 2)
    k, v, sp, bt = _build_arena(keys[0], B, Hkv, Dh, L, P, n_log, kv_lens)
    kq, ks = quantize_page(k, keep_axes=(0, 2))
    vq, vs = quantize_page(v, keep_axes=(0, 2))
    q = jax.random.normal(keys[1], (B, S, Hq, Dh), jnp.float32)
    pos_a = jnp.asarray(pos, jnp.int32)
    li = jnp.int32(L - 1)
    out = paged_extend_attention(q, kq, vq, sp, bt, pos_a, li,
                                 k_scale=ks, v_scale=vs)
    ref = paged_extend_attention_ref(q.transpose(0, 2, 1, 3), kq, vq, sp, bt,
                                     pos_a, li, k_scale=ks, v_scale=vs)
    assert _rel(out, ref.transpose(0, 2, 1, 3)) < 2e-4


def test_quantize_page_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(6), (6, 8, 3, 2, 16))
    x = x * jnp.arange(1, 7, dtype=jnp.float32).reshape(6, 1, 1, 1, 1)
    q, s = quantize_page(x, keep_axes=(0, 2))
    assert q.dtype == jnp.int8 and s.shape == (6, 3)
    deq = dequantize_page(q, s, keep_axes=(0, 2))
    # rounding error per element is bounded by half a quantization step
    amax = jnp.max(jnp.abs(x), axis=(1, 3, 4))
    bound = (amax / 127.0).reshape(6, 1, 3, 1, 1) * 0.5 + 1e-6
    assert bool(jnp.all(jnp.abs(deq - x) <= bound))


def test_quantize_page_zero_group():
    x = jnp.zeros((2, 4, 1, 1, 8))
    q, s = quantize_page(x, keep_axes=(0, 2))
    assert bool(jnp.all(s == 0))
    assert bool(jnp.all(dequantize_page(q, s, keep_axes=(0, 2)) == 0))
