"""Property tests for the PartitionTable (the IFTS shared descriptions)."""
import pytest

pytest.importorskip("hypothesis")  # keep collection alive without the dep

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.partition import PartitionError, PartitionTable


GRID = (2, 16, 16)


def fresh():
    return PartitionTable(grid_shape=GRID)


# ---------------------------------------------------------------------------
# unit semantics
# ---------------------------------------------------------------------------
def test_carve_release_roundtrip():
    t = fresh()
    t, z = t.carve("a", 4)
    assert z.ncols == 4 and t.epoch == 1
    assert t.zone("a") == z
    t = t.release("a")
    assert not t.has_zone("a") and t.epoch == 2


def test_carve_disjoint():
    t = fresh()
    t, za = t.carve("a", 8)
    t, zb = t.carve("b", 8)
    assert not (za.columns() & zb.columns())
    with pytest.raises(PartitionError):
        t.carve("c", 1)           # pod 0 full
    t, zc = t.carve("c", 4, pods=(1,))
    assert zc.pods == (1,)


def test_carve_duplicate_name():
    t = fresh()
    t, _ = t.carve("a", 2)
    with pytest.raises(PartitionError):
        t.carve("a", 2)


def test_resize_grow_shrink():
    t = fresh()
    t, _ = t.carve("a", 4)
    t, z = t.resize("a", 8)
    assert z.ncols == 8
    t, z = t.resize("a", 2)
    assert z.ncols == 2
    t.check_invariants()


def test_resize_refit_when_blocked():
    t = fresh()
    t, _ = t.carve("a", 4)       # cols 0..4
    t, _ = t.carve("b", 4)       # cols 4..8
    # "a" can't grow right (b) — allocator re-carves
    t, z = t.resize("a", 6)
    assert z.ncols == 6
    t.check_invariants()


def test_transfer_preserves_total():
    t = fresh()
    t, _ = t.carve("srv", 4)
    t, _ = t.carve("bat", 8)
    t, zs, zd = t.transfer("bat", "srv", 2)
    assert zs.ncols == 6 and zd.ncols == 6
    with pytest.raises(PartitionError):
        t.transfer("bat", "srv", 6)     # would leave donor empty


def test_mark_failed_evicts():
    t = fresh()
    t, z = t.carve("a", 4)
    t2 = t.mark_failed(0, z.c0)
    assert not t2.has_zone("a")
    assert (0, z.c0) in t2.failed_columns
    with pytest.raises(PartitionError):
        # carving over the failed column must not happen: 16 free minus 1
        t3 = t2
        for i in range(16):      # can only fit 15 single columns now
            t3, _ = t3.carve(f"z{i}", 1)


def test_mark_restored_reopens_column():
    t = fresh()
    t, z = t.carve("a", 4)
    t = t.mark_failed(0, z.c0)
    assert (0, z.c0) in t.failed_columns
    t2 = t.mark_restored(0, z.c0)
    assert (0, z.c0) not in t2.failed_columns
    assert t2.epoch == t.epoch + 1
    # restored column is allocatable again: 16 single-column carves fit
    t3 = t2
    for i in range(16):
        t3, _ = t3.carve(f"z{i}", 1)
    # restoring a non-failed column is a no-op (same table, same epoch)
    assert t2.mark_restored(0, z.c0) is t2


def test_multipod_zone():
    t = fresh()
    t, z = t.carve("mp", 4, pods=(0, 1))
    assert z.columns() == {(p, c) for p in (0, 1) for c in range(z.c0, z.c1)}


# ---------------------------------------------------------------------------
# property: random op sequences keep invariants + epochs strictly increase
# ---------------------------------------------------------------------------
ops = st.lists(
    st.one_of(
        st.tuples(st.just("carve"), st.integers(0, 9), st.integers(1, 6)),
        st.tuples(st.just("release"), st.integers(0, 9), st.integers(1, 6)),
        st.tuples(st.just("resize"), st.integers(0, 9), st.integers(1, 8)),
        st.tuples(st.just("fail"), st.integers(0, 1), st.integers(0, 15)),
    ),
    max_size=25,
)


@settings(max_examples=200, deadline=None)
@given(ops)
def test_invariants_under_random_ops(seq):
    t = fresh()
    last_epoch = t.epoch
    for op, a, b in seq:
        prev = t
        try:
            if op == "carve":
                t, _ = t.carve(f"z{a}", b)
            elif op == "release":
                t = t.release(f"z{a}")
            elif op == "resize":
                t, _ = t.resize(f"z{a}", b)
            elif op == "fail":
                t = t.mark_failed(a, b)
        except PartitionError:
            continue
        t.check_invariants()
        if t is not prev:   # no-op resize legitimately returns the same table
            assert t.epoch > last_epoch, "every mutation must bump the epoch"
        last_epoch = t.epoch
        # no zone overlaps failed columns
        for z in t.zones:
            assert not (z.columns() & t.failed_columns)
