"""KVPool — paged KV memory + radix-tree prefix sharing.

Covers the cache-plane tentpole (hypothesis property tests live in
``test_kvpool_properties.py`` so this module runs without the dep):

  * block-table gather == the dense rows the pages came from (unmapped
    entries read empty) for dense / moe / encdec cache layouts;
  * the copy-on-write invariant: interned (shared) pages are never
    written by serving traffic;
  * EXACTNESS — prefix-hit serving is token-for-token identical to cold
    serving for dense + moe + encdec, colocated and disaggregated;
  * hardening regressions — pool exhaustion REQUEUES (blocks) instead of
    dropping, and a replica detach releases every page / refcount;
  * pool occupancy as the third replica-autoscale signal.
"""
import numpy as np
import pytest

import jax

from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.models.cache_utils import (
    extract_row_pages,
    gather_pages,
    kv_cache_nodes,
    kv_node_axes,
    page_arena,
    read_arena_pages,
    write_arena_pages,
)
from repro.models.model import build_model
from repro.serve.batcher import ContinuousBatcher, Request
from repro.sharding.rules import single_device_ctx

MAX_LEN = 32
CHUNK = 8
PAGE = 8
N_LOG = MAX_LEN // PAGE

# moe stays DROPLESS (expert capacity never binds) as long as every
# prefill/extend invocation sees <= 64 tokens — the sizes here guarantee
# it, so interned pages are bit-identical across batch compositions and
# the exactness assertions below are deterministic.
FAMILY_ARCHS = ["qwen3-4b", "mixtral-8x7b", "seamless-m4t-large-v2"]

_CACHE = {}


def _model(name):
    if name not in _CACHE:
        cfg = smoke_config(get_arch(name))
        if cfg.sliding_window is not None and cfg.sliding_window < MAX_LEN:
            cfg = cfg.replace(sliding_window=64)
        model = build_model(cfg, single_device_ctx())
        _CACHE[name] = (model, model.init(jax.random.PRNGKey(0)))
    return _CACHE[name]


def _requests(cfg, lens, *, shared=0, max_new=4, seed=0, rid0=0, src_seed=None):
    """Prompts sharing a ``shared``-token prefix (seeded separately)."""
    srng = np.random.RandomState(1234)
    sysp = srng.randint(1, cfg.vocab, size=shared).astype(np.int32)
    rng = np.random.RandomState(seed)
    out = []
    for i, L in enumerate(lens):
        tail = rng.randint(1, cfg.vocab, size=L).astype(np.int32)
        src = None
        if cfg.family == "encdec":
            sr = np.random.RandomState(src_seed if src_seed is not None
                                       else 99)
            src = sr.randn(9, cfg.d_model).astype(np.float32)
        out.append(Request(rid=rid0 + i, prompt=np.concatenate([sysp, tail]),
                           max_new_tokens=max_new, src=src))
    return out


# ---------------------------------------------------------------------------
# property-based: page-indexed gather/scatter roundtrips per cache layout
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_block_table_gather_matches_dense(arch):
    """gather_pages through a block table == the dense rows the pages
    came from; unmapped entries read as empty (slot_pos -1)."""
    model, _ = _model(arch)
    num_pages = 2 * N_LOG + 1
    arena = page_arena(model, num_pages, PAGE)
    axes = kv_node_axes(model, 1, MAX_LEN)
    rng = np.random.RandomState(0)
    cache = jax.tree.map(
        lambda x: jax.numpy.asarray(
            rng.standard_normal(x.shape).astype(np.float32)).astype(x.dtype),
        model.init_cache(2, MAX_LEN))
    bt = np.full((2, N_LOG), num_pages, np.int32)      # all unmapped
    for row in range(2):
        stacks = extract_row_pages(cache, axes, row, 0, N_LOG, PAGE)
        ids = list(range(row * N_LOG, (row + 1) * N_LOG))
        arena = write_arena_pages(arena, ids, stacks)
        bt[row, :] = ids
    bt[1, -1] = num_pages                              # hole in row 1
    dense = gather_pages(arena, axes, jax.numpy.asarray(bt), PAGE)
    src = kv_cache_nodes(cache)
    for node, got, a in zip(src, dense, axes):
        ref_sp = np.moveaxis(np.asarray(node.slot_pos), a, 0).copy()
        got_sp = np.moveaxis(np.asarray(got.slot_pos), a, 0)
        ref_k = np.moveaxis(np.asarray(node.k, np.float32), a, 0).copy()
        got_k = np.moveaxis(np.asarray(got.k, np.float32), a, 0)
        # row 1's last page is unmapped: reads empty (slot_pos -1); row
        # 0 is exact everywhere (k checked on its full row)
        ref_sp[1, ..., -PAGE:] = -1
        assert np.array_equal(got_sp, ref_sp)
        assert np.array_equal(got_k[0], ref_k[0])


# ---------------------------------------------------------------------------
# copy-on-write: shared pages are never written
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_shared_pages_never_written(arch):
    """After a warm wave decodes THROUGH shared pages, the interned page
    bytes are bit-identical to their post-intern snapshot — decode only
    ever writes each slot's private current page."""
    model, params = _model(arch)
    cfg = model.cfg
    bat = ContinuousBatcher(model, params, batch_slots=2, max_len=MAX_LEN,
                            prefill_chunk=CHUNK, page_size=PAGE)
    assert bat.pool is not None
    for r in _requests(cfg, [3, 5], shared=18):
        bat.submit(r)
    bat.run_until_drained()
    pool = bat.pool
    interned = [n.page for n in pool.tree._walk()]
    assert interned, "shared prefix must have been interned"
    before = [np.asarray(leaf).copy()
              for s in read_arena_pages(pool.arena, interned) for leaf in s]
    for r in _requests(cfg, [2, 6], shared=18, seed=7, rid0=10):
        bat.submit(r)
    bat.run_until_drained()
    assert pool.prefix_hit_tokens > 0
    after = [np.asarray(leaf)
             for s in read_arena_pages(pool.arena, interned) for leaf in s]
    for b, a in zip(before, after):
        assert np.array_equal(b, a), "a shared page was written"


# ---------------------------------------------------------------------------
# EXACTNESS: prefix-hit serving == cold serving, token for token
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_prefix_hit_exact_colocated(arch):
    """A warm batcher (tree already holding the shared prefix) must serve
    bit-identical token streams to a cold batcher, for dense + moe +
    encdec — the whole point of chunk-exact interning."""
    model, params = _model(arch)
    cfg = model.cfg

    def fresh():
        return ContinuousBatcher(model, params, batch_slots=2,
                                 max_len=MAX_LEN, prefill_chunk=CHUNK,
                                 page_size=PAGE)

    warm = fresh()
    for r in _requests(cfg, [3, 5, 2], shared=18):       # seeds the tree
        warm.submit(r)
    warm.run_until_drained()
    probe = _requests(cfg, [4, 7], shared=18, seed=5, rid0=10)
    for r in probe:
        warm.submit(r)
    got = {r.rid: r.output for r in warm.run_until_drained()
           if r.rid >= 10}
    assert warm.pool.prefix_hit_tokens >= 2 * 16        # 2 pages x 2 reqs

    cold = fresh()
    for r in _requests(cfg, [4, 7], shared=18, seed=5, rid0=10):
        cold.submit(r)
    ref = {r.rid: r.output for r in cold.run_until_drained()}
    assert cold.pool.prefix_hit_tokens == 0
    assert got == ref, (arch, got, ref)


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_paged_matches_legacy_dense_cache(arch):
    """The paged cache plane (block-table indirection + paged installs)
    serves the same outputs as the legacy dense per-slot cache on a cold
    ragged batch, chunked AND token-at-a-time."""
    model, params = _model(arch)
    cfg = model.cfg
    lens = [3, 17, 1, 20, 9]

    def run(chunk, pool):
        bat = ContinuousBatcher(model, params, batch_slots=2,
                                max_len=MAX_LEN, prefill_chunk=chunk,
                                page_size=PAGE, kv_pool=pool)
        assert (bat.pool is not None) == (pool == "auto")
        for r in _requests(cfg, lens, shared=0):
            bat.submit(r)
        return {r.rid: r.output for r in bat.run_until_drained()}

    assert run(CHUNK, "auto") == run(CHUNK, None), arch
    assert run(None, "auto") == run(None, None), arch


def test_prefix_hit_exact_disagg():
    """Disaggregated: a warm server (both prefill-side and decode-side
    trees populated, only the page suffix crossing the channel) serves
    the same tokens as a cold server, and the savings are visible in
    stats() — hit tokens, kv_bytes_saved, and fewer channel bytes."""
    from repro.core import DeviceGrid, Supervisor
    from repro.serve.disagg import DisaggServer

    model, params = _model("qwen3-4b")
    cfg = model.cfg

    def fresh_server():
        grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1,
                                    cols=3, allow_reuse=True)
        sup = Supervisor(grid)
        sup.create_cell("prefill", cfg, "serve", ncols=1)
        dec = sup.create_cell("dec0", cfg, "serve", ncols=1)
        dec.init_serve(rng=jax.random.PRNGKey(0))
        sup.create_cell("dec1", cfg, "serve", ncols=1)
        return sup, DisaggServer(sup, "prefill", ["dec0", "dec1"],
                                 batch_slots=2, max_len=MAX_LEN,
                                 chunk=CHUNK, page_size=PAGE)

    sup, srv = fresh_server()
    assert srv.worker.pool is not None
    for r in _requests(cfg, [3, 5, 2, 4], shared=18):
        srv.submit(r)
    srv.run_until_drained(max_steps=2_000)
    cold_bytes_wave1 = srv.stats()["kv_bytes"]
    probe = _requests(cfg, [4, 7, 3], shared=18, seed=5, rid0=10)
    for r in probe:
        srv.submit(r)
    got = {r.rid: r.output
           for r in srv.run_until_drained(max_steps=2_000) if r.rid >= 10}
    st = srv.stats()
    assert st["paged_kv"]
    assert st["prefix_hit_tokens"] > 0 and st["kv_bytes_saved"] > 0
    # the warm wave's suffixes crossed the channel, not the shared prefix
    warm_bytes = st["kv_bytes"] - cold_bytes_wave1
    assert warm_bytes < cold_bytes_wave1
    # prefill cell skipped the shared chunks' compute
    pc = sup.cells["prefill"].accounting.counters
    assert pc["prefix_hit_tokens"] > 0

    sup2, srv2 = fresh_server()
    for r in _requests(cfg, [4, 7, 3], shared=18, seed=5, rid0=10):
        srv2.submit(r)
    ref = {r.rid: r.output for r in srv2.run_until_drained(max_steps=2_000)}
    assert got == ref


# ---------------------------------------------------------------------------
# hardening regressions
# ---------------------------------------------------------------------------
def test_pool_exhaustion_requeues_not_drops():
    """Regression: a request whose page allocation fails mid-admission
    must go BACK to the queue head (admission blocks) — not be dropped —
    and must serve once pages free up."""
    model, params = _model("qwen3-4b")
    cfg = model.cfg
    # pool of exactly one request's worst case: the second admit blocks
    bat = ContinuousBatcher(model, params, batch_slots=2, max_len=MAX_LEN,
                            prefill_chunk=CHUNK, page_size=PAGE,
                            pool_pages=N_LOG)
    reqs = _requests(cfg, [20, 20, 20], shared=0, max_new=4)
    for r in reqs:
        bat.submit(r)
    bat.step()
    # only one slot admitted; the others are QUEUED, not dropped
    need = bat.pool.required_pages(20, 4)
    assert sum(1 for s in bat.slot_req if s is not None) == 1
    assert len(bat.queue) == 2 and bat.pool.pages_in_use == need
    done = bat.run_until_drained(max_steps=5_000)
    assert {r.rid for r in done} == {0, 1, 2}            # nothing lost
    assert all(len(r.output) == 4 for r in done)


def test_install_prefilled_blocks_on_exhausted_pool():
    """The disaggregated install path returns False (caller retries)
    instead of overrunning the arena."""
    model, params = _model("qwen3-4b")
    cfg = model.cfg
    bat = ContinuousBatcher(model, params, batch_slots=2, max_len=MAX_LEN,
                            prefill_chunk=CHUNK, page_size=PAGE,
                            pool_pages=N_LOG)
    (r0, r1) = _requests(cfg, [20, 20], shared=0)
    bat.submit(r0)
    bat.step()                                           # r0 owns the arena
    row = model.init_cache(1, MAX_LEN)
    before = bat.pool.pages_in_use
    assert bat.install_prefilled(r1, row, 7) is False
    assert bat.pool.pages_in_use == before               # nothing leaked


def test_pump_blocks_on_replica_pool_pressure():
    """Disagg admission control: when every replica's pool is committed,
    pump defers the overflow to pending (``blocked_on_pool``) and serves
    it once pages free — no request lost, no pool overrun."""
    from repro.core import DeviceGrid, Supervisor
    from repro.serve.disagg import DisaggServer

    model, _ = _model("qwen3-4b")
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=3,
                                allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("prefill", cfg, "serve", ncols=1)
    sup.create_cell("dec0", cfg, "serve", ncols=1).init_serve(
        rng=jax.random.PRNGKey(0))
    sup.create_cell("dec1", cfg, "serve", ncols=1)
    # each replica's pool covers exactly ONE in-flight request
    srv = DisaggServer(sup, "prefill", ["dec0", "dec1"], batch_slots=2,
                       max_len=MAX_LEN, chunk=CHUNK, page_size=PAGE,
                       pool_pages=N_LOG)
    for r in _requests(cfg, [20, 20, 20, 20, 20], shared=0, max_new=4):
        srv.submit(r)
    srv.step()
    assert srv.blocked_on_pool >= 1          # overflow deferred, not sent
    assert len(srv.pending) >= 1
    done = {r.rid for r in srv.run_until_drained(max_steps=5_000)}
    assert done == {0, 1, 2, 3, 4}           # every request served
    for rep in srv.replicas:
        assert rep.pool.pages_in_use == rep.pool.tree.interned


def test_detach_releases_pages_and_decrefs():
    """Regression: detaching a replica mid-flight must release its pool
    pages and decref its interned prefixes — every refcount back to 0,
    no page owned by a vanished slot — while its requests requeue."""
    from repro.core import DeviceGrid, Supervisor
    from repro.serve.disagg import DisaggServer

    model, _ = _model("qwen3-4b")
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=3,
                                allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("prefill", cfg, "serve", ncols=1)
    sup.create_cell("dec0", cfg, "serve", ncols=1).init_serve(
        rng=jax.random.PRNGKey(0))
    sup.create_cell("dec1", cfg, "serve", ncols=1)
    srv = DisaggServer(sup, "prefill", ["dec0", "dec1"], batch_slots=2,
                       max_len=MAX_LEN, chunk=CHUNK, page_size=PAGE)
    for r in _requests(cfg, [3, 5, 2, 4], shared=18, max_new=6):
        srv.submit(r)
    srv.step()
    victim = srv.replicas[1]
    pool = victim.pool
    held = sum(1 for s in victim.batcher.slot_req if s is not None)
    infl = len(victim.inflight)
    assert held + infl >= 1 and pool.pages_in_use > 0
    hit_before = srv.stats()["prefix_hit_tokens"]
    n = srv._detach(victim)
    assert n == held + infl
    # every slot page released; interned cache pages all refcount-0
    assert all(n_.refs == 0 for n_ in pool.tree._walk())
    assert pool.pages_in_use == pool.tree.interned
    assert not any(pool._private) and not any(pool._pocket)
    # detached-replica rollup keeps the pool counters in stats()
    assert srv.stats()["prefix_hit_tokens"] >= hit_before
    done = {r.rid for r in srv.run_until_drained(max_steps=2_000)}
    assert done == {0, 1, 2, 3}                          # nothing lost


def test_pool_occupancy_is_third_autoscale_signal():
    """ReconcilePolicy grows replicas on KV-pool pressure alone, and
    refuses to shrink into a memory squeeze."""
    from benchmarks.simlib import SimSupervisor
    from repro.core import CellSpec, ClusterSpec
    from repro.core.elastic import ElasticPolicy, ReconcilePolicy

    sup = SimSupervisor()
    sup.apply(ClusterSpec(cells=(
        CellSpec("dec", None, "serve", ncols=1, replicas=1, max_replicas=3),)))
    occ = {"v": 0.0}
    pol = ReconcilePolicy(
        sup, "dec",
        replica_policy=ElasticPolicy(lt=0.05, ut=0.2, window=10,
                                     metric="tpot"),
        queue_depth=lambda: 0,
        pool_occupancy=lambda: occ["v"], occupancy_high=0.9)
    # a nearly-full pool grows even with an empty queue and no samples
    occ["v"] = 0.95
    act = pol.maybe_act(now=0.0)
    assert act and act["kind"] == "grow_replicas"
    assert act["pool_occupancy"] == 0.95
    assert sup.desired.cell("dec").replicas == 2
    # comfortably-low tail would shrink — but not while memory is tight
    for i in range(10):
        sup.cells["dec/0"].accounting.record_request(i, tpot=0.01)
    occ["v"] = 0.6
    assert pol.maybe_act(now=1.0) is None
    assert sup.desired.cell("dec").replicas == 2
    # memory relaxed: the shrink goes through
    occ["v"] = 0.1
    act = pol.maybe_act(now=2.0)
    assert act and act["kind"] == "shrink_replicas"
    assert sup.desired.cell("dec").replicas == 1
