"""Checkpoint save/restore: round-trip, async, bf16, cross-structure."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.bfloat16) * 1.5,
              "d": jnp.array(7, jnp.int32)},
    }


def test_roundtrip_exact(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    assert ckpt.latest_step(str(tmp_path)) == 3
    out = ckpt.restore(str(tmp_path), 3, jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_async_save(tmp_path):
    fut = ckpt.save(str(tmp_path), 1, _tree(), blocking=False)
    fut.result(timeout=30)
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_latest_step_ignores_partial(tmp_path):
    ckpt.save(str(tmp_path), 5, _tree())
    os.makedirs(tmp_path / "step_9", exist_ok=True)   # no meta.json: partial
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_leaf_count_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 2, _tree())
    bad_target = {"only": jax.ShapeDtypeStruct((3, 4), jnp.float32)}
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 2, bad_target)


def test_multiple_steps_and_overwrite(tmp_path):
    for s in (10, 20, 30):
        ckpt.save(str(tmp_path), s, _tree())
    assert ckpt.latest_step(str(tmp_path)) == 30
    ckpt.save(str(tmp_path), 30, _tree())   # overwrite OK (atomic replace)
    assert ckpt.latest_step(str(tmp_path)) == 30
