"""Multi-tenant QoS — isolate tenants first, share the cache on demand.

Covers the tenancy tentpole across every layer it touches:

  * spec validation — ``TenantSpec`` contracts on a serving cell,
    reserved names, quota budgets, supervisor cross-cell checks;
  * DRR fairness (property) — between continuously backlogged tenants
    the weighted service gap never exceeds one quantum plus one maximal
    request, regardless of weights/costs/budget;
  * KVPool bulkheads (property) — pocket charges always balance
    (``sum(used) == pages_in_use``, ``used[p] <= quota[p]``), and a
    tenant exhausting its own pocket NEVER fails an allocation another
    tenant's quota covers;
  * scoped sharing — private namespaces miss across tenants; the public
    namespace is hit read-only (foreign leases never intern), and all
    public refcounts return to zero after drain;
  * end-to-end — the single-tenant default overlay is token-identical
    (same outputs, same hit rates) to the pre-tenancy configuration,
    HOL blocking is gone (a pool-blocked head no longer starves a
    small admissible request), and token buckets throttle per tenant.

The two randomized properties run here on a seeded driver (no extra
dependency); ``test_tenancy_properties.py`` re-runs the same checkers
under hypothesis when the dep is available.
"""
import random

import numpy as np
import pytest

import jax

from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.core.spec import SpecError, TenantSpec
from repro.models.model import build_model
from repro.serve.batcher import ContinuousBatcher, Request
from repro.serve.kvpool import (
    KVPool,
    PoolExhausted,
    public_ctx_key,
    request_ctx_key,
)
from repro.serve.tenancy import (
    COMMONS,
    PUBLIC,
    TenantRegistry,
    TenantScheduler,
    TokenBucket,
    request_cost,
)
from repro.sharding.rules import single_device_ctx

MAX_LEN = 32
CHUNK = 8
PAGE = 8
N_LOG = MAX_LEN // PAGE

_CACHE = {}


def _model(name="qwen3-4b"):
    if name not in _CACHE:
        cfg = smoke_config(get_arch(name))
        model = build_model(cfg, single_device_ctx())
        _CACHE[name] = (model, model.init(jax.random.PRNGKey(0)))
    return _CACHE[name]


class FakeReq:
    """Queue entry for scheduler-only tests (no model involved)."""

    def __init__(self, tenant, cost, rid=0):
        self.tenant = tenant
        self.prompt = [0] * (cost - 1)
        self.max_new_tokens = 1
        self.rid = rid

    def __repr__(self):
        return f"FakeReq({self.tenant}, {request_cost(self)})"


def _requests(cfg, lens, *, shared=0, max_new=4, seed=0, rid0=0,
              tenant="default", public=False):
    srng = np.random.RandomState(1234)
    sysp = srng.randint(1, cfg.vocab, size=shared).astype(np.int32)
    rng = np.random.RandomState(seed)
    out = []
    for i, L in enumerate(lens):
        tail = rng.randint(1, cfg.vocab, size=L).astype(np.int32)
        out.append(Request(rid=rid0 + i, prompt=np.concatenate([sysp, tail]),
                           max_new_tokens=max_new, tenant=tenant,
                           public=public))
    return out


# ---------------------------------------------------------------------------
# spec layer
# ---------------------------------------------------------------------------
def test_tenant_spec_validation():
    TenantSpec("paid", weight=4.0, page_quota=0.5, rate=100.0)
    with pytest.raises(SpecError):
        TenantSpec("")                        # empty name
    with pytest.raises(SpecError):
        TenantSpec("a/b")                     # separator in name
    with pytest.raises(SpecError):
        TenantSpec(PUBLIC)                    # reserved namespace
    with pytest.raises(SpecError):
        TenantSpec(COMMONS)
    with pytest.raises(SpecError):
        TenantSpec("t", weight=0.0)
    with pytest.raises(SpecError):
        TenantSpec("t", page_quota=1.5)
    with pytest.raises(SpecError):
        TenantSpec("t", rate=-1.0)
    with pytest.raises(SpecError):
        TenantSpec("t", burst=10.0)           # burst without rate


def test_cell_spec_tenant_validation():
    from repro.core.spec import CellSpec
    cfg = smoke_config(get_arch("qwen3-4b"))
    ts = (TenantSpec("a", page_quota=0.5), TenantSpec("b", page_quota=0.4))
    cell = CellSpec(name="srv", arch=cfg, role="serve", ncols=1, tenants=ts)
    assert cell.tenant("a").page_quota == 0.5 and cell.has_tenant("b")
    assert not cell.has_tenant("c")
    with pytest.raises(SpecError):            # duplicate names
        CellSpec(name="srv", arch=cfg, role="serve", ncols=1,
                 tenants=(TenantSpec("a"), TenantSpec("a")))
    with pytest.raises(SpecError):            # quota fractions over-commit
        CellSpec(name="srv", arch=cfg, role="serve", ncols=1,
                 tenants=(TenantSpec("a", page_quota=0.7),
                          TenantSpec("b", page_quota=0.7)))
    with pytest.raises(SpecError):            # tenants on a train cell
        CellSpec(name="trn", arch=cfg, role="train", ncols=1,
                 tenants=(TenantSpec("a"),))


def test_registry_page_quotas_partition_exactly():
    reg = TenantRegistry([TenantSpec("a", page_quota=0.5),
                          TenantSpec("b", page_quota=0.25),
                          TenantSpec("c")])
    q = reg.page_quotas(10)
    assert q == {"a": 5, "b": 2, COMMONS: 3}
    assert sum(q.values()) == 10              # pockets partition the pool
    # floor never over-commits even on awkward pool sizes
    for n in (1, 3, 7, 13):
        assert sum(reg.page_quotas(n).values()) == n


# ---------------------------------------------------------------------------
# DRR fairness (randomized property; hypothesis wrapper in
# test_tenancy_properties.py)
# ---------------------------------------------------------------------------
def check_drr_weighted_service_bound(draw_int, draw_from):
    """Between tenants backlogged for the whole run, the weighted
    served-work gap is bounded by one quantum plus one maximal request:
    served_a/w_a - served_b/w_b <= q + max(q, maxcost/min_w).  No
    tenant ever banks unbounded credit.

    ``draw_int(lo, hi)`` / ``draw_from(seq)`` abstract the randomness
    source so the same checker runs seeded (here) or under hypothesis.
    """
    from collections import deque

    nt = draw_int(2, 4)
    weights = [draw_from([0.5, 1.0, 2.0, 4.0]) for _ in range(nt)]
    names = [f"t{i}" for i in range(nt)]
    reg = TenantRegistry([TenantSpec(n, weight=w)
                          for n, w in zip(names, weights)])
    quantum = draw_from([16, 64, 256])
    sched = TenantScheduler(reg, quantum=quantum)
    ticks = draw_int(3, 12)
    budget = draw_int(1, 6)
    maxcost = 1
    queue = deque()
    rid = [0]

    def top_up():
        # every tenant keeps >= budget+1 queued: always backlogged
        nonlocal maxcost
        depth = {n: 0 for n in names}
        for r in queue:
            depth[r.tenant] += 1
        for n in names:
            while depth[n] < budget + 1:
                c = draw_int(1, 48)
                maxcost = max(maxcost, c)
                queue.append(FakeReq(n, c, rid[0]))
                rid[0] += 1
                depth[n] += 1

    for _ in range(ticks):
        top_up()
        sched.select(queue, lambda r: True, budget=budget)

    norm = {n: sched.served_cost.get(n, 0.0) / reg.weight(n) for n in names}
    slack = quantum + max(quantum, maxcost / min(weights))
    for a in names:
        for b in names:
            assert norm[a] - norm[b] <= slack + 1e-9, (
                norm, weights, quantum, maxcost)
    # deficits never bank beyond one quantum past a maximal pending request
    for n in names:
        cap = (max((request_cost(r) for r in queue if r.tenant == n),
                   default=0) + quantum * reg.weight(n))
        assert sched.deficit.get(n, 0.0) <= cap + 1e-9


def test_drr_weighted_service_bound_seeded():
    for seed in range(60):
        rng = random.Random(seed)
        check_drr_weighted_service_bound(rng.randint, rng.choice)


def test_drr_scan_past_blocked_head():
    """A resource-blocked request must not head-of-line-block admissible
    requests behind it — same tenant or any other."""
    from collections import deque
    reg = TenantRegistry([])
    sched = TenantScheduler(reg, quantum=1024)
    big = FakeReq("default", 24, rid=0)
    small = FakeReq("default", 4, rid=1)
    queue = deque([big, small])
    admitted = sched.select(queue, lambda r: r is not big, budget=2)
    assert admitted == [small]
    assert list(queue) == [big]               # blocked head stays queued


def test_token_bucket_throttles_only_its_tenant():
    """A drained bucket blocks its own tenant's FIFO in order; the other
    tenant's queue flows; refill re-admits (simulated time)."""
    from collections import deque
    reg = TenantRegistry([TenantSpec("limited", rate=10.0, burst=20.0),
                          TenantSpec("open")])
    sched = TenantScheduler(reg, quantum=1024)
    queue = deque([FakeReq("limited", 15, 0), FakeReq("limited", 15, 1),
                   FakeReq("open", 15, 2)])
    got = sched.select(queue, lambda r: True, budget=8, now=0.0)
    assert [r.rid for r in got] == [0, 2]     # bucket covers one; open flows
    assert sched.throttled.get("limited", 0) >= 1
    got = sched.select(queue, lambda r: True, budget=8, now=0.5)
    assert got == []                          # 0.5s * 10/s = 5 < 15
    got = sched.select(queue, lambda r: True, budget=8, now=2.0)
    assert [r.rid for r in got] == [1]        # refilled

    b = TokenBucket(rate=None, burst=0.0)
    assert b.take(1e9, now=0.0)               # rate=None never throttles


def test_shed_victims_lowest_weight_newest_first():
    reg = TenantRegistry([TenantSpec("paid", weight=4.0),
                          TenantSpec("free", weight=1.0)])
    sched = TenantScheduler(reg)
    q = [FakeReq("free", 4, 0), FakeReq("paid", 4, 1), FakeReq("free", 4, 2),
         FakeReq("paid", 4, 3), FakeReq("free", 4, 4)]
    victims = sched.shed_victims(q, 3)
    assert [v.rid for v in victims] == [4, 2, 0]   # free tier, newest first
    victims = sched.shed_victims(q, 4)
    assert [v.rid for v in victims] == [4, 2, 0, 3]  # then newest paid


# ---------------------------------------------------------------------------
# KVPool bulkheads (randomized property; hypothesis wrapper in
# test_tenancy_properties.py)
# ---------------------------------------------------------------------------
def check_pool_quota_accounting_balances(pool, draw_int, draw_from):
    """Random admit/release traffic across quota'd tenants: pocket
    charges always balance the arena (sum(used) == pages_in_use), no
    pocket exceeds its quota, and an admission the tenant's own pocket
    covers NEVER fails — co-tenant exhaustion cannot leak across the
    bulkhead.  All charges return to zero when the last slot releases."""
    assert sum(pool.quotas.values()) == pool.num_pages
    held = {}
    for _ in range(draw_int(1, 24)):
        op = draw_from(["admit", "release"])
        if op == "admit":
            free_slots = [s for s in range(pool.slots) if s not in held]
            if not free_slots:
                continue
            slot = free_slots[0]
            tenant = draw_from(["a", "b", "c", None])
            plen = draw_int(1, 15)
            need = pool.required_pages(plen, 4)
            covered = need <= pool.available_pages(tenant)
            try:
                pool.admit(slot, pool.empty_lease(), plen, 4, tenant=tenant)
                held[slot] = tenant
            except PoolExhausted:
                # the bulkhead promise: a covered allocation never fails
                assert not covered, (tenant, need, pool.stats())
        elif held:
            slot = draw_from(sorted(held))
            pool.release_slot(slot)
            del held[slot]
        assert sum(pool.used.values()) == pool.pages_in_use
        for p, q in pool.quotas.items():
            assert pool.used[p] <= q, (p, pool.used, pool.quotas)
    for slot in list(held):
        pool.release_slot(slot)
    assert pool.pages_in_use == 0
    assert all(v == 0 for v in pool.used.values())


def _quota_pool():
    model, _ = _model()
    reg = TenantRegistry([TenantSpec("a", page_quota=0.5),
                          TenantSpec("b", page_quota=0.25)])
    return KVPool(model, max_len=MAX_LEN, page_size=PAGE, slots=4,
                  num_pages=2 * N_LOG, quotas=reg.page_quotas)


def test_pool_quota_accounting_balances_seeded():
    for seed in range(25):
        rng = random.Random(seed)
        check_pool_quota_accounting_balances(
            _quota_pool(), rng.randint, rng.choice)


def test_pool_exhausted_tenant_never_starves_cotenant():
    """Tenant A fully commits its pocket; B's first admission (covered
    by B's own quota) still succeeds, while A's next one blocks."""
    model, _ = _model()
    reg = TenantRegistry([TenantSpec("a", page_quota=0.5),
                          TenantSpec("b", page_quota=0.5)])
    pool = KVPool(model, max_len=MAX_LEN, page_size=PAGE, slots=3,
                  num_pages=2 * N_LOG, quotas=reg.page_quotas)
    pool.admit(0, pool.empty_lease(), 28, 4, tenant="a")   # 4 pages: a full
    assert pool.used["a"] == pool.quotas["a"]
    with pytest.raises(PoolExhausted):
        pool.admit(1, pool.empty_lease(), 1, 1, tenant="a")
    pool.admit(2, pool.empty_lease(), 28, 4, tenant="b")   # b unaffected
    assert pool.used["b"] == 4 and pool.pages_in_use == 8


# ---------------------------------------------------------------------------
# scoped sharing: private namespaces, public grant, foreign read-only
# ---------------------------------------------------------------------------
def test_ctx_keys_namespace_tenants():
    default = Request(rid=0, prompt=np.arange(4), max_new_tokens=1)
    assert request_ctx_key(default) is None          # pre-tenancy key
    assert public_ctx_key(default) == ("public",)
    other = Request(rid=1, prompt=np.arange(4), max_new_tokens=1,
                    tenant="acme")
    assert request_ctx_key(other) == ("tenant", "acme")
    pub = Request(rid=2, prompt=np.arange(4), max_new_tokens=1,
                  tenant="acme", public=True)
    assert request_ctx_key(pub) == ("public",)
    assert public_ctx_key(pub) is None               # already public


def test_private_namespaces_do_not_cross_tenants():
    """The same prompt served by two tenants interns twice — tenant B's
    lookups never reach tenant A's private tree."""
    model, params = _model()
    cfg = model.cfg
    bat = ContinuousBatcher(model, params, batch_slots=2, max_len=MAX_LEN,
                            prefill_chunk=CHUNK, page_size=PAGE,
                            tenants=[TenantSpec("a", share_public=False),
                                     TenantSpec("b", share_public=False)])
    for r in _requests(cfg, [3], shared=18, tenant="a"):
        bat.submit(r)
    bat.run_until_drained()
    assert bat.pool.prefix_hit_tokens == 0
    for r in _requests(cfg, [3], shared=18, tenant="b", rid0=10):
        bat.submit(r)
    bat.run_until_drained()
    assert bat.pool.prefix_hit_tokens == 0           # no cross-tenant hit
    owners = {n.owner for n in bat.pool.tree._walk()}
    assert owners == {"a", "b"}                      # both interned privately


def test_public_namespace_shared_read_only():
    """A public request seeds the shared namespace; a granted tenant hits
    it (foreign lease) without interning its own suffix there, and every
    public refcount returns to zero after drain."""
    model, params = _model()
    cfg = model.cfg
    bat = ContinuousBatcher(model, params, batch_slots=2, max_len=MAX_LEN,
                            prefill_chunk=CHUNK, page_size=PAGE,
                            tenants=[TenantSpec("a"),
                                     TenantSpec("b", share_public=False)])
    for r in _requests(cfg, [3], shared=18, tenant="a", public=True):
        bat.submit(r)
    bat.run_until_drained()
    pub_before = [n for n in bat.pool.tree._walk() if n.owner == PUBLIC]
    assert pub_before, "public request must intern under the public root"

    for r in _requests(cfg, [3], shared=18, tenant="a", rid0=10):
        bat.submit(r)
    bat.run_until_drained()
    assert bat.pool.prefix_hit_tokens > 0            # granted: read hit
    pub_after = [n for n in bat.pool.tree._walk() if n.owner == PUBLIC]
    # read-only grant: the hit added NOTHING to the public namespace
    assert len(pub_after) == len(pub_before)

    hits = bat.pool.prefix_hit_tokens
    for r in _requests(cfg, [3], shared=18, tenant="b", rid0=20):
        bat.submit(r)
    bat.run_until_drained()
    assert bat.pool.prefix_hit_tokens == hits        # b has no grant
    assert all(n.refs == 0 for n in bat.pool.tree._walk())


# ---------------------------------------------------------------------------
# end-to-end QoS
# ---------------------------------------------------------------------------
def test_single_tenant_overlay_is_token_identical():
    """Declaring a tenant overlay (weight/quota/bucket) around a
    single-tenant workload changes NOTHING: same tokens, same hit rate —
    the cold path is byte-identical to the pre-tenancy stack."""
    model, params = _model()
    cfg = model.cfg

    def run(**kw):
        bat = ContinuousBatcher(model, params, batch_slots=2,
                                max_len=MAX_LEN, prefill_chunk=CHUNK,
                                page_size=PAGE, **kw)
        for r in _requests(cfg, [3, 5, 2], shared=18):
            bat.submit(r)
        bat.run_until_drained()
        for r in _requests(cfg, [4, 7], shared=18, seed=5, rid0=10):
            bat.submit(r)
        out = {r.rid: r.output for r in bat.run_until_drained()}
        return out, bat.pool.prefix_hit_tokens

    plain, hits_plain = run()
    overlay, hits_overlay = run(tenants=[TenantSpec(
        "default", weight=2.0, page_quota=0.5, rate=1e9)])
    assert plain == overlay
    assert hits_plain == hits_overlay > 0


def test_quota_bulkhead_victim_admits_under_flood():
    """An adversary flooding its own pocket cannot block a victim whose
    pocket covers its allocation — the batcher admits the victim on the
    same tick the adversary saturates."""
    model, params = _model()
    cfg = model.cfg
    bat = ContinuousBatcher(model, params, batch_slots=4, max_len=MAX_LEN,
                            prefill_chunk=CHUNK, page_size=PAGE,
                            pool_pages=2 * N_LOG,
                            tenants=[TenantSpec("victim", page_quota=0.5),
                                     TenantSpec("adv", page_quota=0.5)])
    for r in _requests(cfg, [20] * 4, tenant="adv"):          # 3 pages each
        bat.submit(r)
    for r in _requests(cfg, [20], tenant="victim", rid0=10):
        bat.submit(r)
    bat.step()
    slotted = {bat.slot_req[s].rid for s in range(4)
               if bat.slot_req[s] is not None}
    assert 10 in slotted, "victim must admit despite the adversary flood"
    assert len([r for r in slotted if r < 10]) == 1           # adv: 1 fits
    done = bat.run_until_drained(max_steps=5_000)
    assert {r.rid for r in done} == {0, 1, 2, 3, 10}          # nothing lost


def test_weighted_slots_favor_heavy_tenant():
    """With both tenants backlogged, DRR admits the heavy tenant's
    backlog first: its requests finish earlier on average (everything
    still drains — weights shape ORDER, never starve)."""
    model, params = _model()
    cfg = model.cfg
    bat = ContinuousBatcher(model, params, batch_slots=2, max_len=MAX_LEN,
                            prefill_chunk=CHUNK, page_size=PAGE, quantum=16,
                            tenants=[TenantSpec("paid", weight=3.0),
                                     TenantSpec("free", weight=1.0)])
    for i in range(8):
        bat.submit(_requests(cfg, [6], tenant="paid", rid0=i)[0])
        bat.submit(_requests(cfg, [6], tenant="free", rid0=100 + i)[0])
    done = bat.run_until_drained(max_steps=5_000)
    assert len(done) == 16                    # weights never starve anyone
    rank = {r.rid: i for i, r in enumerate(done)}
    mean_paid = sum(rank[i] for i in range(8)) / 8
    mean_free = sum(rank[100 + i] for i in range(8)) / 8
    assert mean_paid < mean_free, (rank, mean_paid, mean_free)


def test_disagg_tenant_stats_and_shedding():
    """DisaggServer: tenant spec flows from the applied ClusterSpec,
    per-tenant rollups appear in stats(), and overload sheds the
    low-weight tier first (victims finish rejected, not lost)."""
    from repro.core import DeviceGrid, Supervisor
    from repro.serve.disagg import DisaggServer

    model, _ = _model()
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=2,
                                allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("prefill", cfg, "serve", ncols=1)
    dec = sup.create_cell("dec0", cfg, "serve", ncols=1)
    dec.init_serve(rng=jax.random.PRNGKey(0))
    srv = DisaggServer(sup, "prefill", ["dec0"], batch_slots=2,
                       max_len=MAX_LEN, chunk=CHUNK, page_size=PAGE,
                       tenants=[TenantSpec("paid", weight=4.0),
                                TenantSpec("free", weight=1.0)],
                       shed_queue=4)
    for r in _requests(cfg, [4] * 4, tenant="paid"):
        srv.submit(r)
    for r in _requests(cfg, [4] * 4, tenant="free", rid0=100):
        srv.submit(r)
    done = srv.run_until_drained(max_steps=2_000)
    assert len(done) == 8                                    # none lost
    st = srv.stats()
    assert set(st["per_tenant"]) == {"paid", "free"}
    assert st["shed_requests"] == 4
    # shed victims are the newest FREE-tier requests, finished empty
    shed = [r for r in done if not len(r.output)]
    assert {r.tenant for r in shed} == {"free"}
    served = [r for r in done if len(r.output)]
    assert sum(r.tenant == "paid" for r in served) == 4


def test_elastic_policy_filters_by_tenant():
    """A tenant-scoped ReconcilePolicy ingests only that tenant's
    samples — a co-tenant's latency cannot mask (or fake) a violation."""
    from types import SimpleNamespace

    from repro.core.accounting import RequestMetrics
    from repro.core.elastic import ElasticPolicy, ReconcilePolicy

    reqs = [RequestMetrics(rid=i, prompt_len=4, new_tokens=4,
                           ttft=t, tpot=t, tenant=n)
            for i, (n, t) in enumerate([("paid", 0.9), ("free", 0.1),
                                        ("paid", 0.8), ("free", 0.2)])]
    cell = SimpleNamespace(accounting=SimpleNamespace(requests=reqs, uid=7))
    sup = SimpleNamespace(desired=None, cells={"srv": cell})
    pol = ReconcilePolicy(
        sup, "srv",
        replica_policy=ElasticPolicy(lt=0.2, ut=0.5, metric="tpot"),
        tenant="paid")
    assert pol.pull() == 2
    assert sorted(pol.replica_samples) == [0.8, 0.9]


def test_accounting_tenant_labels():
    from repro.core.accounting import (
        CellAccounting,
        RequestMetrics,
        tenant_percentile,
    )
    acct = CellAccounting("srv")
    acct.record_counter("blocked_on_pool", tenant="a")
    acct.record_counter("blocked_on_pool", 2, tenant="b")
    acct.record_counter("blocked_on_pool")
    assert acct.counters["blocked_on_pool"] == 4      # global always moves
    assert acct.tenant_counters["a"]["blocked_on_pool"] == 1
    assert acct.tenant_counters["b"]["blocked_on_pool"] == 2
    reqs = [RequestMetrics(rid=i, prompt_len=1, new_tokens=1,
                           ttft=float(i), tpot=0.1, tenant="a" if i < 3
                           else "b") for i in range(5)]
    assert tenant_percentile(reqs, "ttft", 50.0, tenant="a") == 1.0
    assert tenant_percentile(reqs, "ttft", 50.0, tenant="b") == 3.5
    assert tenant_percentile(reqs, "ttft", 50.0, tenant="nobody") is None
