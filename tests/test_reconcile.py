"""Declarative control plane: ClusterSpec + reconciler.

Planner-level tests run the real Reconciler against a bookkeeping-only
supervisor (pure logic, no jax compiles); the end-to-end test drives a
real Supervisor on 8 virtual host devices through apply/reconcile,
column failure + degraded recovery + restore, and spawn_child lineage.
"""
import json
import os
import subprocess
import sys

import pytest

from benchmarks.simlib import SimCell, SimSupervisor
from repro.core.spec import (
    CellSpec,
    ChannelSpec,
    ClusterSpec,
    SLOTarget,
    SpecError,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# spec semantics
# ---------------------------------------------------------------------------
def test_cellspec_validation():
    with pytest.raises(SpecError):
        CellSpec("a/b", None, "serve")             # reserved separator
    with pytest.raises(SpecError):
        CellSpec("a", None, "serve", replicas=0)
    with pytest.raises(SpecError):
        CellSpec("a", None, "serve", ncols=5, max_ncols=3)
    with pytest.raises(SpecError):
        ClusterSpec(cells=(CellSpec("a", None, "serve"),
                           CellSpec("a", None, "train")))
    with pytest.raises(SpecError):
        ClusterSpec(cells=(CellSpec("a", None, "serve"),),
                    channels=(ChannelSpec("a", "ghost"),))


def test_spec_instances_and_scaling():
    c = CellSpec("dec", None, "serve", ncols=2, min_ncols=1, max_ncols=4,
                 replicas=3, slo=SLOTarget(ttft_p99=0.1))
    assert c.instances() == ["dec/0", "dec/1", "dec/2"]
    spec = ClusterSpec(cells=(c, CellSpec("pre", None, "serve")),
                       channels=(ChannelSpec("pre", "dec", kind="kv"),))
    assert set(spec.instance_specs()) == {"dec/0", "dec/1", "dec/2", "pre"}
    assert spec.instance_channels() == [
        ("pre", "dec/0", "kv"), ("pre", "dec/1", "kv"), ("pre", "dec/2", "kv")]

    s2, d = spec.scale_by("dec", 10)               # clamped at max_ncols
    assert d == 2 and s2.cell("dec").ncols == 4
    s3, d = s2.scale_by("dec", -10)
    assert d == -3 and s3.cell("dec").ncols == 1   # clamped at min_ncols
    _, d = s3.scale_by("dec", -1)
    assert d == 0                                   # pinned
    assert spec.scale("pre", 1) is not spec
    assert spec.without_cell("dec").channels == ()


# ---------------------------------------------------------------------------
# planner on the shared bookkeeping supervisor (benchmarks/simlib.py)
# ---------------------------------------------------------------------------
def _sup(**cols):
    return SimSupervisor(*(SimCell(n, c) for n, c in cols.items()))


def test_reconcile_converges_and_is_idempotent():
    sup = _sup()
    spec = ClusterSpec(cells=(
        CellSpec("a", None, "serve", ncols=2),
        CellSpec("b", None, "train", ncols=3),
    ))
    plan = sup.apply(spec)
    assert [op.verb for op in plan.ops] == ["create", "create"]
    assert all(op.status == "ok" for op in plan.ops)
    # second reconcile: nothing to do
    assert sup.reconcile().empty
    assert sup.reconcile().empty


def test_reconcile_pairs_shrink_and_grow_into_transfer():
    sup = _sup(a=4, b=2)
    spec = ClusterSpec(cells=(
        CellSpec("a", None, "serve", ncols=2, min_ncols=1, max_ncols=6),
        CellSpec("b", None, "serve", ncols=4, min_ncols=1, max_ncols=6),
    ))
    plan = sup.apply(spec)
    assert [op.verb for op in plan.ops] == ["transfer"]
    assert sup.log == [("transfer", "a", "b", 2)]
    assert sup.reconcile().empty


def test_reconcile_destroys_unmanaged_and_orders_ops():
    sup = _sup(old=2, keep=1)
    spec = ClusterSpec(cells=(
        CellSpec("keep", None, "serve", ncols=3, max_ncols=3),
        CellSpec("new", None, "serve", ncols=1),
    ))
    plan = sup.apply(spec)
    assert [op.verb for op in plan.ops] == ["destroy", "grow", "create"]
    assert set(sup.cells) == {"keep", "new"}
    assert sup.reconcile().empty


def test_unbalanced_shrink_plus_transfer_lands_on_desired():
    """Regression: a donor that both shrinks AND funds a transfer must end
    exactly at its desired width — the residual shrink accounts for the
    columns the (later) transfer takes."""
    sup = _sup(a=5, b=3)
    spec = ClusterSpec(cells=(
        CellSpec("a", None, "serve", ncols=2, min_ncols=2, max_ncols=6),
        CellSpec("b", None, "serve", ncols=4, min_ncols=1, max_ncols=6),
    ))
    plan = sup.apply(spec)
    assert [op.verb for op in plan.ops] == ["shrink", "transfer"]
    assert plan.ops[0].args["ncols"] == 3        # 2 desired + 1 in transit
    assert sup.cells["a"].zone.ncols == 2        # never below desired/min
    assert sup.cells["b"].zone.ncols == 4
    assert sup.reconcile().empty


def test_blocked_create_blocks_channel_without_crashing():
    """Regression: a blocked create must leave its declared channel op
    'blocked' (retried later), not escape reconcile with a KeyError."""
    from repro.core.partition import PartitionError

    class _FullSup(SimSupervisor):
        def create_cell(self, name, arch, role, **kw):
            raise PartitionError("no free columns")

        def find_channel(self, src, dst, kind="array"):
            return None

        def open_channel(self, src, dst, kind="array"):
            raise AssertionError("must not be reached with a missing endpoint")

    sup = _FullSup(SimCell("a", 1))
    spec = ClusterSpec(
        cells=(CellSpec("a", None, "serve", ncols=1, max_ncols=1),
               CellSpec("b", None, "serve", ncols=1)),
        channels=(ChannelSpec("a", "b"),),
    )
    plan = sup.apply(spec)                       # must not raise
    by_verb = {op.verb: op.status for op in plan.ops}
    assert by_verb == {"create": "blocked", "open_channel": "blocked"}


def test_recreate_reopens_declared_channels():
    """Regression: destroy+recreate (role change) closes the old channel
    mid-plan; the same plan must schedule a fresh open_channel."""
    class _ChanSup(SimSupervisor):
        def __init__(self, *cells):
            super().__init__(*cells)
            self.channels = []

        def find_channel(self, src, dst, kind="array"):
            for c in self.channels:
                if c == (src, dst, kind):
                    return c
            return None

        def open_channel(self, src, dst, kind="array"):
            self.channels.append((src, dst, kind))
            return type("Ch", (), {"cid": len(self.channels)})()

        def destroy_cell(self, name):
            super().destroy_cell(name)
            self.channels = [c for c in self.channels
                             if name not in (c[0], c[1])]

    sup = _ChanSup(SimCell("a", 1), SimCell("b", 1))
    spec = ClusterSpec(
        cells=(CellSpec("a", None, "serve", ncols=1, max_ncols=1),
               CellSpec("b", None, "serve", ncols=1, max_ncols=1)),
        channels=(ChannelSpec("a", "b", kind="kv"),),
    )
    sup.apply(spec)
    assert sup.find_channel("a", "b", "kv") is not None
    # converged: the open channel is not re-opened
    assert sup.reconcile().empty
    # now flip b's role: destroy+create closes the channel; same plan reopens
    plan = sup.apply(spec.with_cell(
        CellSpec("b", None, "train", ncols=1, max_ncols=1)))
    assert [op.verb for op in plan.ops] == ["destroy", "create", "open_channel"]
    assert sup.find_channel("a", "b", "kv") is not None
    assert sup.reconcile().empty


def test_reconcile_recovers_failed_cells():
    sup = _sup(a=2)
    sup.cells["a"].status = "failed"
    spec = ClusterSpec(cells=(CellSpec("a", None, "serve", ncols=2, max_ncols=2),))
    plan = sup.apply(spec)
    assert [op.verb for op in plan.ops] == ["recover"]
    assert sup.cells["a"].status == "running"
    assert sup.reconcile().empty


def test_reconcile_recreates_on_role_change():
    sup = _sup(a=2)
    spec = ClusterSpec(cells=(CellSpec("a", None, "train", ncols=2, max_ncols=2),))
    plan = sup.apply(spec)
    assert [op.verb for op in plan.ops] == ["destroy", "create"]
    assert sup.cells["a"].role == "train"
    assert sup.reconcile().empty


def test_reconcile_expands_replicas():
    sup = _sup()
    spec = ClusterSpec(cells=(
        CellSpec("dec", None, "serve", ncols=1, replicas=3),))
    plan = sup.apply(spec)
    assert sorted(op.cell for op in plan.ops) == ["dec/0", "dec/1", "dec/2"]
    assert sup.reconcile().empty
    # dropping a replica destroys exactly the orphaned instances
    plan = sup.apply(ClusterSpec(cells=(
        CellSpec("dec", None, "serve", ncols=1, replicas=2),)))
    assert [(op.verb, op.cell) for op in plan.ops] == [("destroy", "dec/2")]


# ---------------------------------------------------------------------------
# end-to-end on a real Supervisor (8 virtual host devices, subprocess)
# ---------------------------------------------------------------------------
E2E = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.core import CellSpec, ClusterSpec, DeviceGrid, Supervisor
from repro.train.optimizer import OptConfig

grid = DeviceGrid.from_flat(jax.devices(), pods=1, rows=2, cols=4)
sup = Supervisor(grid)
cfg = smoke_config(get_arch("qwen3-4b")).replace(num_layers=2, d_model=64,
    d_ff=128, num_heads=2, num_kv_heads=2, head_dim=32, vocab=256)
out = {}

spec = ClusterSpec(cells=(
    CellSpec("tr", cfg, "train", ncols=2, min_ncols=1, max_ncols=3,
             opt_cfg=OptConfig()),
    CellSpec("srv", cfg, "serve", ncols=1, min_ncols=1, max_ncols=2),
))
plan = sup.apply(spec)
out["plan1"] = [op.verb for op in plan.ops]
out["idempotent"] = sup.reconcile().empty and sup.reconcile().empty

# declarative rescale: grow srv into the free column (tr [0,2) srv [2,3))
plan = sup.apply(spec.scale("srv", 2))
out["plan2"] = [(op.verb, op.status) for op in plan.ops]
# then hand srv's extra column to tr: one paired transfer
plan = sup.apply(spec.scale("tr", 3).scale("srv", 1))
out["plan3"] = [(op.verb, op.status) for op in plan.ops]
out["cols3"] = [sup.cells["tr"].zone.ncols, sup.cells["srv"].zone.ncols]
out["idempotent3"] = sup.reconcile().empty

# column failure -> degraded recovery through reconcile (tr wants 3 but
# only 2 contiguous non-failed columns remain)
affected = sup.fail_column(0, sup.cells["tr"].zone.c0)
out["affected"] = affected
out["tr_status"] = sup.cells["tr"].status
plan = sup.reconcile()               # recover: re-carve what fits
recov = [op for op in plan.ops if op.verb == "recover"]
out["recover_status"] = [op.status for op in recov]
out["tr_cols_degraded"] = sup.cells["tr"].zone.ncols

# restore the quarantined column; reconcile grows the cell back to spec
pod_col = sorted(sup.table.failed_columns)[0]
assert sup.restore_column(*pod_col)
plan = sup.reconcile()
out["regrow"] = [(op.verb, op.status) for op in plan.ops]
out["tr_cols_restored"] = sup.cells["tr"].zone.ncols
out["converged"] = sup.reconcile().empty

# spawn_child lineage (imperative fork below the declarative plane)
sup.desired = None                   # detach so reconcile won't prune child
child = sup.spawn_child("tr", "tr_child", cfg, "train", ncols=1)
out["lineage"] = sup.lineage("tr_child")
out["child_cols"] = child.zone.ncols
out["parent_cols"] = sup.cells["tr"].zone.ncols

# validate_cell_programs runs the guard over compiled programs
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.configs.base import ShapeConfig
pipe = SyntheticPipeline(DataConfig(kind="bigram", vocab=128), cfg,
                         ShapeConfig("t", "train", 8, 8))
sup.cells["tr"].train_steps(pipe.get_batch, 1)
out["validated"] = sup.validate_cell_programs("tr")
out["events"] = sorted(set(e["op"] for e in sup.events))
print(json.dumps(out))
"""


def test_reconcile_e2e_real_supervisor():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", E2E], capture_output=True, text=True,
        cwd=ROOT, env=env, timeout=540,
    )
    assert proc.returncode == 0, proc.stderr[-2500:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert sorted(out["plan1"]) == ["create", "create"]
    assert out["idempotent"]
    assert out["plan2"] == [["grow", "ok"]]
    assert out["plan3"] == [["transfer", "ok"]]
    assert out["cols3"] == [3, 1]
    assert out["idempotent3"]
    # failure -> degraded recovery -> restore -> regrow to spec
    assert out["affected"] == ["tr"]
    assert out["tr_status"] == "failed"
    assert out["recover_status"] == ["degraded"]
    assert out["tr_cols_degraded"] == 2
    assert out["regrow"] == [["grow", "ok"]]
    assert out["tr_cols_restored"] == 3
    assert out["converged"]
    # lineage + guarded programs
    assert out["lineage"] == ["tr_child", "tr"]
    assert out["child_cols"] == 1
    assert out["validated"] >= 1
    assert "restore_column" in out["events"] and "recover" in out["events"]
