"""Declarative control plane: ClusterSpec + reconciler.

Planner-level tests run the real Reconciler against a bookkeeping-only
supervisor (pure logic, no jax compiles); the end-to-end test drives a
real Supervisor on 8 virtual host devices through apply/reconcile,
column failure + degraded recovery + restore, and spawn_child lineage.
"""
import json
import os
import subprocess
import sys
import time

import pytest

from benchmarks.simlib import SimCell, SimSupervisor
from repro.core.daemon import SupervisorDaemon
from repro.core.elastic import ElasticPolicy, ReconcilePolicy
from repro.core.spec import (
    CellSpec,
    ChannelSpec,
    ClusterSpec,
    SLOTarget,
    SpecError,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# spec semantics
# ---------------------------------------------------------------------------
def test_cellspec_validation():
    with pytest.raises(SpecError):
        CellSpec("a/b", None, "serve")             # reserved separator
    with pytest.raises(SpecError):
        CellSpec("a", None, "serve", replicas=0)
    with pytest.raises(SpecError):
        CellSpec("a", None, "serve", ncols=5, max_ncols=3)
    with pytest.raises(SpecError):
        ClusterSpec(cells=(CellSpec("a", None, "serve"),
                           CellSpec("a", None, "train")))
    with pytest.raises(SpecError):
        ClusterSpec(cells=(CellSpec("a", None, "serve"),),
                    channels=(ChannelSpec("a", "ghost"),))


def test_replica_bounds_validation_and_clamping():
    with pytest.raises(SpecError):
        CellSpec("a", None, "serve", replicas=4, max_replicas=2)
    with pytest.raises(SpecError):
        CellSpec("a", None, "serve", replicas=1, min_replicas=2)
    with pytest.raises(SpecError):
        CellSpec("a", None, "serve", min_replicas=0)
    c = CellSpec("a", None, "serve", replicas=2, min_replicas=1, max_replicas=4)
    assert c.clamp_replicas(9) == 4 and c.clamp_replicas(0) == 1
    assert c.with_replicas(3).replicas == 3
    spec = ClusterSpec(cells=(c,))
    s2, d = spec.scale_replicas_by("a", 10)
    assert d == 2 and s2.cell("a").replicas == 4
    _, d = s2.scale_replicas_by("a", 1)
    assert d == 0                                   # pinned at max


def test_spec_instances_and_scaling():
    c = CellSpec("dec", None, "serve", ncols=2, min_ncols=1, max_ncols=4,
                 replicas=3, slo=SLOTarget(ttft_p99=0.1))
    assert c.instances() == ["dec/0", "dec/1", "dec/2"]
    spec = ClusterSpec(cells=(c, CellSpec("pre", None, "serve")),
                       channels=(ChannelSpec("pre", "dec", kind="kv"),))
    assert set(spec.instance_specs()) == {"dec/0", "dec/1", "dec/2", "pre"}
    assert spec.instance_channels() == [
        ("pre", "dec/0", "kv"), ("pre", "dec/1", "kv"), ("pre", "dec/2", "kv")]

    s2, d = spec.scale_by("dec", 10)               # clamped at max_ncols
    assert d == 2 and s2.cell("dec").ncols == 4
    s3, d = s2.scale_by("dec", -10)
    assert d == -3 and s3.cell("dec").ncols == 1   # clamped at min_ncols
    _, d = s3.scale_by("dec", -1)
    assert d == 0                                   # pinned
    assert spec.scale("pre", 1) is not spec
    assert spec.without_cell("dec").channels == ()


# ---------------------------------------------------------------------------
# planner on the shared bookkeeping supervisor (benchmarks/simlib.py)
# ---------------------------------------------------------------------------
def _sup(**cols):
    return SimSupervisor(*(SimCell(n, c) for n, c in cols.items()))


def test_reconcile_converges_and_is_idempotent():
    sup = _sup()
    spec = ClusterSpec(cells=(
        CellSpec("a", None, "serve", ncols=2),
        CellSpec("b", None, "train", ncols=3),
    ))
    plan = sup.apply(spec)
    assert [op.verb for op in plan.ops] == ["create", "create"]
    assert all(op.status == "ok" for op in plan.ops)
    # second reconcile: nothing to do
    assert sup.reconcile().empty
    assert sup.reconcile().empty


def test_reconcile_pairs_shrink_and_grow_into_transfer():
    sup = _sup(a=4, b=2)
    spec = ClusterSpec(cells=(
        CellSpec("a", None, "serve", ncols=2, min_ncols=1, max_ncols=6),
        CellSpec("b", None, "serve", ncols=4, min_ncols=1, max_ncols=6),
    ))
    plan = sup.apply(spec)
    assert [op.verb for op in plan.ops] == ["transfer"]
    assert sup.log == [("transfer", "a", "b", 2)]
    assert sup.reconcile().empty


def test_reconcile_destroys_unmanaged_and_orders_ops():
    sup = _sup(old=2, keep=1)
    spec = ClusterSpec(cells=(
        CellSpec("keep", None, "serve", ncols=3, max_ncols=3),
        CellSpec("new", None, "serve", ncols=1),
    ))
    plan = sup.apply(spec)
    assert [op.verb for op in plan.ops] == ["destroy", "grow", "create"]
    assert set(sup.cells) == {"keep", "new"}
    assert sup.reconcile().empty


def test_unbalanced_shrink_plus_transfer_lands_on_desired():
    """Regression: a donor that both shrinks AND funds a transfer must end
    exactly at its desired width — the residual shrink accounts for the
    columns the (later) transfer takes."""
    sup = _sup(a=5, b=3)
    spec = ClusterSpec(cells=(
        CellSpec("a", None, "serve", ncols=2, min_ncols=2, max_ncols=6),
        CellSpec("b", None, "serve", ncols=4, min_ncols=1, max_ncols=6),
    ))
    plan = sup.apply(spec)
    assert [op.verb for op in plan.ops] == ["shrink", "transfer"]
    assert plan.ops[0].args["ncols"] == 3        # 2 desired + 1 in transit
    assert sup.cells["a"].zone.ncols == 2        # never below desired/min
    assert sup.cells["b"].zone.ncols == 4
    assert sup.reconcile().empty


def test_blocked_create_blocks_channel_without_crashing():
    """Regression: a blocked create must leave its declared channel op
    'blocked' (retried later), not escape reconcile with a KeyError."""
    from repro.core.partition import PartitionError

    class _FullSup(SimSupervisor):
        def create_cell(self, name, arch, role, **kw):
            raise PartitionError("no free columns")

        def find_channel(self, src, dst, kind="array"):
            return None

        def open_channel(self, src, dst, kind="array"):
            raise AssertionError("must not be reached with a missing endpoint")

    sup = _FullSup(SimCell("a", 1))
    spec = ClusterSpec(
        cells=(CellSpec("a", None, "serve", ncols=1, max_ncols=1),
               CellSpec("b", None, "serve", ncols=1)),
        channels=(ChannelSpec("a", "b"),),
    )
    plan = sup.apply(spec)                       # must not raise
    by_verb = {op.verb: op.status for op in plan.ops}
    assert by_verb == {"create": "blocked", "open_channel": "blocked"}


def test_recreate_reopens_declared_channels():
    """Regression: destroy+recreate (role change) closes the old channel
    mid-plan; the same plan must schedule a fresh open_channel."""
    class _ChanSup(SimSupervisor):
        def __init__(self, *cells):
            super().__init__(*cells)
            self.channels = []

        def find_channel(self, src, dst, kind="array"):
            for c in self.channels:
                if c == (src, dst, kind):
                    return c
            return None

        def open_channel(self, src, dst, kind="array"):
            self.channels.append((src, dst, kind))
            return type("Ch", (), {"cid": len(self.channels)})()

        def destroy_cell(self, name):
            super().destroy_cell(name)
            self.channels = [c for c in self.channels
                             if name not in (c[0], c[1])]

    sup = _ChanSup(SimCell("a", 1), SimCell("b", 1))
    spec = ClusterSpec(
        cells=(CellSpec("a", None, "serve", ncols=1, max_ncols=1),
               CellSpec("b", None, "serve", ncols=1, max_ncols=1)),
        channels=(ChannelSpec("a", "b", kind="kv"),),
    )
    sup.apply(spec)
    assert sup.find_channel("a", "b", "kv") is not None
    # converged: the open channel is not re-opened
    assert sup.reconcile().empty
    # now flip b's role: destroy+create closes the channel; same plan reopens
    plan = sup.apply(spec.with_cell(
        CellSpec("b", None, "train", ncols=1, max_ncols=1)))
    assert [op.verb for op in plan.ops] == ["destroy", "create", "open_channel"]
    assert sup.find_channel("a", "b", "kv") is not None
    assert sup.reconcile().empty


def test_reconcile_recovers_failed_cells():
    sup = _sup(a=2)
    sup.cells["a"].status = "failed"
    spec = ClusterSpec(cells=(CellSpec("a", None, "serve", ncols=2, max_ncols=2),))
    plan = sup.apply(spec)
    assert [op.verb for op in plan.ops] == ["recover"]
    assert sup.cells["a"].status == "running"
    assert sup.reconcile().empty


def test_reconcile_recreates_on_role_change():
    sup = _sup(a=2)
    spec = ClusterSpec(cells=(CellSpec("a", None, "train", ncols=2, max_ncols=2),))
    plan = sup.apply(spec)
    assert [op.verb for op in plan.ops] == ["destroy", "create"]
    assert sup.cells["a"].role == "train"
    assert sup.reconcile().empty


def test_reconcile_expands_replicas():
    sup = _sup()
    spec = ClusterSpec(cells=(
        CellSpec("dec", None, "serve", ncols=1, replicas=3),))
    plan = sup.apply(spec)
    assert sorted(op.cell for op in plan.ops) == ["dec/0", "dec/1", "dec/2"]
    assert sup.reconcile().empty
    # dropping a replica destroys exactly the orphaned instances
    plan = sup.apply(ClusterSpec(cells=(
        CellSpec("dec", None, "serve", ncols=1, replicas=2),)))
    assert [(op.verb, op.cell) for op in plan.ops] == [("destroy", "dec/2")]


# ---------------------------------------------------------------------------
# elastic policy: validation, SLO-derived bands, cursor identity, replicas
# ---------------------------------------------------------------------------
def test_elastic_policy_validates_metric_and_band():
    """Regression: a metric typo ('tftt') used to make pull() ingest None
    forever and silently disable elasticity."""
    with pytest.raises(ValueError):
        ElasticPolicy(lt=0.1, ut=0.2, metric="tftt")
    with pytest.raises(ValueError):
        ElasticPolicy(lt=0.3, ut=0.2)               # empty band
    with pytest.raises(ValueError):
        ElasticPolicy(lt=0.1, ut=0.2, window=0)


def test_elastic_policy_from_slo_band_derivation():
    slo = SLOTarget(ttft_p99=0.2, tpot_p99=0.05)
    p = ElasticPolicy.from_slo(slo, metric="ttft", hysteresis=0.8)
    assert (p.lt, p.ut, p.metric) == (pytest.approx(0.16), 0.2, "ttft")
    p = ElasticPolicy.from_slo(slo, metric="tpot", hysteresis=0.5, window=20)
    assert (p.lt, p.ut, p.window) == (0.025, 0.05, 20)
    with pytest.raises(ValueError):                  # no target declared
        ElasticPolicy.from_slo(SLOTarget(ttft_p99=0.2), metric="tpot")
    with pytest.raises(ValueError):
        ElasticPolicy.from_slo(None, metric="ttft")
    with pytest.raises(ValueError):
        ElasticPolicy.from_slo(slo, hysteresis=1.5)


def test_pull_cursor_keyed_on_accounting_identity():
    """Regression: a recovered cell's FRESH log that already grew past the
    old cursor was silently skipped (len(reqs) >= stale cursor)."""
    from repro.core.accounting import CellAccounting

    sup = _sup(server=2, batch=2)
    sup.apply(ClusterSpec(cells=(
        CellSpec("server", None, "serve", ncols=2, min_ncols=1, max_ncols=4),
        CellSpec("batch", None, "train", ncols=2, min_ncols=1, max_ncols=4),
    )))
    pol = ReconcilePolicy(sup, "server", "batch",
                          ElasticPolicy(lt=0.1, ut=0.2, window=10))
    for i in range(3):
        sup.cells["server"].accounting.record_request(i, ttft=0.15)
    assert pol.pull() == 3
    # recovery swaps in a fresh accounting; its log grows PAST the old
    # cursor (5 > 3) before the next pull
    sup.cells["server"].accounting = CellAccounting("server")
    for i in range(5):
        sup.cells["server"].accounting.record_request(i, ttft=0.15)
    assert pol.pull() == 5                # length heuristic would read 2


def test_recover_threads_ckpt_dir_from_spec():
    """The spec's ckpt_dir must ride the recover plan op into
    recover_cell (reconcile-driven checkpoint restore)."""
    sup = _sup(a=2)
    sup.cells["a"].status = "failed"
    spec = ClusterSpec(cells=(
        CellSpec("a", None, "serve", ncols=2, max_ncols=2,
                 ckpt_dir="/ckpts/a"),))
    plan = sup.apply(spec)
    assert [op.verb for op in plan.ops] == ["recover"]
    assert plan.ops[0].args["ckpt_dir"] == "/ckpts/a"
    assert ("recover", "a", 2, "/ckpts/a") in sup.log


def test_replica_autoscale_from_queue_and_tpot_tail():
    sup = _sup()
    sup.apply(ClusterSpec(cells=(
        CellSpec("dec", None, "serve", ncols=1, replicas=1, max_replicas=3),)))
    q = {"n": 0}
    pol = ReconcilePolicy(
        sup, "dec",
        replica_policy=ElasticPolicy(lt=0.05, ut=0.2, window=10,
                                     metric="tpot"),
        queue_depth=lambda: q["n"], queue_high=4)
    # queue pressure alone grows — decode samples may not flow at all
    # while every replica is saturated or dead
    q["n"] = 10
    act = pol.maybe_act(now=0.0)
    assert act and act["kind"] == "grow_replicas" and act["queue_depth"] == 10
    assert sup.desired.cell("dec").replicas == 2
    assert set(sup.cells) == {"dec/0", "dec/1"}
    # TPOT tail above the band grows again
    q["n"] = 0
    for i in range(10):
        sup.cells["dec/0"].accounting.record_request(i, tpot=0.5)
    act = pol.maybe_act(now=1.0)
    assert act and act["kind"] == "grow_replicas"
    assert sup.desired.cell("dec").replicas == 3
    assert set(sup.cells) == {"dec/0", "dec/1", "dec/2"}
    # pinned at max_replicas: tail pressure changes nothing
    for i in range(10, 20):
        sup.cells["dec/0"].accounting.record_request(i, tpot=0.5)
    assert pol.maybe_act(now=2.0) is None
    assert sup.desired.cell("dec").replicas == 3
    # idle queue + comfortably low tail shrinks back
    for i in range(20, 32):
        sup.cells["dec/1"].accounting.record_request(i, tpot=0.01)
    act = pol.maybe_act(now=3.0)
    assert act and act["kind"] == "shrink_replicas"
    assert sup.desired.cell("dec").replicas == 2
    assert sup.reconcile().empty


def test_replica_autoscale_never_crosses_rename_boundary():
    """Bounded specs keep indexed names at replicas==1, so a 2 -> 1
    shrink destroys ONLY the surplus instance; an UNBOUNDED spec would
    rename ('dec/i' <-> 'dec') — a full teardown — so autoscale refuses
    to cross that boundary and leaves it to an explicit apply()."""
    sup = _sup()
    sup.apply(ClusterSpec(cells=(
        CellSpec("dec", None, "serve", ncols=1, replicas=2, max_replicas=3),)))
    pol = ReconcilePolicy(
        sup, "dec",
        replica_policy=ElasticPolicy(lt=0.05, ut=0.2, window=10,
                                     metric="tpot"),
        queue_depth=lambda: 0)
    for i in range(10):
        sup.cells["dec/0"].accounting.record_request(i, tpot=0.01)
    act = pol.maybe_act(now=0.0)
    assert act and act["kind"] == "shrink_replicas"
    assert sup.desired.cell("dec").replicas == 1
    assert set(sup.cells) == {"dec/0"}           # dec/0 survived untouched
    assert ("destroy", "dec/0") not in sup.log
    # grow back: add dec/1, never tear dec/0 down
    for i in range(10, 22):
        sup.cells["dec/0"].accounting.record_request(i, tpot=0.5)
    act = pol.maybe_act(now=1.0)
    assert act and act["kind"] == "grow_replicas"
    assert set(sup.cells) == {"dec/0", "dec/1"}
    assert sup.log.count(("destroy", "dec/0")) == 0

    # UNBOUNDED spec: 2 -> 1 would rename dec/i -> dec; guarded
    sup2 = _sup()
    sup2.apply(ClusterSpec(cells=(
        CellSpec("dec", None, "serve", ncols=1, replicas=2),)))
    pol2 = ReconcilePolicy(
        sup2, "dec",
        replica_policy=ElasticPolicy(lt=0.05, ut=0.2, window=10,
                                     metric="tpot"),
        queue_depth=lambda: 0)
    for i in range(10):
        sup2.cells["dec/0"].accounting.record_request(i, tpot=0.01)
    assert pol2.maybe_act(now=0.0) is None
    assert sup2.desired.cell("dec").replicas == 2
    assert set(sup2.cells) == {"dec/0", "dec/1"}


def test_reconcile_policy_requires_an_axis():
    sup = _sup()
    with pytest.raises(ValueError):
        ReconcilePolicy(sup, "a")                    # no axis at all
    with pytest.raises(ValueError):
        ReconcilePolicy(sup, "a", None,              # cols axis, no donor
                        ElasticPolicy(lt=0.1, ut=0.2))


# ---------------------------------------------------------------------------
# supervisor daemon (bookkeeping supervisor: pure control-loop logic)
# ---------------------------------------------------------------------------
class _RecordingSup(SimSupervisor):
    def __init__(self, *cells):
        super().__init__(*cells)
        self.calls = []
        self.dead_once = []

    def check_health(self):
        self.calls.append("health")
        out, self.dead_once = self.dead_once, []
        return out

    def reconcile(self):
        self.calls.append("reconcile")
        return super().reconcile()


def test_daemon_tick_ordering_and_dead_cell_recovery():
    sup = _RecordingSup(SimCell("a", 2))
    sup.apply(ClusterSpec(cells=(
        CellSpec("a", None, "serve", ncols=2, max_ncols=2),)))

    calls = sup.calls

    class _FakePolicy:
        actions = []

        def maybe_act(self, now=None):
            calls.append("policy")
            return None

    class _FakeSrv:
        _decode_base = "a"

        def sync(self, spec, base=None):
            calls.append("sync")
            return {"attached": [], "detached": [], "requeued": 0}

    daemon = SupervisorDaemon(sup)
    daemon.add_policy(_FakePolicy())
    daemon.attach_server(_FakeSrv())
    sup.dead_once = ["a"]                 # heartbeat timed out before tick 0
    calls.clear()
    rec = daemon.tick()
    # strict stage order: health feeds reconcile feeds policies feeds sync
    assert calls == ["health", "reconcile", "policy", "sync"]
    assert rec["dead"] == ["a"]
    assert rec["plan"] == "recover:1"     # recovered within the SAME tick
    assert sup.cells["a"].status == "running"
    # converged: the next tick is a noop
    rec = daemon.tick()
    assert rec["dead"] == [] and rec["plan"] == "noop"
    assert daemon.ticks == 2 and len(daemon.history) == 2


def test_daemon_slo_policy_derives_bands_from_spec():
    sup = _sup(srv=2, don=4)
    sup.apply(ClusterSpec(cells=(
        CellSpec("srv", None, "serve", ncols=2, min_ncols=1, max_ncols=6,
                 slo=SLOTarget(ttft_p99=0.2, tpot_p99=0.05)),
        CellSpec("don", None, "train", ncols=4, min_ncols=1, max_ncols=6),
    )))
    daemon = SupervisorDaemon(sup)
    pol = daemon.add_slo_policy("srv", "don", hysteresis=0.8,
                                autoscale_replicas=True)
    assert (pol.policy.lt, pol.policy.ut) == (pytest.approx(0.16), 0.2)
    assert (pol.replica_policy.lt, pol.replica_policy.ut) == \
        (pytest.approx(0.04), 0.05)
    assert pol.replica_policy.metric == "tpot"
    # the derived policy acts end to end through a daemon tick
    for i in range(10):
        sup.cells["srv"].accounting.record_request(i, ttft=0.5)
    rec = daemon.tick(now=0.0)
    assert [a["kind"] for a in rec["actions"]] == ["grow_server"]
    assert sup.cells["srv"].zone.ncols == 3
    # re-applying a spec with a CHANGED SLO re-derives the bands — the
    # objective is the spec's, never frozen at registration time
    import dataclasses
    sup.apply(sup.desired.with_cell(dataclasses.replace(
        sup.desired.cell("srv"), slo=SLOTarget(ttft_p99=0.1, tpot_p99=0.02))))
    daemon.tick(now=100.0)
    assert (pol.policy.lt, pol.policy.ut) == (pytest.approx(0.08), 0.1)
    assert pol.replica_policy.ut == 0.02
    # unknown cell / missing SLO are loud errors, not silent zero-bands
    with pytest.raises(ValueError):
        daemon.add_slo_policy("ghost", "don")
    sup2 = _sup(x=1)
    sup2.apply(ClusterSpec(cells=(
        CellSpec("x", None, "serve", ncols=1, max_ncols=1),)))
    with pytest.raises(ValueError):
        SupervisorDaemon(sup2).add_slo_policy("x", autoscale_replicas=True)


def test_daemon_threaded_start_stop():
    sup = _sup(a=1)
    sup.apply(ClusterSpec(cells=(
        CellSpec("a", None, "serve", ncols=1, max_ncols=1),)))
    daemon = SupervisorDaemon(sup, interval=0.005)
    with daemon:
        assert daemon.running
        with pytest.raises(RuntimeError):
            daemon.start()                # double-start is an error
        deadline = time.monotonic() + 5.0
        while daemon.ticks == 0 and time.monotonic() < deadline:
            time.sleep(0.005)
    assert not daemon.running
    assert daemon.ticks >= 1
    assert not daemon.errors
    ticks_at_stop = daemon.ticks
    time.sleep(0.03)
    assert daemon.ticks == ticks_at_stop  # really stopped


# ---------------------------------------------------------------------------
# end-to-end on a real Supervisor (8 virtual host devices, subprocess)
# ---------------------------------------------------------------------------
E2E = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.core import CellSpec, ClusterSpec, DeviceGrid, Supervisor
from repro.train.optimizer import OptConfig

grid = DeviceGrid.from_flat(jax.devices(), pods=1, rows=2, cols=4)
sup = Supervisor(grid)
cfg = smoke_config(get_arch("qwen3-4b")).replace(num_layers=2, d_model=64,
    d_ff=128, num_heads=2, num_kv_heads=2, head_dim=32, vocab=256)
out = {}

spec = ClusterSpec(cells=(
    CellSpec("tr", cfg, "train", ncols=2, min_ncols=1, max_ncols=3,
             opt_cfg=OptConfig()),
    CellSpec("srv", cfg, "serve", ncols=1, min_ncols=1, max_ncols=2),
))
plan = sup.apply(spec)
out["plan1"] = [op.verb for op in plan.ops]
out["idempotent"] = sup.reconcile().empty and sup.reconcile().empty

# declarative rescale: grow srv into the free column (tr [0,2) srv [2,3))
plan = sup.apply(spec.scale("srv", 2))
out["plan2"] = [(op.verb, op.status) for op in plan.ops]
# then hand srv's extra column to tr: one paired transfer
plan = sup.apply(spec.scale("tr", 3).scale("srv", 1))
out["plan3"] = [(op.verb, op.status) for op in plan.ops]
out["cols3"] = [sup.cells["tr"].zone.ncols, sup.cells["srv"].zone.ncols]
out["idempotent3"] = sup.reconcile().empty

# column failure -> degraded recovery through reconcile (tr wants 3 but
# only 2 contiguous non-failed columns remain)
affected = sup.fail_column(0, sup.cells["tr"].zone.c0)
out["affected"] = affected
out["tr_status"] = sup.cells["tr"].status
plan = sup.reconcile()               # recover: re-carve what fits
recov = [op for op in plan.ops if op.verb == "recover"]
out["recover_status"] = [op.status for op in recov]
out["tr_cols_degraded"] = sup.cells["tr"].zone.ncols

# restore the quarantined column; reconcile grows the cell back to spec
pod_col = sorted(sup.table.failed_columns)[0]
assert sup.restore_column(*pod_col)
plan = sup.reconcile()
out["regrow"] = [(op.verb, op.status) for op in plan.ops]
out["tr_cols_restored"] = sup.cells["tr"].zone.ncols
out["converged"] = sup.reconcile().empty

# spawn_child lineage (imperative fork below the declarative plane)
sup.desired = None                   # detach so reconcile won't prune child
child = sup.spawn_child("tr", "tr_child", cfg, "train", ncols=1)
out["lineage"] = sup.lineage("tr_child")
out["child_cols"] = child.zone.ncols
out["parent_cols"] = sup.cells["tr"].zone.ncols

# validate_cell_programs runs the guard over compiled programs
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.configs.base import ShapeConfig
pipe = SyntheticPipeline(DataConfig(kind="bigram", vocab=128), cfg,
                         ShapeConfig("t", "train", 8, 8))
sup.cells["tr"].train_steps(pipe.get_batch, 1)
out["validated"] = sup.validate_cell_programs("tr")
out["events"] = sorted(set(e["op"] for e in sup.events))
print(json.dumps(out))
"""


def test_reconcile_e2e_real_supervisor():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", E2E], capture_output=True, text=True,
        cwd=ROOT, env=env, timeout=540,
    )
    assert proc.returncode == 0, proc.stderr[-2500:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert sorted(out["plan1"]) == ["create", "create"]
    assert out["idempotent"]
    assert out["plan2"] == [["grow", "ok"]]
    assert out["plan3"] == [["transfer", "ok"]]
    assert out["cols3"] == [3, 1]
    assert out["idempotent3"]
    # failure -> degraded recovery -> restore -> regrow to spec
    assert out["affected"] == ["tr"]
    assert out["tr_status"] == "failed"
    assert out["recover_status"] == ["degraded"]
    assert out["tr_cols_degraded"] == 2
    assert out["regrow"] == [["grow", "ok"]]
    assert out["tr_cols_restored"] == 3
    assert out["converged"]
    # lineage + guarded programs
    assert out["lineage"] == ["tr_child", "tr"]
    assert out["child_cols"] == 1
    assert out["validated"] >= 1
    assert "restore_column" in out["events"] and "recover" in out["events"]
