"""Property-based (hypothesis) invariants for the snapshot cache plane.

The snapshot twin of ``test_kvpool_properties.py``: a snapshot-mode
``KVPool`` interns per-chunk recurrent-state payloads under the same
``PrefixTree`` handles that page pools use for page ids, so every tree
invariant must carry over payload-polymorphically —

  * intern/lease/release over random prompt sequences: handle <->
    payload bijection (``_snaps`` keys are exactly the walked handles),
    refcounts never negative and return to 0 after every lease is
    released, ``snapshot_chain`` materializes the DEEPEST interned
    boundary state of the matched chain;
  * ``export_subtree`` / ``import_subtree`` (the migration path)
    round-trip chains payload-exactly with refs-0 arrivals.

Deterministic snapshot-plane tests (capability gate, eviction reaping,
warm-restore decode exactness) live in ``test_snapshot_cache.py`` so
they run even without the hypothesis dep.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # keep collection alive without the dep

import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import smoke_config  # noqa: E402
from repro.configs.registry import get_arch  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve.kvpool import KVPool  # noqa: E402
from repro.sharding.rules import single_device_ctx  # noqa: E402

MAX_LEN = 32
PAGE = 8

_CACHE = {}


def _model(name):
    if name not in _CACHE:
        cfg = smoke_config(get_arch(name))
        model = build_model(cfg, single_device_ctx())
        _CACHE[name] = (model, model.init(jax.random.PRNGKey(0)))
    return _CACHE[name]


def _payloads(tag, n):
    """n fake chunk payloads whose states are distinguishable scalars —
    the pool never inspects payload contents, only stores/returns them."""
    return [{"state": np.asarray([tag, lp], np.int64), "pages": []}
            for lp in range(n)]


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_snapshot_pool_invariants(data):
    """Intern/lease/release over random prompts from a tiny alphabet
    (maximal prefix collisions): the handle->payload map mirrors the
    tree exactly, ``snapshot_chain`` returns the deepest matched
    boundary state, refcounts are non-negative throughout and return to
    0 once every lease is released."""
    model, _ = _model("mamba2-2.7b")
    # generous handle supply: intern never breaks mid-chain, so a
    # pre-intern walk predicts insertions exactly
    pool = KVPool(model, max_len=MAX_LEN, page_size=PAGE, slots=0,
                  num_pages=256)
    mirror = {}          # key-path -> expected "state" payload
    leased = []
    next_tag = [0]

    def _paths(prompt):
        keys = [tuple(int(t) for t in prompt[i * PAGE:(i + 1) * PAGE])
                for i in range(len(prompt) // PAGE)]
        return [tuple(keys[:lp + 1]) for lp in range(len(keys))]

    for _ in range(data.draw(st.integers(1, 25), label="ops")):
        op = data.draw(st.sampled_from(["intern", "lease", "release"]),
                       label="op")
        prompt = np.asarray(data.draw(
            st.lists(st.integers(0, 2), min_size=0, max_size=MAX_LEN),
            label="prompt"), np.int32)
        if op == "intern":
            pays = _payloads(next_tag[0], len(prompt) // PAGE)
            next_tag[0] += 1
            pool.intern_snapshots(prompt, None, pays)
            # intern only inserts missing nodes (existing paths keep
            # their original payload): record each newly-landed path
            parent = pool.tree.root(None)
            for lp, path in enumerate(_paths(prompt)):
                node = parent.children.get(path[-1])
                assert node is not None, "generous pool never breaks"
                if path not in mirror:
                    mirror[path] = pays[lp]["state"]
                parent = node
        elif op == "lease":
            lease = pool.lease(prompt, None)
            state, stacks = pool.snapshot_chain(lease)
            assert stacks == []
            if lease.nodes:
                path = tuple(n.key for n in lease.nodes)
                assert np.array_equal(state, mirror[path])
                assert all(n.refs >= 1 for n in lease.nodes)
                leased.append(lease)
            else:
                assert state is None
                pool.release_lease(lease)
        elif op == "release" and leased:
            pool.release_lease(leased.pop())

    # handle <-> payload bijection
    handles = [n.page for n in pool.tree._walk()]
    assert len(handles) == len(set(handles)) == pool.tree.interned
    assert set(handles) == set(pool._snaps)
    assert pool.snapshots_interned == pool.tree.interned
    # every interned payload matches its mirror entry
    for ck, root in pool.tree._roots.items():
        stack = [(root, ())]
        while stack:
            node, path = stack.pop()
            for key, child in node.children.items():
                p = path + (key,)
                assert np.array_equal(pool._snaps[child.page]["state"],
                                      mirror[p])
                stack.append((child, p))
    # refcounts return to 0
    assert all(n.refs >= 0 for n in pool.tree._walk())
    for lease in leased:
        pool.release_lease(lease)
    assert all(n.refs == 0 for n in pool.tree._walk())
    assert pool.evictable_pages() == pool.tree.interned


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_snapshot_export_import_roundtrip(data):
    """Migration round-trips snapshot chains payload-exactly: the
    destination reproduces every key-path with an equal ``"state"``
    payload at refs 0; re-import is idempotent."""
    model, _ = _model("mamba2-2.7b")
    src = KVPool(model, max_len=MAX_LEN, page_size=PAGE, slots=0,
                 num_pages=64)
    for tag in range(data.draw(st.integers(1, 4), label="prompts")):
        n_tok = data.draw(st.integers(PAGE, MAX_LEN), label="len")
        prompt = np.asarray(data.draw(
            st.lists(st.integers(1, 3), min_size=n_tok, max_size=n_tok),
            label="prompt"), np.int32)
        src.intern_snapshots(prompt, None, _payloads(tag, n_tok // PAGE))

    def _paths(pool):
        out = {}
        for ck, root in pool.tree._roots.items():
            stack = [(root, ())]
            while stack:
                node, path = stack.pop()
                for key, child in node.children.items():
                    p = path + (key,)
                    out[(ck, p)] = child
                    stack.append((child, p))
        return out

    dst = KVPool(model, max_len=MAX_LEN, page_size=PAGE, slots=0,
                 num_pages=64)
    records, stacks = src.export_subtree(None)
    assert len(stacks) == len(records)
    imported = dst.import_subtree(None, records, stacks)
    before, after = _paths(src), _paths(dst)
    assert set(after) == set(before) and imported == len(before)
    for key, node in after.items():
        assert node.refs == 0
        assert np.array_equal(dst._snaps[node.page]["state"],
                              src._snaps[before[key].page]["state"])
    # idempotent
    records, stacks = src.export_subtree(None)
    assert dst.import_subtree(None, records, stacks) == 0
