"""Flight recorder: span lifecycle, histogram sketches, decision audit,
and the Chrome trace export — plus the end-to-end guarantee that one
request yields exactly ONE closed span tree in both colocated and
disaggregated serving.
"""
import json

import numpy as np
import pytest

import jax

from repro.configs.base import smoke_config
from repro.configs.registry import get_arch
from repro.core import DeviceGrid, Supervisor
from repro.core.accounting import CellAccounting, summarize_requests
from repro.core.telemetry import (
    DecisionAudit,
    EventLog,
    FlightRecorder,
    HistogramSketch,
    chrome_trace,
    finish_request,
    mark_admitted,
    open_request,
    recorder_of,
)
from repro.models.model import build_model
from repro.serve.batcher import ContinuousBatcher, Request
from repro.sharding.rules import single_device_ctx

MAX_LEN = 48
SLOTS = 3


@pytest.fixture(scope="module")
def model_and_params():
    cfg = smoke_config(get_arch("qwen3-4b"))
    model = build_model(cfg, single_device_ctx())
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _requests(vocab, lens, max_new=4, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(rid=i, prompt=rng.randint(1, vocab, size=L).astype(np.int32),
                    max_new_tokens=max_new)
            for i, L in enumerate(lens)]


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def test_span_lifecycle_with_fake_clock():
    t = [0.0]
    rec = FlightRecorder("cellA", clock=lambda: t[0])
    root = rec.start_span("request", trace_id=7, prompt_len=12)
    assert root.open and rec.open_spans == [root]
    t[0] = 0.5
    child = rec.start_span("queue", trace_id=7, parent=root.ctx)
    t[0] = 1.25
    child.end(outcome="admitted")
    root.end()
    assert not root.open and rec.open_spans == []
    evs = {e["name"]: e for e in rec.log}
    assert evs["queue"]["parent_id"] == root.span_id
    assert evs["queue"]["dur"] == pytest.approx(0.75)
    assert evs["request"]["dur"] == pytest.approx(1.25)
    assert evs["request"]["attrs"]["prompt_len"] == 12
    # end() is idempotent: a second close must not double-log
    root.end()
    assert sum(1 for e in rec.log if e["name"] == "request") == 1


def test_disabled_recorder_is_total_noop():
    rec = FlightRecorder("off", enabled=False)
    s = rec.start_span("x", trace_id=1)
    s.end()
    rec.add_complete("y", 0.0, 1.0)
    rec.record("lat", 0.5)
    assert len(rec.log) == 0 and rec.hists == {} and rec.open_spans == []
    # accounting=None resolves to the shared disabled recorder
    assert recorder_of(None).enabled is False


def test_event_log_ring_is_bounded_and_counts_drops():
    log = EventLog(capacity=4)
    for i in range(10):
        log.append({"i": i})
    assert len(log) == 4
    assert log.dropped == 6
    assert [e["i"] for e in log] == [6, 7, 8, 9]
    assert [e["i"] for e in log.drain()] == [6, 7, 8, 9]
    assert len(log) == 0


def test_histogram_sketch_tracks_numpy_percentiles():
    rng = np.random.RandomState(0)
    xs = rng.lognormal(mean=-3.0, sigma=1.0, size=20_000)
    h = HistogramSketch(rel_err=0.01)
    for x in xs:
        h.record(x)
    for q, pct in ((0.5, 50), (0.99, 99), (0.999, 99.9)):
        got, want = h.quantile(q), float(np.percentile(xs, pct))
        assert abs(got - want) / want < 0.05, (q, got, want)
    s = h.summary()
    assert s["count"] == len(xs)
    assert s["min"] == pytest.approx(xs.min())
    assert s["max"] == pytest.approx(xs.max())


def test_histogram_sketch_merge_and_roundtrip():
    a, b = HistogramSketch(), HistogramSketch()
    xs = np.linspace(0.001, 1.0, 500)
    for x in xs:
        a.record(x)
        b.record(x)
    b = HistogramSketch.from_dict(json.loads(json.dumps(b.to_dict())))
    a.merge(b)
    assert a.count == 2 * len(xs)
    assert a.quantile(0.5) == pytest.approx(float(np.percentile(xs, 50)),
                                            rel=0.05)
    # zeros bin + empty sketch edges
    z = HistogramSketch()
    assert z.quantile(0.5) is None and z.summary() == {"count": 0}
    z.record(0.0)
    z.record(-1.0)
    # non-positive values collapse into the zeros bin (estimate 0.0);
    # the true minimum survives in the summary
    assert z.quantile(0.5) == 0.0
    assert z.summary()["min"] == -1.0


def test_decision_audit_query_filters_kind_and_cell():
    audit = DecisionAudit()
    audit.record(0, 1.0, {"decode": {"queue_depth": 7}},
                 [{"kind": "scale_replicas", "cell": "decode",
                   "reason": "scale replicas 2->3: queue_depth 7 > 4"}])
    audit.record(1, 2.0, {}, [{"kind": "plan:recover", "cell": "decode/1",
                               "reason": "reconcile: recover decode/1 [failed]"}])
    hits = audit.query(kind="scale")
    assert len(hits) == 1 and "2->3" in hits[0]["reason"]
    assert hits[0]["signals"]["decode"]["queue_depth"] == 7
    assert audit.query(cell="decode/1")[0]["kind"] == "plan:recover"
    assert audit.query(kind="nope") == []


# ---------------------------------------------------------------------------
# accounting satellites
# ---------------------------------------------------------------------------
def test_record_gauge_always_sets_global_entry():
    """Regression: a gauge recorded WITH a tenant label must still move
    the global counter — unlabeled readers (pool occupancy, stats())
    would otherwise read a stale global while the per-tenant mirror
    advanced."""
    acc = CellAccounting("c")
    acc.record_gauge("pages_in_use", 5)
    assert acc.counters["pages_in_use"] == 5
    acc.record_gauge("pages_in_use", 9, tenant="t0")
    assert acc.counters["pages_in_use"] == 9
    assert acc.tenant_counters["t0"]["pages_in_use"] == 9
    acc.record_gauge("pages_in_use", 2)
    assert acc.counters["pages_in_use"] == 2


def test_summarize_requests_reports_p999():
    reqs = [Request(rid=i, prompt=np.ones(4, np.int32), max_new_tokens=1)
            for i in range(100)]
    for i, r in enumerate(reqs):
        r.submitted_at = 0.0
        r.first_token_at = 0.001 * (i + 1)
        r.finished_at = r.first_token_at + 0.01
        r.output = [1, 2]
    s = summarize_requests(reqs)
    assert {"ttft_p50", "ttft_p99", "ttft_p999", "tpot_p999"} <= set(s)
    assert s["ttft_p50"] <= s["ttft_p99"] <= s["ttft_p999"] <= 0.1


# ---------------------------------------------------------------------------
# request helpers
# ---------------------------------------------------------------------------
def test_request_helpers_build_one_closed_tree():
    t = [0.0]
    rec = FlightRecorder("front", clock=lambda: t[0])
    req = Request(rid=3, prompt=np.ones(8, np.int32), max_new_tokens=2)
    req.submitted_at = 0.0
    open_request(rec, req)
    assert open_request(rec, req) is req._tspans["request"]  # idempotent
    t[0] = 0.2
    mark_admitted(req, slot=1)
    t[0] = 1.0
    req.first_token_at, req.finished_at, req.output = 0.3, 1.0, [5, 6]
    finish_request(req, ts=1.0)
    finish_request(req, ts=2.0)                              # idempotent
    assert rec.open_spans == []
    names = [e["name"] for e in rec.log]
    assert names.count("request") == 1 and names.count("finish") == 1
    fin = next(e for e in rec.log if e["name"] == "finish")
    assert fin["attrs"]["outcome"] == "ok"
    assert fin["attrs"]["new_tokens"] == 2
    assert "ttft_s" in rec.hists and "tpot_s" in rec.hists


# ---------------------------------------------------------------------------
# colocated end-to-end
# ---------------------------------------------------------------------------
def test_colocated_requests_yield_closed_span_trees(model_and_params):
    model, params = model_and_params
    acc = CellAccounting("solo")
    bat = ContinuousBatcher(model, params, batch_slots=SLOTS, max_len=MAX_LEN,
                            prefill_chunk=16, accounting=acc)
    reqs = _requests(model.cfg.vocab, [3, 33, 17, 40])
    for r in reqs:
        bat.submit(r)
    done = bat.run_until_drained()
    assert len(done) == len(reqs)

    rec = acc.recorder
    assert rec.open_spans == [], [s.name for s in rec.open_spans]
    roots = [e for e in rec.log if e["name"] == "request"]
    assert sorted(e["trace_id"] for e in roots) == [0, 1, 2, 3]
    assert all(e["dur"] is not None for e in roots)
    # per-request phases all parent back to that request's root
    by_rid = {e["trace_id"]: e for e in roots}
    for name in ("queue", "prefill", "decode", "finish"):
        evs = [e for e in rec.log if e["name"] == name]
        assert len(evs) == len(reqs), name
        for e in evs:
            assert e["parent_id"] == by_rid[e["trace_id"]]["span_id"], name
    assert any(e["name"] == "decode_step" for e in rec.log)
    assert {"ttft_s", "tpot_s", "prefill_s", "decode_step_s"} <= set(rec.hists)


# ---------------------------------------------------------------------------
# disaggregated end-to-end + export
# ---------------------------------------------------------------------------
def test_disagg_span_tree_and_chrome_export(model_and_params, tmp_path):
    from repro.serve.disagg import DisaggServer

    model, params = model_and_params
    cfg = model.cfg
    grid = DeviceGrid.from_flat(jax.devices()[:1], pods=1, rows=1, cols=2,
                                allow_reuse=True)
    sup = Supervisor(grid)
    sup.create_cell("prefill", cfg, "serve", ncols=1)
    dec = sup.create_cell("decode", cfg, "serve", ncols=1)
    dec.init_serve(rng=jax.random.PRNGKey(0))
    srv = DisaggServer(sup, "prefill", "decode", batch_slots=SLOTS,
                       max_len=MAX_LEN, chunk=16)
    reqs = _requests(cfg.vocab, [3, 33, 17, 40])
    for r in reqs:
        srv.submit(r)
    done = srv.run_until_drained()
    assert len(done) == len(reqs)

    # zero leaked spans on ANY cell after drain
    for name, rec in srv._recorders().items():
        assert rec.open_spans == [], (name, [s.name for s in rec.open_spans])

    # one closed root per request, on the front-door (prefill) cell
    prec = recorder_of(srv.prefill_cell.accounting)
    roots = {e["trace_id"]: e for e in prec.log if e["name"] == "request"}
    assert sorted(roots) == [0, 1, 2, 3]
    assert all(e["dur"] is not None for e in roots.values())

    # the full disagg phase chain, each phase parented to its root:
    # queue -> route -> prefill (prefill cell) -> channel -> decode (decode
    # cell) -> finish
    all_events = [e for _, rec in srv._recorders().items() for e in rec.log]
    for name in ("queue", "route", "prefill", "channel", "decode", "finish"):
        evs = [e for e in all_events if e["name"] == name
               and e.get("trace_id") is not None]
        assert len(evs) >= len(reqs), name
        for e in evs:
            assert e["parent_id"] == roots[e["trace_id"]]["span_id"], name
    drec = recorder_of(dec.accounting)
    assert any(e["name"] == "decode" for e in drec.log)
    # per-transfer spans land on the SENDING cell (exact attribution)
    assert any(e["name"] == "xfer:kv" for e in prec.log)

    # export: valid JSON, Perfetto-shaped, round-trips through json.loads
    path = tmp_path / "trace.json"
    trace = srv.trace_export(str(path))
    loaded = json.loads(path.read_text())
    assert loaded["traceEvents"] and loaded["displayTimeUnit"] == "ms"
    assert len(loaded["traceEvents"]) == len(trace["traceEvents"])
    for ev in loaded["traceEvents"]:
        assert {"ph", "ts", "pid", "tid"} <= set(ev), ev
        assert ev["ph"] in ("X", "M", "i")
        if ev["ph"] == "X":
            assert ev["ts"] >= 0.0 and ev["dur"] >= 0.0
    # one pid per cell, tid = request id on request-scoped events
    names = {ev["args"]["name"] for ev in loaded["traceEvents"]
             if ev["ph"] == "M"}
    assert {"cell:prefill", "cell:decode"} <= names
    tids = {ev["tid"] for ev in loaded["traceEvents"]
            if ev["ph"] == "X" and ev["name"] == "request"}
    assert tids == {0, 1, 2, 3}

    # histogram summaries fold into stats()
    st = srv.stats()
    tel = st["telemetry"]
    assert tel["ttft_s"]["count"] == len(reqs)
    assert {"p50", "p99", "p999"} <= set(tel["ttft_s"])
    assert "xfer_kv_bytes" in tel


def test_daemon_audit_explains_actions():
    """A daemon tick records observed signals + audited actions; the
    Chrome export folds them in as instant events on a daemon pid."""

    class _FakePlan:
        ops = ()

        def summary(self):
            return "noop"

    class _FakeSup:
        cells: dict = {}
        desired = None

        def check_health(self):
            return ["decode/1"]

        def reconcile(self):
            return _FakePlan()

    d = None
    from repro.core.daemon import SupervisorDaemon
    d = SupervisorDaemon(_FakeSup())
    d.tick(now=1.0)
    hits = d.audit.query(kind="mark_failed")
    assert len(hits) == 1 and hits[0]["cell"] == "decode/1"
    assert "heartbeat" in hits[0]["reason"]

    trace = chrome_trace([], audit=d.audit)
    inst = [e for e in trace["traceEvents"] if e["ph"] == "i"]
    assert len(inst) == 1 and inst[0]["name"] == "mark_failed"
    assert trace["otherData"]["decision_audit"][0]["tick"] == 0
