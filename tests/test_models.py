"""Per-arch smoke tests (reduced configs) + decode/teacher-forcing parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.configs.registry import ARCHS
from repro.models.model import build_model
from repro.sharding.rules import single_device_ctx

ARCH_NAMES = sorted(ARCHS)


def _batch(cfg, rng, B=2, S=32):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "encdec":
        batch["src"] = jax.random.normal(rng, (B, S, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_train_step(name):
    """Reduced same-family config: one forward/loss on CPU, shapes + no NaNs."""
    cfg = smoke_config(ARCHS[name])
    ctx = single_device_ctx()
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss), (name, float(loss))
    assert loss.shape == ()
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_matches_teacher_forcing(name):
    """decode(t) after prefill(t-1 tokens) must equal the full forward's
    next-token logits — the strongest cache-correctness check we have."""
    cfg = smoke_config(ARCHS[name])
    ctx = single_device_ctx()
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    rng = jax.random.PRNGKey(2)
    batch = _batch(cfg, rng, B=B, S=S)

    # full teacher-forced pass: logits at the last position
    pf_full = {k: v for k, v in batch.items() if k != "labels"}
    cache_full = model.init_cache(B, S)
    logits_full, _ = jax.jit(model.prefill)(params, pf_full, cache_full)

    # prefill S-1 then decode token S-1
    pf = dict(pf_full)
    pf["tokens"] = pf_full["tokens"][:, : S - 1]
    if cfg.family == "encdec":
        pf["src"] = pf_full["src"]
    cache = model.init_cache(B, S)
    _, cache = jax.jit(model.prefill)(params, pf, cache)
    dec = {
        "tokens": pf_full["tokens"][:, S - 1 :],
        "pos": jnp.full((B,), S - 1, jnp.int32),
    }
    logits_dec, _ = jax.jit(model.decode)(params, cache, dec)

    a = np.asarray(logits_full, np.float32)
    b = np.asarray(logits_dec, np.float32)
    # compare over the real vocab (padded tail is -inf on both)
    a, b = a[:, : cfg.vocab], b[:, : cfg.vocab]
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-6)
    assert rel < 5e-2, (name, rel)     # bf16 params; fp32 softmax path
    # argmax agreement is the serving-level requirement
    assert (a.argmax(-1) == b.argmax(-1)).mean() > 0.9, name


def test_swa_rolling_cache_decode():
    """Sliding-window arch: decode with a rolling window buffer must match
    decode with a full-length cache (window masking equivalence)."""
    cfg = smoke_config(ARCHS["mixtral-8x7b"])  # window=64 in smoke
    ctx = single_device_ctx()
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 48   # < window: rolling and full caches agree exactly
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)

    cache = model.init_cache(B, S)
    _, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :-1]}, cache)
    dec = {"tokens": toks[:, -1:], "pos": jnp.full((B,), S - 1, jnp.int32)}
    logits_a, _ = jax.jit(model.decode)(params, cache, dec)

    cache_full = model.init_cache(B, S)
    logits_b, _ = jax.jit(model.prefill)(params, {"tokens": toks}, cache_full)
    a = np.asarray(logits_a)[:, : cfg.vocab]
    b = np.asarray(logits_b)[:, : cfg.vocab]
    rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-6)
    assert rel < 5e-2, rel


def test_vocab_padding_masked():
    cfg = smoke_config(ARCHS["qwen3-4b"]).replace(vocab=500, vocab_pad_multiple=128)
    ctx = single_device_ctx()
    model = build_model(cfg, ctx)
    assert model.vocab_padded == 512
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(1, 8)
    logits, _ = jax.jit(model.prefill)(
        params, {"tokens": jnp.zeros((1, 8), jnp.int32)}, cache
    )
    assert np.all(np.asarray(logits)[:, 500:] < -1e29)


def test_param_counts_match_published_scale():
    """Full configs should land near their nameplate parameter counts."""
    ctx = single_device_ctx()
    expect = {
        "mixtral-8x7b": (45e9, 48e9),
        "deepseek-moe-16b": (15e9, 18e9),
        "qwen3-4b": (3.5e9, 4.5e9),
        "deepseek-coder-33b": (32e9, 35e9),
        "qwen2.5-32b": (31e9, 34e9),
        "nemotron-4-340b": (320e9, 350e9),
        "mamba2-2.7b": (2.4e9, 3.0e9),
        "chameleon-34b": (32e9, 36e9),
        "zamba2-2.7b": (2.4e9, 3.2e9),
        "seamless-m4t-large-v2": (1.4e9, 2.8e9),
    }
    for name, (lo, hi) in expect.items():
        n = build_model(ARCHS[name], ctx).n_params()
        assert lo <= n <= hi, (name, n / 1e9)
