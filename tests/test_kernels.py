"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention, decode_attention_ref, lse_combine
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.moe_gmm import gmm, gmm_ref
from repro.kernels.ssd_scan import ssd
from repro.models.mamba2 import ssd_chunked


def _rel(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.abs(a - b).max() / (np.abs(b).max() + 1e-9)


FLASH_CASES = [
    # B, Hq, Hkv, Sq, Skv, Dh, causal, window, dtype
    (2, 4, 2, 128, 128, 64, True, None, jnp.float32),
    (1, 8, 8, 256, 256, 64, True, None, jnp.bfloat16),
    (2, 4, 1, 128, 128, 32, True, 64, jnp.bfloat16),   # MQA + sliding window
    (1, 2, 2, 128, 256, 64, True, None, jnp.float32),  # q suffix of longer kv
    (2, 4, 2, 128, 128, 64, False, None, jnp.float32), # bidirectional
    (1, 4, 4, 64, 64, 128, True, None, jnp.float32),   # big head dim
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_oracle(case):
    B, Hq, Hkv, Sq, Skv, Dh, causal, win, dt = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, Dh), jnp.float32).astype(dt)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, Dh), jnp.float32).astype(dt)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, Dh), jnp.float32).astype(dt)
    out = flash_attention(q, k, v, causal=causal, window=win, block_q=64, block_k=64)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal, window=win,
    ).transpose(0, 2, 1, 3)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    assert _rel(out, ref) < tol, case


def test_flash_attention_block_shape_invariance():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    outs = [
        flash_attention(q, k, v, block_q=bq, block_k=bk)
        for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]
    ]
    for o in outs[1:]:
        assert _rel(o, outs[0]) < 1e-5


DECODE_CASES = [
    (2, 8, 2, 512, 64, jnp.float32),
    (4, 4, 4, 256, 128, jnp.bfloat16),
    (1, 16, 2, 1024, 64, jnp.bfloat16),
    (3, 2, 1, 128, 32, jnp.float32),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_matches_oracle(case):
    B, Hq, Hkv, S, Dh, dt = case
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, Dh), jnp.float32).astype(dt)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), jnp.float32).astype(dt)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), jnp.float32).astype(dt)
    kv_len = (jnp.arange(B, dtype=jnp.int32) * 37 + S // 3) % S + 1
    out = decode_attention(q, k, v, kv_len, block_k=64)
    ref = decode_attention_ref(q[:, 0], k, v, kv_len)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    assert _rel(out[:, 0], ref) < tol, case


def test_lse_combine_equals_monolithic():
    """Split-KV partials merged with lse_combine == one-shot softmax."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    S, Dh = 128, 32
    q = jax.random.normal(ks[0], (Dh,))
    k = jax.random.normal(ks[1], (S, Dh))
    v = jax.random.normal(ks[2], (S, Dh))
    s = k @ q / np.sqrt(Dh)
    ref = jax.nn.softmax(s) @ v
    ms, ls, accs = [], [], []
    for i in range(4):
        si = s[i * 32:(i + 1) * 32]
        m = si.max()
        p = jnp.exp(si - m)
        ms.append(m)
        ls.append(p.sum())
        accs.append(p @ v[i * 32:(i + 1) * 32])
    out = lse_combine(jnp.stack(ms), jnp.stack(ls), jnp.stack(accs))
    assert _rel(out, ref) < 1e-5


def test_ssd_kernel_matches_chunked_oracle():
    Bb, S, H, P, G, N = 2, 128, 4, 16, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (Bb, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S, H)) * 0.5)
    A_log = jax.random.uniform(ks[2], (H,), minval=0.0, maxval=1.5)
    B = jax.random.normal(ks[3], (Bb, S, G, N)) * 0.3
    C = jax.random.normal(ks[4], (Bb, S, G, N)) * 0.3
    for chunk in (16, 32, 64):
        y_k, h_k = ssd(x, dt, A_log, B, C, chunk=chunk)
        y_r, h_r = ssd_chunked(x, dt, A_log, B, C, chunk=chunk)
        assert _rel(y_k, y_r) < 1e-5, chunk
        assert _rel(h_k, h_r) < 1e-5, chunk


def test_ssd_kernel_initial_state():
    Bb, S, H, P, G, N = 1, 64, 2, 8, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    x = jax.random.normal(ks[0], (Bb, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S, H)) * 0.5)
    A_log = jax.random.uniform(ks[2], (H,), minval=0.0, maxval=1.0)
    B = jax.random.normal(ks[3], (Bb, S, G, N)) * 0.3
    C = jax.random.normal(ks[4], (Bb, S, G, N)) * 0.3
    init = jax.random.normal(ks[5], (Bb, H, P, N)) * 0.2
    y_k, _ = ssd(x, dt, A_log, B, C, chunk=16, initial_state=init)
    y_r, _ = ssd_chunked(x, dt, A_log, B, C, chunk=16, initial_state=init)
    assert _rel(y_k, y_r) < 1e-5


def test_ssd_kernel_pad_mask_exact():
    """Pad-token masking: kernel and oracle under a ragged (B,S) validity
    mask must agree with each other AND with running each row truncated
    to its real length — pads make no state update (dA=0, dt*x=0)."""
    Bb, S, H, P, G, N = 3, 64, 2, 8, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    x = jax.random.normal(ks[0], (Bb, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S, H)) * 0.5)
    A_log = jax.random.uniform(ks[2], (H,), minval=0.0, maxval=1.0)
    B = jax.random.normal(ks[3], (Bb, S, G, N)) * 0.3
    C = jax.random.normal(ks[4], (Bb, S, G, N)) * 0.3
    lengths = np.array([64, 17, 1])            # full row, ragged, all-pad tail
    mask = jnp.asarray(np.arange(S)[None, :] < lengths[:, None])

    y_k, h_k = ssd(x, dt, A_log, B, C, chunk=16, mask=mask)
    y_r, h_r = ssd_chunked(x, dt, A_log, B, C, chunk=16, mask=mask)
    assert _rel(h_k, h_r) < 1e-5
    for b, L in enumerate(lengths):
        # truncated single-row reference: state at the last REAL token
        # (row length need not be a chunk multiple — the scan degrades its
        # chunk to a divisor)
        _, h_t = ssd_chunked(x[b:b + 1, :L], dt[b:b + 1, :L], A_log,
                             B[b:b + 1, :L], C[b:b + 1, :L], chunk=16)
        assert _rel(h_k[b:b + 1], h_t) < 1e-5, (b, L)
        assert _rel(y_k[b:b + 1, :L], y_r[b:b + 1, :L]) < 1e-5, (b, L)


@pytest.mark.parametrize("counts", [
    [0, 5, 128, 256, 129, 200, 1, 64],
    [0, 0, 0, 0, 0, 0, 0, 0],
    [256] * 8,
])
def test_moe_gmm_matches_oracle(counts):
    E, Cc, D, F = 8, 256, 128, 256
    x = jax.random.normal(jax.random.PRNGKey(6), (E, Cc, D), jnp.float32).astype(jnp.bfloat16)
    w = (jax.random.normal(jax.random.PRNGKey(7), (E, D, F)) * 0.05).astype(jnp.bfloat16)
    c = jnp.array(counts, jnp.int32)
    out = gmm(x, w, c, block_c=64, block_f=128)
    ref = gmm_ref(x, w, c)
    assert _rel(out, ref) < 2e-2
