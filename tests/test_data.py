"""Data pipeline: determinism, learnability floor, encdec frontend stub."""
import numpy as np

from repro.configs.base import ShapeConfig, smoke_config
from repro.configs.registry import get_arch
from repro.data.pipeline import DataConfig, SyntheticPipeline


def test_deterministic_batches():
    cfg = smoke_config(get_arch("qwen3-4b"))
    shape = ShapeConfig("t", "train", 16, 4)
    a = SyntheticPipeline(DataConfig(kind="bigram", seed=7), cfg, shape)
    b = SyntheticPipeline(DataConfig(kind="bigram", seed=7), cfg, shape)
    ba, bb = a.get_batch(13), b.get_batch(13)
    np.testing.assert_array_equal(np.asarray(ba["tokens"]), np.asarray(bb["tokens"]))
    assert not np.array_equal(np.asarray(a.get_batch(14)["tokens"]),
                              np.asarray(ba["tokens"]))


def test_labels_are_next_tokens():
    cfg = smoke_config(get_arch("qwen3-4b"))
    shape = ShapeConfig("t", "train", 32, 2)
    p = SyntheticPipeline(DataConfig(kind="bigram"), cfg, shape)
    b = p.get_batch(0)
    np.testing.assert_array_equal(
        np.asarray(b["tokens"])[:, 1:], np.asarray(b["labels"])[:, :-1])


def test_bigram_entropy_floor_reasonable():
    cfg = smoke_config(get_arch("qwen3-4b"))
    p = SyntheticPipeline(DataConfig(kind="bigram", branching=8),
                          cfg, ShapeConfig("t", "train", 8, 2))
    h = p.bigram_entropy()
    assert 0.5 < h < np.log(8) + 1e-6


def test_encdec_src_embeddings():
    cfg = smoke_config(get_arch("seamless-m4t-large-v2"))
    shape = ShapeConfig("t", "train", 16, 2)
    p = SyntheticPipeline(DataConfig(kind="bigram"), cfg, shape)
    b = p.get_batch(0)
    assert b["src"].shape == (2, 16, cfg.d_model)
    assert str(b["src"].dtype) == "bfloat16"
