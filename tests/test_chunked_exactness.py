"""Family-parameterized chunked-prefill exactness suite.

For EVERY config in ``configs/registry.py`` (reduced to its smoke shape):

  * capability single-source-of-truth — ``Model.chunked_prefill_exact``,
    the ``NotImplementedError`` guard inside ``prefill_ranged`` and
    ``supports_chunked_prefill`` must agree (the old hardcoded family
    tuples could drift);
  * ``prefill_ranged`` logits at the last real token of a bucket-padded
    row match the exact-length ``prefill`` program;
  * the full serving trajectory (chunked prefill + decode) matches the
    token-at-a-time path on ragged prompt batches — including batch-pad
    dummy rows (5 prompts -> power-of-two bucket padding) and, for
    encdec, per-request ragged source features;
  * a sliding-window config (mixtral smoke, window 64) runs the chunked
    path when ``window >= max_len`` and this suite still passes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import smoke_config
from repro.configs.registry import ARCHS
from repro.models.model import build_model
from repro.serve.batcher import ContinuousBatcher, Request
from repro.serve.serve_step import supports_chunked_prefill
from repro.sharding.rules import single_device_ctx

ARCH_NAMES = sorted(ARCHS)
MAX_LEN = 32
CHUNK = 8
SLOTS = 3

_CACHE = {}


def _model(name):
    if name not in _CACHE:
        cfg = smoke_config(ARCHS[name])
        model = build_model(cfg, single_device_ctx())
        _CACHE[name] = (model, model.init(jax.random.PRNGKey(0)))
    return _CACHE[name]


def _requests(model, lens, max_new=4, seed=0):
    rng = np.random.RandomState(seed)
    cfg = model.cfg
    out = []
    for i, L in enumerate(lens):
        src = None
        if cfg.family == "encdec":
            # ragged per-request source features (different lengths so the
            # src_len mask, not the common pad, must carry the exactness)
            src = rng.randn(5 + 3 * i, cfg.d_model).astype(np.float32)
        out.append(Request(rid=i, prompt=rng.randint(1, cfg.vocab, size=L)
                           .astype(np.int32), max_new_tokens=max_new, src=src))
    return out


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_capability_single_source_of_truth(name):
    """prefill_ranged's guard and supports_chunked_prefill may not drift:
    both must reduce to Model.chunked_prefill_exact for every registered
    config (the tentpole: that property is True for ALL families now)."""
    model, _ = _model(name)
    assert model.chunked_prefill_exact, name

    batch = {"tokens": jnp.zeros((1, CHUNK), jnp.int32),
             "length": jnp.ones((1,), jnp.int32)}
    batch.update(model.ranged_batch_extras([None], MAX_LEN))
    raised = False
    try:
        jax.eval_shape(model.prefill_ranged, model.abstract_params(), batch,
                       model.abstract_cache(1, MAX_LEN))
    except NotImplementedError:
        raised = True
    assert raised == (not model.chunked_prefill_exact), name

    w = model.cfg.sliding_window
    assert supports_chunked_prefill(model, MAX_LEN) == (
        model.chunked_prefill_exact and (w is None or w >= MAX_LEN)), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_ranged_matches_exact_length(name):
    """Bucket-padded prefill_ranged == exact-length prefill at the last
    real token, for every family (incl. an almost-all-pad row)."""
    model, params = _model(name)
    cfg = model.cfg
    rng = np.random.RandomState(1)
    src = (rng.randn(9, cfg.d_model).astype(np.float32)
           if cfg.family == "encdec" else None)
    for L in (1, 11):                       # L=1: 15-pad tail in bucket 16
        prompt = rng.randint(1, cfg.vocab, size=L).astype(np.int32)
        ref_batch = {"tokens": jnp.asarray(prompt[None])}
        if src is not None:
            ex = model.ranged_batch_extras([src], MAX_LEN)
            ref_batch.update(ex)
        ref_logits, _ = model.prefill(params, ref_batch,
                                      model.init_cache(1, MAX_LEN))

        s_pad = 16
        padded = np.zeros((1, s_pad), np.int32)
        padded[0, :L] = prompt
        batch = {"tokens": jnp.asarray(padded),
                 "length": jnp.asarray([L], jnp.int32)}
        batch.update(model.ranged_batch_extras([src], MAX_LEN))
        got_logits, _ = model.prefill_ranged(params, batch,
                                             model.init_cache(1, MAX_LEN))
        a = np.asarray(got_logits, np.float32)[:, : cfg.vocab]
        b = np.asarray(ref_logits, np.float32)[:, : cfg.vocab]
        rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-6)
        assert rel < 1e-4, (name, L, rel)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_trajectory_matches_token_at_a_time(name):
    """Chunked serving trajectory == token-at-a-time trajectory on a
    ragged batch (5 prompts: bucket grouping + power-of-two dummy-row
    padding both exercised)."""
    model, params = _model(name)
    lens = [3, 17, 1, 20, 9]

    base = ContinuousBatcher(model, params, batch_slots=SLOTS,
                             max_len=MAX_LEN, prefill_chunk=None)
    for r in _requests(model, lens):
        base.submit(r)
    ref = {r.rid: r.output for r in base.run_until_drained()}
    assert base.prefill_invocations == 0

    chunked = ContinuousBatcher(model, params, batch_slots=SLOTS,
                                max_len=MAX_LEN, prefill_chunk=CHUNK)
    assert chunked.chunked, name
    for r in _requests(model, lens):
        chunked.submit(r)
    got = {r.rid: r.output for r in chunked.run_until_drained()}

    assert got == ref, name
    assert 0 < chunked.prefill_invocations <= len(lens)
    assert chunked.decode_invocations < base.decode_invocations
