PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test lint bench-smoke bench

# tier-1 verify (see ROADMAP.md)
test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

# ruff (pinned in requirements-dev.txt; config in ruff.toml)
lint:
	ruff check src tests benchmarks examples

# one registered config per family — a reintroduced family gate in the
# serving plane fails this sweep fast
BENCH_FAMILY_ARCHS := qwen3-4b mixtral-8x7b mamba2-2.7b zamba2-2.7b seamless-m4t-large-v2

# CI-friendly benchmark smoke: colocated-vs-disaggregated serving latency
# (small shapes, swept over one config per family: dense, moe, ssm,
# hybrid, encdec) + the paged-vs-dense decode step-time gate (native
# paged step must be <= 1.0x the dense-cache step; skipped for
# non-pageable families) + the daemon-driven elastic scheduling trace
# (short) + the prefix-cache cold/warm gate — paged (warm TTFT < 0.6x
# cold, kv bytes saved) AND snapshot ssm/hybrid (warm TTFT < 0.7x cold,
# snapshot bytes saved, warm channel bytes < cold)
bench-smoke:
	for arch in $(BENCH_FAMILY_ARCHS); do \
		PYTHONPATH=$(PYTHONPATH) python benchmarks/disagg_serving.py --smoke --arch $$arch || exit 1; \
	done
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.elastic_sched --smoke
	PYTHONPATH=$(PYTHONPATH) python benchmarks/prefix_cache.py --smoke
	PYTHONPATH=$(PYTHONPATH) python benchmarks/prefix_cache.py --smoke --arch mamba2-2.7b
	PYTHONPATH=$(PYTHONPATH) python benchmarks/prefix_cache.py --smoke --arch zamba2-2.7b
	PYTHONPATH=$(PYTHONPATH) python benchmarks/multitenant.py --smoke
	PYTHONPATH=$(PYTHONPATH) python benchmarks/cluster_cache.py --smoke

# full benchmark harness (paper tables/figures)
bench:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/run.py
