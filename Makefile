PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test lint bench-smoke bench

# tier-1 verify (see ROADMAP.md)
test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

# ruff (pinned in requirements-dev.txt; config in ruff.toml)
lint:
	ruff check src tests benchmarks examples

# CI-friendly benchmark smoke: colocated-vs-disaggregated serving latency
# (small shapes) + the daemon-driven elastic scheduling trace (short)
bench-smoke:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/disagg_serving.py --smoke
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.elastic_sched --smoke

# full benchmark harness (paper tables/figures)
bench:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/run.py
