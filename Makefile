PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
ARTIFACTS := artifacts

.PHONY: test lint bench-smoke bench trace-demo

# tier-1 verify (see ROADMAP.md)
test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

# ruff (pinned in requirements-dev.txt; config in ruff.toml)
lint:
	ruff check src tests benchmarks examples

# one registered config per family — a reintroduced family gate in the
# serving plane fails this sweep fast
BENCH_FAMILY_ARCHS := qwen3-4b mixtral-8x7b mamba2-2.7b zamba2-2.7b seamless-m4t-large-v2

# CI-friendly benchmark smoke: colocated-vs-disaggregated serving latency
# (small shapes, swept over one config per family: dense, moe, ssm,
# hybrid, encdec) + the paged-vs-dense decode step-time gate (native
# paged step must be <= 1.0x the dense-cache step; skipped for
# non-pageable families) + the telemetry-overhead gate (flight recorder
# on <= 1.05x off on the decode step) + the daemon-driven elastic
# scheduling trace (short) + the prefix-cache cold/warm gate — paged
# (warm TTFT < 0.6x cold, kv bytes saved) AND snapshot ssm/hybrid (warm
# TTFT < 0.7x cold, snapshot bytes saved, warm channel bytes < cold).
# Every run's CSV is captured under $(ARTIFACTS)/ and folded into one
# bench_smoke.json for the CI artifact upload.
bench-smoke:
	mkdir -p $(ARTIFACTS)
	for arch in $(BENCH_FAMILY_ARCHS); do \
		PYTHONPATH=$(PYTHONPATH) python benchmarks/disagg_serving.py --smoke --arch $$arch > $(ARTIFACTS)/disagg_serving_$$arch.csv || exit 1; \
		cat $(ARTIFACTS)/disagg_serving_$$arch.csv; \
	done
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.elastic_sched --smoke > $(ARTIFACTS)/elastic_sched.csv
	cat $(ARTIFACTS)/elastic_sched.csv
	PYTHONPATH=$(PYTHONPATH) python benchmarks/prefix_cache.py --smoke > $(ARTIFACTS)/prefix_cache.csv
	cat $(ARTIFACTS)/prefix_cache.csv
	PYTHONPATH=$(PYTHONPATH) python benchmarks/prefix_cache.py --smoke --arch mamba2-2.7b > $(ARTIFACTS)/prefix_cache_mamba2.csv
	cat $(ARTIFACTS)/prefix_cache_mamba2.csv
	PYTHONPATH=$(PYTHONPATH) python benchmarks/prefix_cache.py --smoke --arch zamba2-2.7b > $(ARTIFACTS)/prefix_cache_zamba2.csv
	cat $(ARTIFACTS)/prefix_cache_zamba2.csv
	PYTHONPATH=$(PYTHONPATH) python benchmarks/multitenant.py --smoke > $(ARTIFACTS)/multitenant.csv
	cat $(ARTIFACTS)/multitenant.csv
	PYTHONPATH=$(PYTHONPATH) python benchmarks/cluster_cache.py --smoke > $(ARTIFACTS)/cluster_cache.csv
	cat $(ARTIFACTS)/cluster_cache.csv
	python benchmarks/smoke_json.py $(ARTIFACTS)/*.csv -o $(ARTIFACTS)/bench_smoke.json

# Perfetto-openable demo trace: the closed-loop serving example
# (autoscale + kill-column self-heal) exports its flight-recorder state
# + daemon decision audit as Chrome trace-event JSON
trace-demo:
	mkdir -p $(ARTIFACTS)
	PYTHONPATH=$(PYTHONPATH) python examples/serve_disagg.py --trace-out $(ARTIFACTS)/serve_disagg_trace.json

# full benchmark harness (paper tables/figures)
bench:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/run.py
