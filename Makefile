PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test lint bench-smoke bench

# tier-1 verify (see ROADMAP.md)
test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

# ruff (pinned in requirements-dev.txt; config in ruff.toml)
lint:
	ruff check src tests benchmarks examples

# colocated-vs-disaggregated serving latency, small shapes (CI-friendly)
bench-smoke:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/disagg_serving.py --smoke

# full benchmark harness (paper tables/figures)
bench:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/run.py
