"""Logical-axis -> mesh-axis sharding rules.

Every parameter / activation dim carries a *logical* axis name; the rules
below map it to (tuples of) mesh axes.  Resolution is divisibility-aware:
an axis that does not divide the dim is dropped (safe fallback to
replication) and the drop is recorded so the dry-run can report it.

Baseline rule set (paper-faithful cell layout):
  batch     -> ("pod", "data")      DP over pods and the data axis
  vocab     -> "model"              vocab-parallel embedding / logits
  heads     -> "model"              Megatron TP for attention
  kv_heads  -> "model"              (dropped when n_kv < model-axis size)
  ffn       -> "model"              Megatron TP for MLPs
  expert    -> "model"              EP when E divides the model axis
  expert_ffn-> "model"              TP-in-expert when EP not divisible
  inner/ssm_heads -> "model"        Mamba d_inner / SSD head parallelism
  embed     -> "data"               ZeRO-3/FSDP weight sharding
  kv_seq    -> ("data", "model")    decode KV cache sequence sharding (SP)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.param import tree_map_pspec


Axes = Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Per-cell sharding context: the cell's mesh + axis roles.

    ``dp_over_model``: ZeRO-3 layout — the model axis joins the batch axes
    (256-way DP), weights keep FSDP sharding, and only the vocab head stays
    model-parallel.  Right for archs whose per-layer TP activation
    collectives dwarf their weight traffic (small dense models).
    """

    mesh: Mesh
    batch_axes: Axes = ("data",)
    model_axis: Optional[str] = "model"
    fsdp: bool = True
    dp_over_model: bool = False

    @property
    def axis_sizes(self) -> Dict[str, int]:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    @property
    def all_axes(self) -> Axes:
        return tuple(self.mesh.axis_names)

    def dp_size(self) -> int:
        sizes = self.axis_sizes
        axes = self.rules()["batch"]
        return int(np.prod([sizes[a] for a in axes]))

    def model_size(self) -> int:
        if self.model_axis is None:
            return 1
        return self.axis_sizes[self.model_axis]

    # ---- rules ------------------------------------------------------------
    def rules(self) -> Dict[str, Axes]:
        m = (self.model_axis,) if self.model_axis else ()
        fsdp_axes: Axes = (
            tuple(a for a in ("pod", "data") if a in self.axis_sizes)
            if self.fsdp else ()
        )
        if self.dp_over_model:
            # ZeRO-3: no per-layer tensor parallelism; all device axes do
            # data parallelism.  The head keeps vocab parallelism with the
            # batch dim backing off to the data axes ("batch_head") so the
            # (B, S, V) logits never materialize a full vocab per device.
            return {
                "batch": self.batch_axes + m,
                "batch_head": self.batch_axes,
                "vocab": m,
                "heads": (), "kv_heads": (), "ffn": (),
                "expert": (), "expert_ffn": (), "inner": (), "ssm_heads": (),
                "embed": fsdp_axes,
                "kv_seq": (),
                "act_seq": (), "act_embed": (),
            }
        return {
            "batch": self.batch_axes,
            "batch_head": self.batch_axes,
            "vocab": m,
            "heads": m,
            "kv_heads": m,
            "ffn": m,
            "expert": m,
            "expert_ffn": m,
            "inner": m,
            "ssm_heads": m,
            # embed: FSDP when on; in serve mode (fsdp off) fall back to the
            # model axis so weights whose TP dim doesn't divide it (56/40
            # heads on a 16-axis) don't end up fully replicated.  "embed"
            # resolves LAST (see pspec priority), so TP dims keep the model
            # axis whenever they can use it.
            "embed": fsdp_axes if self.fsdp else m,
            "kv_seq": tuple(a for a in ("data",) + m if a in self.axis_sizes),
            "act_seq": m,       # sequence dim of the residual stream
            "act_embed": m,     # d_model dim of the residual stream
        }

    # ---- resolution -------------------------------------------------------
    # resolution priority: batch dims bind first (the decode cache's batch
    # dim must win the data axis over kv_seq), then TP dims, then "embed"
    # (so its model-axis serve fallback never steals from a TP dim)
    _PRIORITY = {"batch": 0, "batch_head": 0, "embed": 2}

    def pspec(self, logical: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        """Resolve logical axes to a PartitionSpec, divisibility-aware."""
        rules = self.rules()
        sizes = self.axis_sizes
        used: set = set()
        parts: list = [None] * len(shape)
        order = sorted(
            range(len(shape)),
            key=lambda i: (self._PRIORITY.get(logical[i], 1), i),
        )
        for i in order:
            dim, name = shape[i], logical[i]
            if name is None or name not in rules:
                continue
            cand = rules[name]
            chosen = []
            prod = 1
            for ax in cand:
                if ax in used or ax not in sizes:
                    continue
                if dim % (prod * sizes[ax]) == 0:
                    chosen.append(ax)
                    prod *= sizes[ax]
            if not chosen:
                continue
            parts[i] = chosen[0] if len(chosen) == 1 else tuple(chosen)
            used.update(chosen)
        return P(*parts)

    def sharding(self, logical: Sequence[Optional[str]], shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(logical, shape))

    def params_pspecs(self, spec_tree):
        """PartitionSpec tree for a PSpec tree."""
        return tree_map_pspec(lambda s: self.pspec(s.logical, s.shape), spec_tree)

    def params_shardings(self, spec_tree):
        return tree_map_pspec(
            lambda s: NamedSharding(self.mesh, self.pspec(s.logical, s.shape)),
            spec_tree,
        )

    def activation_pspec(self, logical: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        return self.pspec(logical, shape)

    # manual shard_map axis bookkeeping
    @property
    def manual_axes(self) -> frozenset:
        return frozenset(a for a in self.all_axes)


def shard_map_compat(f, *, mesh, in_specs, out_specs, axis_names,
                     check_vma: bool = False):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes ``jax.shard_map(..., axis_names=, check_vma=)``; older
    releases only have ``jax.experimental.shard_map.shard_map`` where manual
    axes are the complement of ``auto`` and the flag is ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=axis_names, check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(
        f, mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma, auto=auto,
    )


def single_device_ctx() -> ShardCtx:
    """A trivial ctx for single-device tests (same code paths)."""
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    return ShardCtx(mesh=mesh, batch_axes=("data",), model_axis="model")


def make_ctx(mesh: Mesh, fsdp: bool = True, dp_over_model: bool = False) -> ShardCtx:
    """Infer axis roles from mesh axis names (pod/data/model conventions)."""
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    model_axis = "model" if "model" in names else None
    return ShardCtx(mesh=mesh, batch_axes=batch_axes or (names[0],),
                    model_axis=model_axis, fsdp=fsdp,
                    dp_over_model=dp_over_model)
