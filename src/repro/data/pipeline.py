"""Deterministic synthetic data pipeline.

Two generators:
  * ``uniform``: i.i.d. uniform tokens (throughput/dry-run shapes).
  * ``bigram``:  sequences from a fixed random bigram chain — a learnable
    task (a trained LM's loss approaches the chain's conditional entropy),
    used by the end-to-end training examples to show real learning.

The pipeline is sharded-by-construction: ``global_batch`` rows are assigned
round-robin to data shards by index, each host materializes only its rows
(single-host here, but the addressing is multi-host correct), and arrays are
``device_put`` with the batch sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass
class DataConfig:
    kind: str = "bigram"          # uniform | bigram
    seed: int = 1234
    vocab: int = 512
    branching: int = 8            # bigram: nonzero successors per token


class SyntheticPipeline:
    def __init__(self, cfg: DataConfig, arch: ArchConfig, shape: ShapeConfig,
                 sharding=None):
        self.cfg = cfg
        self.arch = arch
        self.shape = shape
        self.sharding = sharding
        self.vocab = min(cfg.vocab, arch.vocab)
        rng = np.random.default_rng(cfg.seed)
        if cfg.kind == "bigram":
            # sparse row-stochastic transition matrix
            succ = rng.integers(0, self.vocab, size=(self.vocab, cfg.branching))
            probs = rng.dirichlet(np.ones(cfg.branching), size=self.vocab)
            self._succ, self._probs = succ, probs
        if arch.family == "encdec":
            # fixed random "frontend" projecting token ids to frame embeddings
            self._frontend = rng.standard_normal(
                (self.vocab, arch.d_model)).astype(np.float32) / np.sqrt(arch.d_model)

    def _sample_tokens(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        if self.cfg.kind == "uniform":
            return rng.integers(0, self.vocab, size=(batch, seq + 1)).astype(np.int32)
        toks = np.empty((batch, seq + 1), np.int64)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        for t in range(seq):
            cur = toks[:, t]
            choice = np.array(
                [rng.choice(self.cfg.branching, p=self._probs[c]) for c in cur]
            )
            toks[:, t + 1] = self._succ[cur, choice]
        return toks.astype(np.int32)

    def bigram_entropy(self) -> float:
        """Conditional entropy of the chain (nats) — the loss floor."""
        p = self._probs
        h_rows = -(p * np.log(p + 1e-12)).sum(-1)
        return float(h_rows.mean())

    def batches(self, start_step: int = 0) -> Iterator[Dict[str, jax.Array]]:
        step = start_step
        while True:
            yield self.get_batch(step)
            step += 1

    def get_batch(self, step: int) -> Dict[str, jax.Array]:
        """Deterministic batch for a step (restart-safe)."""
        shape, arch = self.shape, self.arch
        rng = np.random.default_rng((self.cfg.seed, step))
        toks = self._sample_tokens(rng, shape.global_batch, shape.seq_len)
        batch = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
        }
        if arch.family == "encdec":
            # source frames = frontend embeddings of the (shifted) targets,
            # truncated/padded to the source length
            s_src = min(shape.seq_len, 4096)
            src_tok = toks[:, 1:1 + s_src]
            if src_tok.shape[1] < s_src:
                src_tok = np.pad(src_tok, ((0, 0), (0, s_src - src_tok.shape[1])))
            batch["src"] = self._frontend[src_tok].astype(np.float32)
        out = {}
        for k, v in batch.items():
            arr = jnp.asarray(v) if k != "src" else jnp.asarray(v, jnp.bfloat16)
            if self.sharding is not None:
                sh = self.sharding.get(k) if isinstance(self.sharding, dict) else self.sharding
                if sh is not None:
                    arr = jax.device_put(arr, sh)
            out[k] = arr
        return out
