"""Pure-jnp oracle for the per-chunk SSD computation."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_chunk_ref(x, dA, B, C):
    """Per-chunk SSD (intra-chunk output + chunk state + chunk decay).

    x:  (Bb, H, nc, Q, P)   dt-weighted inputs
    dA: (Bb, H, nc, Q)      per-step log decays (dt * A, negative)
    B, C: (Bb, G, nc, Q, N) group-shared input/output projections

    Pad-token masking happens UPSTREAM (``ops.ssd`` zeroes ``dt`` at
    masked steps): a step arriving here with dA=0 and x=0 is an identity
    state update, so this per-chunk math needs no mask of its own.

    Returns (y_diag (Bb,H,nc,Q,P), states (Bb,H,nc,P,N), decay (Bb,H,nc)).
    """
    Bb, H, nc, Q, P = x.shape
    G = B.shape[1]
    HG = H // G
    cs = jnp.cumsum(dA, axis=-1)                               # (Bb,H,nc,Q)
    diff = cs[..., :, None] - cs[..., None, :]
    L = jnp.where(jnp.tril(jnp.ones((Q, Q), bool)), jnp.exp(diff), 0.0)
    Bh = jnp.repeat(B, HG, axis=1)                             # (Bb,H,nc,Q,N)
    Ch = jnp.repeat(C, HG, axis=1)
    scores = jnp.einsum("bhcqn,bhckn->bhcqk", Ch, Bh) * L
    y_diag = jnp.einsum("bhcqk,bhckp->bhcqp", scores, x)
    decay_states = jnp.exp(cs[..., -1:] - cs)                  # (Bb,H,nc,Q)
    states = jnp.einsum("bhcqp,bhcqn,bhcq->bhcpn", x, Bh, decay_states)
    decay = jnp.exp(cs[..., -1])                               # (Bb,H,nc)
    return y_diag, states, decay
