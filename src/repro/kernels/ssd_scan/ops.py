"""jit'd SSD wrapper: Pallas per-chunk kernel + jnp inter-chunk recurrence.

Produces the same (y, final_state) contract as
``repro.models.mamba2.ssd_chunked`` (the oracle) and is numerically
interchangeable with it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_chunks
from repro.models.mamba2 import ssd_tiling_chunk

F32 = jnp.float32


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt, A_log, B_in, C_in, *, chunk: int = 256, initial_state=None,
        mask=None):
    """x: (B,S,H,P); dt: (B,S,H); A_log: (H,); B/C: (B,S,G,N).

    ``mask`` (B,S) bool: validity mask for bucket-padded prefill.  Masked
    steps have ``dt`` zeroed BEFORE the per-chunk kernel, so they enter it
    as dA=0 / dt-weighted-x=0 rows — identity state updates through the
    unchanged dense matmuls (no in-kernel control flow), making
    ``final_state`` exact at each row's last real token.
    Returns (y (B,S,H,P) f32, final_state (B,H,P,N) f32).
    """
    Bb, S, H, P_ = x.shape
    G, N = B_in.shape[2], B_in.shape[3]
    Q = ssd_tiling_chunk(S, chunk)
    nc = S // Q

    if mask is not None:
        dt = jnp.where(mask[..., None], dt, jnp.zeros_like(dt))
    A = -jnp.exp(A_log.astype(F32))
    dA = (dt.astype(F32) * A)                                  # (B,S,H)
    xw = x.astype(F32) * dt.astype(F32)[..., None]

    xk = xw.reshape(Bb, nc, Q, H, P_).transpose(0, 3, 1, 2, 4)     # (B,H,nc,Q,P)
    dAk = dA.reshape(Bb, nc, Q, H).transpose(0, 3, 1, 2)           # (B,H,nc,Q)
    Bk = B_in.astype(F32).reshape(Bb, nc, Q, G, N).transpose(0, 3, 1, 2, 4)
    Ck = C_in.astype(F32).reshape(Bb, nc, Q, G, N).transpose(0, 3, 1, 2, 4)

    y_diag, states, decay = ssd_chunks(xk, dAk, Bk, Ck, interpret=not _on_tpu())

    # inter-chunk recurrence over the nc per-chunk states
    if initial_state is None:
        initial_state = jnp.zeros((Bb, H, P_, N), F32)
    a_seq = decay.transpose(2, 0, 1)[..., None, None]          # (nc,B,H,1,1)
    s_seq = states.transpose(2, 0, 1, 3, 4)                    # (nc,B,H,P,N)

    def combine(c1, c2):
        a1, h1 = c1
        a2, h2 = c2
        return a1 * a2, h1 * a2 + h2

    a_all, h_all = jax.lax.associative_scan(combine, (a_seq, s_seq), axis=0)
    prev = jnp.concatenate([jnp.zeros_like(h_all[:1]), h_all[:-1]], 0) + \
        jnp.concatenate([jnp.ones_like(a_all[:1]), a_all[:-1]], 0) * initial_state[None]
    prev = prev.transpose(1, 2, 0, 3, 4)                       # (B,H,nc,P,N)
    final = h_all[-1] + a_all[-1] * initial_state

    # state -> output term (dense einsum; OK for XLA)
    cs = jnp.cumsum(dAk, axis=-1)                              # (B,H,nc,Q)
    out_decay = jnp.exp(cs)
    HG = H // G
    Ch = jnp.repeat(Ck, HG, axis=1)                            # (B,H,nc,Q,N)
    y_off = jnp.einsum("bhcqn,bhcpn,bhcq->bhcqp", Ch, prev, out_decay)

    y = (y_diag + y_off).transpose(0, 2, 3, 1, 4).reshape(Bb, S, H, P_)
    return y, final
