from repro.kernels.ssd_scan.ops import ssd  # noqa: F401
from repro.kernels.ssd_scan.ref import ssd_chunk_ref  # noqa: F401
