"""Mamba-2 SSD per-chunk TPU kernel.

Hardware adaptation (GPU -> TPU): the original SSD kernels use warp-level
scans for the within-chunk cumulative decays.  TPUs have no warp shuffles —
instead the kernel casts *everything* as dense matmuls for the MXU:

  * the within-chunk cumsum of log-decays is a lower-triangular ones
    matmul (``tril @ dA``),
  * the decay matrix L, the (C·Bᵀ ⊙ L) score matrix, the intra-chunk
    output, and the chunk state are all (Q x Q)/(Q x N)/(Q x P) matmuls.

Grid: (Bb, H, nc) — one chunk of one head per step; B/C blocks are indexed
through the head->group map in the BlockSpec index_map (no per-head
materialization of group-shared tensors in HBM).  The inter-chunk
recurrence (tiny: nc states of (P, N)) runs outside in jnp via
``associative_scan``.

Bucket-padded prefill masking is handled entirely by the wrapper
(``ops.ssd``): masked steps are fed in with dA=0 and zero dt-weighted
input, which the matmuls below treat as identity state updates — the
kernel body stays shape-static with no divergent control flow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401  (TPU lowering registration)

from repro.kernels._compat import tpu_compiler_params


def _ssd_chunk_kernel(x_ref, dA_ref, b_ref, c_ref,
                      y_ref, st_ref, dec_ref, *, Q: int):
    x = x_ref[0, 0, 0].astype(jnp.float32)                 # (Q, P)
    dA = dA_ref[0, 0, 0].astype(jnp.float32)               # (Q,)
    Bm = b_ref[0, 0, 0].astype(jnp.float32)                # (Q, N)
    Cm = c_ref[0, 0, 0].astype(jnp.float32)                # (Q, N)

    # cumsum as a lower-triangular matmul (MXU instead of a scan)
    tril = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    cs = jax.lax.dot_general(
        tril, dA[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]                                                # (Q,)

    diff = cs[:, None] - cs[None, :]
    L = jnp.where(
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1),
        jnp.exp(diff), 0.0,
    )
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * L                                                  # (Q, Q)
    y_ref[0, 0, 0] = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(y_ref.dtype)

    decay_states = jnp.exp(cs[-1] - cs)                    # (Q,)
    xw = x * decay_states[:, None]                         # (Q, P)
    st_ref[0, 0, 0] = jax.lax.dot_general(
        xw, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(st_ref.dtype)                                 # (P, N)
    dec_ref[0, 0, 0] = jnp.exp(cs[-1])


def ssd_chunks(x, dA, B, C, *, interpret: bool = True):
    """x: (Bb,H,nc,Q,P); dA: (Bb,H,nc,Q); B/C: (Bb,G,nc,Q,N).

    Returns (y_diag, states (Bb,H,nc,P,N), decay (Bb,H,nc)).
    """
    Bb, H, nc, Q, P = x.shape
    G, N = B.shape[1], B.shape[4]
    HG = H // G

    kernel = functools.partial(_ssd_chunk_kernel, Q=Q)
    return pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, Q, N), lambda b, h, c: (b, h // HG, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, N), lambda b, h, c: (b, h // HG, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, c: (b, h, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, H, nc, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb, H, nc, P, N), jnp.float32),
            jax.ShapeDtypeStruct((Bb, H, nc), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
        name="ssd_chunks",
    )(x, dA, B, C)
