"""jit'd wrapper for the grouped expert GEMM."""
from __future__ import annotations

import functools

import jax

from repro.kernels.moe_gmm.moe_gmm import gmm as _gmm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_c", "block_f"))
def gmm(x, w, counts, *, block_c: int = 128, block_f: int = 512):
    return _gmm(x, w, counts, block_c=block_c, block_f=block_f,
                interpret=not _on_tpu())
