"""Grouped expert GEMM TPU kernel with empty-block skipping.

MegaBlocks adapted to the TPU: instead of CSR block-sparse indexing (a
GPU-friendly gather), the capacity layout (E, C, D) is tiled densely and
the per-expert token count (a tiny scalar operand) gates each (bc x bf)
output tile with ``pl.when`` — tiles past an expert's token count are
skipped entirely (written zero), so compute scales with the *actual*
load per expert rather than the capacity bound.

Grid (E, C/bc, F/bf); the full D ("k") dim is kept resident per tile:
bc*D + D*bf + bc*bf floats must fit VMEM (e.g. 128x4096 tiles = ~2 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401  (TPU lowering registration)

from repro.kernels._compat import tpu_compiler_params


def _gmm_kernel(cnt_ref, x_ref, w_ref, o_ref, *, block_c: int):
    ci = pl.program_id(1)
    count = cnt_ref[0]
    start = ci * block_c

    @pl.when(start < count)
    def _compute():
        x = x_ref[0].astype(jnp.float32)                   # (bc, D)
        w = w_ref[0].astype(jnp.float32)                   # (D, bf)
        acc = jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        # zero partially-valid rows in the tail tile
        rows = start + jax.lax.broadcasted_iota(jnp.int32, acc.shape, 0)
        acc = jnp.where(rows < count, acc, 0.0)
        o_ref[0] = acc.astype(o_ref.dtype)

    @pl.when(start >= count)
    def _skip():
        o_ref[0] = jnp.zeros_like(o_ref[0])


def gmm(x, w, counts, *, block_c: int = 128, block_f: int = 512,
        interpret: bool = True):
    """x: (E, C, D); w: (E, D, F); counts: (E,) int32 -> (E, C, F)."""
    E, C, D = x.shape
    F = w.shape[2]
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    assert C % block_c == 0 and F % block_f == 0
    kernel = functools.partial(_gmm_kernel, block_c=block_c)
    return pl.pallas_call(
        kernel,
        grid=(E, C // block_c, F // block_f),
        in_specs=[
            pl.BlockSpec((1,), lambda e, c, f: (e,)),
            pl.BlockSpec((1, block_c, D), lambda e, c, f: (e, c, 0)),
            pl.BlockSpec((1, D, block_f), lambda e, c, f: (e, 0, f)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f), lambda e, c, f: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
        name="moe_gmm",
    )(counts.astype(jnp.int32), x, w)
