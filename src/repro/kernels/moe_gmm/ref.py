"""Pure-jnp oracle for the grouped expert GEMM (capacity layout)."""
from __future__ import annotations

import jax.numpy as jnp


def gmm_ref(x, w, counts):
    """x: (E, C, D) dispatched tokens; w: (E, D, F); counts: (E,) valid rows.

    Rows beyond counts[e] are zeroed (they're padding slots).
    """
    E, C, D = x.shape
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32), w.astype(jnp.float32))
    valid = jnp.arange(C)[None, :] < counts[:, None]
    return jnp.where(valid[..., None], out, 0.0).astype(x.dtype)
