from repro.kernels.moe_gmm.ops import gmm  # noqa: F401
from repro.kernels.moe_gmm.ref import gmm_ref  # noqa: F401
