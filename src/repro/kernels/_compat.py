"""Version compatibility for Pallas TPU compiler params.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
installed version may carry either name.  All kernels construct their
compiler params through :func:`tpu_compiler_params` so the resolution
happens once.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def tpu_compiler_params(**kwargs):
    """Build the TPU compiler-params object under either jax naming."""
    return CompilerParams(**kwargs)
