"""Flash attention TPU kernel (pl.pallas_call + BlockSpec VMEM tiling).

Layout: q (B, Hq, Sq, Dh), k/v (B, Hkv, Skv, Dh).  Grid (B, Hq, nq, nk)
with the kv dimension innermost ("arbitrary" semantics): the (m, l, acc)
running-softmax state lives in VMEM scratch and is carried across kv grid
steps; the output block is written on the last kv step.  Causal + sliding
window masking; fully-masked kv blocks are skipped with ``pl.when``.

Block sizes are chosen so the working set
(q_blk + k_blk + v_blk + acc = bq*Dh*4 + 2*bk*Dh*2 + bq*bk*4 bytes)
fits comfortably in the ~16 MiB of VMEM with MXU-aligned (128-multiple)
tile dims.

``paged_extend_attention_bhsd`` is the block-table variant for the paged
suffix-extend path (prefix-hit prefill): K/V stream straight from the
physical page arena through scalar-prefetched block-table index maps —
same calling convention as the paged decode kernel (see
kernels/decode_attention), with per-row absolute query offsets.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,      # blocks
    m_scr, l_scr, acc_scr,           # VMEM scratch (carried over kv steps)
    *, scale: float, causal: bool, window: Optional[int],
    block_q: int, block_k: int, nk: int, seq_off: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q + seq_off          # absolute q positions
    k_start = ki * block_k

    # skip blocks that are entirely masked
    run = True
    if causal:
        run = (q_start + block_q - 1) >= k_start
    if window is not None:
        # newest k in block must be > oldest q - window
        run = jnp.logical_and(run, (k_start + block_k - 1) > (q_start - window))

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)               # (bq, Dh)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, Dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                          # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    block_q: int = 512, block_k: int = 512, interpret: bool = True,
):
    """q: (B, Hq, Sq, Dh); k/v: (B, Hkv, Skv, Dh) -> (B, Hq, Sq, Dh)."""
    B, Hq, Sq, Dh = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, block_q, Skv, block_k)
    nq, nk = Sq // block_q, Skv // block_k
    seq_off = Skv - Sq                 # q block positions count from the end

    kernel = functools.partial(
        _flash_kernel,
        scale=1.0 / math.sqrt(Dh),
        causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk, seq_off=seq_off,
    )
    grid = (B, Hq, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, Dh), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dh), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dh), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)


def _paged_extend_kernel(
    bt_ref, pos_ref, layer_ref,          # scalar prefetch (SMEM)
    q_ref, k_ref, v_ref, sp_ref, *rest,
    scale: float, block_q: int, page: int, n_log: int, num_pages: int,
    quant: bool,
):
    del layer_ref  # consumed by the BlockSpec index maps only
    if quant:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    qi = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    page_id = bt_ref[b * n_log + j]
    # newest attendable position for this q block (absolute layout:
    # logical page j holds positions [j*P, j*P + P))
    q_hi = pos_ref[b] + (qi + 1) * block_q - 1

    @pl.when((page_id < num_pages) & (j * page <= q_hi))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                # (bq, Dh)
        k = k_ref[0, :, 0, 0].astype(jnp.float32)          # (P, Dh)
        v = v_ref[0, :, 0, 0].astype(jnp.float32)
        if quant:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                          # (bq, P)
        q_pos = pos_ref[b] + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, page), 0)
        sp = sp_ref[0, :, 0]                               # (P,)
        valid = (sp[None, :] >= 0) & (sp[None, :] <= q_pos)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(j == n_log - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_extend_attention_bhsd(
    q, k_arena, v_arena, slot_pos, block_table, pos, layer,
    *, k_scale=None, v_scale=None, block_q: int = 128,
    interpret: bool = True,
):
    """Paged suffix-extend attention: q (B, Hq, Sq, Dh) vs an arena.

    The multi-query sibling of ``paged_decode_attention_bhd`` (see
    kernels/decode_attention): row b's queries sit at absolute positions
    ``pos[b] + i`` behind a prefix already resident in the block-table's
    pages; a slot is attended iff its ``slot_pos`` is valid (>= 0) and
    <= the query position.  k/v_arena: (N, P, L, Hkv, Dh); slot_pos:
    (N, P, L); block_table: (B, n_log) int32 (>= N = unmapped); pos:
    (B,) int32 per-row offsets; layer: () int32.  Returns (B, Hq, Sq, Dh).
    """
    B, Hq, Sq, Dh = q.shape
    N, P, _L, Hkv, _ = k_arena.shape
    G = Hq // Hkv
    n_log = block_table.shape[1]
    block_q = min(block_q, Sq)
    assert Sq % block_q == 0, (Sq, block_q)
    nq = Sq // block_q
    bt_flat = block_table.reshape(-1).astype(jnp.int32)
    layer_arr = jnp.asarray(layer, jnp.int32).reshape(1)
    quant = k_scale is not None

    def phys(b, j, bt):
        return jnp.minimum(bt[b * n_log + j], N - 1)

    in_specs = [
        pl.BlockSpec((1, 1, block_q, Dh),
                     lambda b, h, i, j, bt, ps, lyr: (b, h, i, 0)),
        pl.BlockSpec((1, P, 1, 1, Dh),
                     lambda b, h, i, j, bt, ps, lyr: (phys(b, j, bt), 0, lyr[0], h // G, 0)),
        pl.BlockSpec((1, P, 1, 1, Dh),
                     lambda b, h, i, j, bt, ps, lyr: (phys(b, j, bt), 0, lyr[0], h // G, 0)),
        pl.BlockSpec((1, P, 1),
                     lambda b, h, i, j, bt, ps, lyr: (phys(b, j, bt), 0, lyr[0])),
    ]
    args = [q, k_arena, v_arena, slot_pos]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1), lambda b, h, i, j, bt, ps, lyr: (phys(b, j, bt), lyr[0])),
            pl.BlockSpec((1, 1), lambda b, h, i, j, bt, ps, lyr: (phys(b, j, bt), lyr[0])),
        ]
        args += [k_scale, v_scale]

    kernel = functools.partial(
        _paged_extend_kernel,
        scale=1.0 / math.sqrt(Dh), block_q=block_q, page=P, n_log=n_log,
        num_pages=N, quant=quant,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hq, nq, n_log),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, block_q, Dh),
                               lambda b, h, i, j, bt, ps, lyr: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="paged_extend_attention",
    )(bt_flat, pos.astype(jnp.int32), layer_arr, *args)
