"""Public wrappers for the flash-attention kernels.

Accepts the model's (B, S, H, Dh) layout, dispatches to the Pallas kernel
(interpret=True on CPU — the kernel body executes for correctness; real
Mosaic lowering on TPU).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax

from repro.kernels.flash_attention.flash_attention import (
    flash_attention_bhsd,
    paged_extend_attention_bhsd,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k")
)
def flash_attention(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    block_q: int = 512, block_k: int = 512,
):
    """q: (B, Sq, Hq, Dh); k/v: (B, Skv, Hkv, Dh) -> (B, Sq, Hq, Dh)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=not _on_tpu(),
    )
    return out.transpose(0, 2, 1, 3)


def paged_extend_attention(q, k_arena, v_arena, slot_pos, block_table,
                           pos, layer, *, k_scale=None, v_scale=None,
                           block_q: int = 128):
    """q: (B, S, Hq, Dh) vs a paged arena (see ``paged_extend_attention_bhsd``).

    Unjitted on purpose — traced inside the caller's (model) jit so the
    arena is never copied across a jit boundary per layer.  ``block_q``
    snaps to a divisor of S so any bucketed suffix length tiles cleanly.
    """
    S = q.shape[1]
    bq = S if S <= block_q else math.gcd(S, block_q)
    out = paged_extend_attention_bhsd(
        q.transpose(0, 2, 1, 3), k_arena, v_arena, slot_pos, block_table,
        pos, layer, k_scale=k_scale, v_scale=v_scale, block_q=bq,
        interpret=not _on_tpu(),
    )
    return out.transpose(0, 2, 1, 3)
