"""jit'd public wrapper for the flash-attention kernel.

Accepts the model's (B, S, H, Dh) layout, dispatches to the Pallas kernel
(interpret=True on CPU — the kernel body executes for correctness; real
Mosaic lowering on TPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k")
)
def flash_attention(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    block_q: int = 512, block_k: int = 512,
):
    """q: (B, Sq, Hq, Dh); k/v: (B, Skv, Hkv, Dh) -> (B, Sq, Hq, Dh)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=not _on_tpu(),
    )
    return out.transpose(0, 2, 1, 3)
