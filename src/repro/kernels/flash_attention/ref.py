"""Pure-jnp oracle for flash attention (naive full-matrix softmax)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
):
    """q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh) -> (B, Hq, Sq, Dh).

    GQA by head grouping (head h uses kv head h // (Hq//Hkv)).
    """
    B, Hq, Sq, Dh = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kf = jnp.repeat(k, G, axis=1)
    vf = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf.astype(jnp.float32))
    s = s / math.sqrt(Dh)
    q_pos = jnp.arange(Sq)[:, None] + (Skv - Sq)   # align ends (q suffix)
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_extend_attention_ref(
    q, k_arena, v_arena, slot_pos, block_table, pos, layer,
    *, k_scale=None, v_scale=None,
):
    """Pure-jnp oracle for the paged extend kernel (same signature).

    q: (B, Hq, Sq, Dh); k/v_arena: (N, P, L, Hkv, Dh); slot_pos:
    (N, P, L); block_table: (B, n_log) int32 (>= N unmapped); pos: (B,)
    absolute offset of each row's first query; layer: () int32.  A slot
    is attended iff its stored position is >= 0 and <= the query's
    absolute position.  Returns (B, Hq, Sq, Dh).
    """
    B, Hq, Sq, Dh = q.shape
    N, P = k_arena.shape[0], k_arena.shape[1]
    n_log = block_table.shape[1]
    btc = jnp.minimum(block_table, N - 1)
    k = jnp.take(k_arena, layer, axis=2)[btc]          # (B, n_log, P, Hkv, Dh)
    v = jnp.take(v_arena, layer, axis=2)[btc]
    sp = jnp.take(slot_pos, layer, axis=2)[btc]        # (B, n_log, P)
    if k_scale is not None:
        ks = jnp.take(k_scale, layer, axis=1)[btc]
        vs = jnp.take(v_scale, layer, axis=1)[btc]
        k = k.astype(jnp.float32) * ks[..., None, None, None]
        v = v.astype(jnp.float32) * vs[..., None, None, None]
    sp = jnp.where((block_table < N)[:, :, None], sp, -1)
    Hkv = k.shape[3]
    G = Hq // Hkv
    k = jnp.repeat(k.reshape(B, n_log * P, Hkv, Dh), G, axis=2)
    v = jnp.repeat(v.reshape(B, n_log * P, Hkv, Dh), G, axis=2)
    sp = sp.reshape(B, n_log * P)
    s = jnp.einsum("bhqd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(Dh)
    q_pos = pos[:, None] + jnp.arange(Sq)[None, :]     # (B, Sq)
    valid = (sp[:, None, :] >= 0) & (sp[:, None, :] <= q_pos[:, :, None])
    s = jnp.where(valid[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
