"""Pure-jnp oracle for flash attention (naive full-matrix softmax)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
):
    """q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh) -> (B, Hq, Sq, Dh).

    GQA by head grouping (head h uses kv head h // (Hq//Hkv)).
    """
    B, Hq, Sq, Dh = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kf = jnp.repeat(k, G, axis=1)
    vf = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf.astype(jnp.float32))
    s = s / math.sqrt(Dh)
    q_pos = jnp.arange(Sq)[:, None] + (Skv - Sq)   # align ends (q suffix)
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf.astype(jnp.float32))
    return out.astype(q.dtype)
