"""Flash attention: dense prefill/train kernel + native paged extend.

``paged_extend_attention`` serves the prefix-hit suffix path: ``q (B, S,
Hq, Dh)`` suffix queries attend over a paged KV arena through the
serving block table (same arena/block-table/sentinel convention as
``repro.kernels.decode_attention``), with per-row ``pos`` giving the
absolute position of each row's first query — so a shared prefix is
attended in place, never densified.  ``*_ref`` are the pure-jnp parity
oracles and the CPU fallback math.
"""
from repro.kernels.flash_attention.ops import (  # noqa: F401
    flash_attention,
    paged_extend_attention,
)
from repro.kernels.flash_attention.ref import (  # noqa: F401
    attention_ref,
    paged_extend_attention_ref,
)
