"""Flash-decode kernels: dense (slot-indexed) and native paged variants.

The paged op consumes the serving block table directly — ``q (B, 1, Hq,
Dh)`` against a ``(num_pages, page_size, L, Hkv, Dh)`` arena, a ``(B,
n_logical)`` int32 block table (entries ``>= num_pages`` are unmapped
sentinels), per-row ``kv_len`` and a scalar ``layer`` index — so no
contiguous per-slot KV copy is ever materialized.  Optional ``k_scale``/
``v_scale (num_pages, L)`` enable int8 arenas with in-kernel dequant.
``*_ref`` are pure-jnp oracles used for interpret-mode parity tests and
as the bit-identical CPU fallback math.
"""
from repro.kernels.decode_attention.ops import (  # noqa: F401
    decode_attention,
    lse_combine,
    paged_decode_attention,
)
from repro.kernels.decode_attention.ref import (  # noqa: F401
    decode_attention_ref,
    paged_decode_attention_ref,
)
