"""Flash-decode TPU kernels: one query token vs a long KV cache.

Decode is HBM-bandwidth-bound (the entire KV cache is streamed once per
token), so the kernel's job is to keep the streaming dense and the
softmax state in VMEM: grid (B, Hkv, nk) with the kv dim innermost; each
step loads a (block_k, Dh) K/V tile, updates the running (m, l, acc) for
all G query heads of the kv group, and emits the normalized output on the
last step.  Length masking comes from a per-batch ``kv_len`` scalar block.

Two variants share that structure:

* ``decode_attention_bhd`` — dense per-slot caches (B, S, Hkv, Dh).
* ``paged_decode_attention_bhd`` — the NATIVE PAGED kernel.  The KV lives
  in a physical page arena (num_pages, page_size, L, Hkv, Dh) shared by
  every request; each batch row's pages are named by a block-table row.
  The block table, per-row ``kv_len`` and the arena ``layer`` index ride
  scalar prefetch (``pltpu.PrefetchScalarGridSpec``), so the K/V
  BlockSpec index maps dereference ``block_table[b, j]`` and the kernel
  walks each row's physical pages DIRECTLY in the arena — no contiguous
  per-slot KV copy is ever materialized (the "gather tax" of
  serve/kvpool.py's dense fallback).  Sentinel entries (>= num_pages)
  are clamped in the index map and fully masked in the body (their
  ``slot_pos`` is ignored), so unmapped pages contribute nothing.
  Per-slot absolute positions come from the arena's ``slot_pos`` plane,
  which also masks partially filled pages.  Int8 arenas dequantize
  in-kernel with a per-(page, layer) scale block.

On real hardware the page/nk dimension maps to the sequential grid walk
(``arbitrary``), giving the classic split-KV streaming pattern; splits
across the model axis are combined outside the kernel with an LSE merge
(see serve/distributed decode).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(
    kvlen_ref, q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, block_k: int, nk: int, G: int,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = kvlen_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                # (G, Dh)
        k = k_ref[0, :, 0].astype(jnp.float32)             # (bk, Dh)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                          # (G, bk)
        pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_bhd(
    q, k_cache, v_cache, kv_len, *, block_k: int = 512, interpret: bool = True,
):
    """q: (B, Hq, Dh); k/v_cache: (B, S, Hkv, Dh); kv_len: (B,) int32."""
    B, Hq, Dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    block_k = min(block_k, S)
    assert S % block_k == 0
    nk = S // block_k
    qg = q.reshape(B, Hkv, G, Dh)

    kernel = functools.partial(
        _decode_kernel,
        scale=1.0 / math.sqrt(Dh), block_k=block_k, nk=nk, G=G,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, Dh), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, block_k, 1, Dh), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention",
    )(kv_len, qg, k_cache, v_cache)
    return out.reshape(B, Hq, Dh)


def _paged_decode_kernel(
    bt_ref, kvlen_ref, layer_ref,        # scalar prefetch (SMEM)
    q_ref, k_ref, v_ref, sp_ref, *rest,
    scale: float, page: int, n_log: int, G: int, num_pages: int, quant: bool,
):
    del layer_ref  # consumed by the BlockSpec index maps only
    if quant:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    page_id = bt_ref[b * n_log + j]
    kv_len = kvlen_ref[b]

    # skip unmapped pages and pages entirely past the row's valid length
    # (absolute-position layout: logical page j holds positions [j*P, j*P+P))
    @pl.when((page_id < num_pages) & (j * page < kv_len))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                # (G, Dh)
        k = k_ref[0, :, 0, 0].astype(jnp.float32)          # (P, Dh)
        v = v_ref[0, :, 0, 0].astype(jnp.float32)
        if quant:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                          # (G, P)
        sp = sp_ref[0, :, 0]                               # (P,)
        valid = (sp >= 0) & (sp < kv_len)
        s = jnp.where(valid[None, :], s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(j == n_log - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention_bhd(
    q, k_arena, v_arena, slot_pos, block_table, kv_len, layer,
    *, k_scale=None, v_scale=None, interpret: bool = True,
):
    """Paged flash-decode: q (B, Hq, Dh) vs a block-table-indirected arena.

    k/v_arena: (N, P, L, Hkv, Dh); slot_pos: (N, P, L) int32 absolute
    position per slot (-1 = empty); block_table: (B, n_log) int32, entries
    >= N are unmapped sentinels; kv_len: (B,) valid count; layer: () int32
    arena layer to read.  k/v_scale: (N, L) f32 per-(page, layer)
    dequantization scales for int8 arenas (None = float arena).
    Returns (B, Hq, Dh).
    """
    B, Hq, Dh = q.shape
    N, P, _L, Hkv, _ = k_arena.shape
    G = Hq // Hkv
    n_log = block_table.shape[1]
    qg = q.reshape(B, Hkv, G, Dh)
    bt_flat = block_table.reshape(-1).astype(jnp.int32)
    layer_arr = jnp.asarray(layer, jnp.int32).reshape(1)
    quant = k_scale is not None

    def phys(b, j, bt):
        return jnp.minimum(bt[b * n_log + j], N - 1)

    in_specs = [
        pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, bt, kvl, lyr: (b, h, 0, 0)),
        pl.BlockSpec((1, P, 1, 1, Dh),
                     lambda b, h, j, bt, kvl, lyr: (phys(b, j, bt), 0, lyr[0], h, 0)),
        pl.BlockSpec((1, P, 1, 1, Dh),
                     lambda b, h, j, bt, kvl, lyr: (phys(b, j, bt), 0, lyr[0], h, 0)),
        pl.BlockSpec((1, P, 1),
                     lambda b, h, j, bt, kvl, lyr: (phys(b, j, bt), 0, lyr[0])),
    ]
    args = [qg, k_arena, v_arena, slot_pos]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1), lambda b, h, j, bt, kvl, lyr: (phys(b, j, bt), lyr[0])),
            pl.BlockSpec((1, 1), lambda b, h, j, bt, kvl, lyr: (phys(b, j, bt), lyr[0])),
        ]
        args += [k_scale, v_scale]

    kernel = functools.partial(
        _paged_decode_kernel,
        scale=1.0 / math.sqrt(Dh), page=P, n_log=n_log, G=G,
        num_pages=N, quant=quant,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, n_log),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, Dh),
                               lambda b, h, j, bt, kvl, lyr: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="paged_decode_attention",
    )(bt_flat, kv_len.astype(jnp.int32), layer_arr, *args)
    return out.reshape(B, Hq, Dh)
