"""Flash-decode TPU kernel: one query token vs a long KV cache.

Decode is HBM-bandwidth-bound (the entire KV cache is streamed once per
token), so the kernel's job is to keep the streaming dense and the
softmax state in VMEM: grid (B, Hkv, nk) with the kv dim innermost; each
step loads a (block_k, Dh) K/V tile, updates the running (m, l, acc) for
all G query heads of the kv group, and emits the normalized output on the
last step.  Length masking comes from a per-batch ``kv_len`` scalar block.

On real hardware the nk dimension maps to the sequential grid walk
(``arbitrary``), giving the classic split-KV streaming pattern; splits
across the model axis are combined outside the kernel with an LSE merge
(see serve/distributed decode).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(
    kvlen_ref, q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, block_k: int, nk: int, G: int,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = kvlen_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                # (G, Dh)
        k = k_ref[0, :, 0].astype(jnp.float32)             # (bk, Dh)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                          # (G, bk)
        pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_bhd(
    q, k_cache, v_cache, kv_len, *, block_k: int = 512, interpret: bool = True,
):
    """q: (B, Hq, Dh); k/v_cache: (B, S, Hkv, Dh); kv_len: (B,) int32."""
    B, Hq, Dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    block_k = min(block_k, S)
    assert S % block_k == 0
    nk = S // block_k
    qg = q.reshape(B, Hkv, G, Dh)

    kernel = functools.partial(
        _decode_kernel,
        scale=1.0 / math.sqrt(Dh), block_k=block_k, nk=nk, G=G,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, Dh), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, block_k, 1, Dh), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention",
    )(kv_len, qg, k_cache, v_cache)
    return out.reshape(B, Hq, Dh)
