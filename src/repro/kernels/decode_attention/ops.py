"""Wrappers for the flash-decode kernels + distributed LSE combine."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_bhd,
    paged_decode_attention_bhd,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k_cache, v_cache, kv_len, *, block_k: int = 512):
    """q: (B, 1, Hq, Dh); caches (B, S, Hkv, Dh); kv_len (B,)."""
    out = decode_attention_bhd(
        q[:, 0], k_cache, v_cache, kv_len.astype(jnp.int32),
        block_k=block_k, interpret=not _on_tpu(),
    )
    return out[:, None]


def paged_decode_attention(q, k_arena, v_arena, slot_pos, block_table,
                           kv_len, layer, *, k_scale=None, v_scale=None):
    """q: (B, 1, Hq, Dh) vs a paged arena (see ``paged_decode_attention_bhd``).

    Unjitted on purpose — traced inside the caller's (model) jit so the
    arena is never copied across a jit boundary per layer.
    """
    out = paged_decode_attention_bhd(
        q[:, 0], k_arena, v_arena, slot_pos, block_table,
        kv_len.astype(jnp.int32), layer,
        k_scale=k_scale, v_scale=v_scale, interpret=not _on_tpu(),
    )
    return out[:, None]


def lse_combine(ms, ls, accs):
    """Merge per-split softmax partials (flash-decode split-KV combine).

    ms/ls: (n_split, ...), accs: (n_split, ..., Dh).  Used to merge kernel
    partials across sequence-sharded KV (the SP decode path).
    """
    m = jnp.max(ms, axis=0)
    w = jnp.exp(ms - m[None])
    l = jnp.sum(ls * w, axis=0)
    acc = jnp.sum(accs * w[..., None], axis=0)
    return acc / jnp.maximum(l, 1e-30)[..., None]
