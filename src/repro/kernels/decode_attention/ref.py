"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, kv_len):
    """q: (B, Hq, Dh); k/v_cache: (B, S, Hkv, Dh); kv_len: (B,) valid count.

    Returns (B, Hq, Dh).  Slot i holds position i; positions >= kv_len are
    masked.
    """
    B, S, Hkv, Dh = k_cache.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    s = s / math.sqrt(Dh)
    valid = jnp.arange(S)[None] < kv_len[:, None]               # (B, S)
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, Dh).astype(q.dtype)
