"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, kv_len):
    """q: (B, Hq, Dh); k/v_cache: (B, S, Hkv, Dh); kv_len: (B,) valid count.

    Returns (B, Hq, Dh).  Slot i holds position i; positions >= kv_len are
    masked.
    """
    B, S, Hkv, Dh = k_cache.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    s = s / math.sqrt(Dh)
    valid = jnp.arange(S)[None] < kv_len[:, None]               # (B, S)
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, Dh).astype(q.dtype)


def paged_decode_attention_ref(
    q, k_arena, v_arena, slot_pos, block_table, kv_len, layer,
    *, k_scale=None, v_scale=None,
):
    """Pure-jnp oracle for the paged decode kernel (same signature).

    q: (B, Hq, Dh); k/v_arena: (N, P, L, Hkv, Dh); slot_pos: (N, P, L);
    block_table: (B, n_log) int32, entries >= N unmapped; kv_len: (B,);
    layer: () int32.  k/v_scale: (N, L) per-(page, layer) int8 scales or
    None.  A slot is attended iff its stored position is in [0, kv_len).
    Returns (B, Hq, Dh).
    """
    B, Hq, Dh = q.shape
    N, P = k_arena.shape[0], k_arena.shape[1]
    n_log = block_table.shape[1]
    btc = jnp.minimum(block_table, N - 1)
    k = jnp.take(k_arena, layer, axis=2)[btc]          # (B, n_log, P, Hkv, Dh)
    v = jnp.take(v_arena, layer, axis=2)[btc]
    sp = jnp.take(slot_pos, layer, axis=2)[btc]        # (B, n_log, P)
    if k_scale is not None:
        ks = jnp.take(k_scale, layer, axis=1)[btc]     # (B, n_log)
        vs = jnp.take(v_scale, layer, axis=1)[btc]
        k = k.astype(jnp.float32) * ks[..., None, None, None]
        v = v.astype(jnp.float32) * vs[..., None, None, None]
    sp = jnp.where((block_table < N)[:, :, None], sp, -1)
    Hkv = k.shape[3]
    k = k.reshape(B, n_log * P, Hkv, Dh)
    v = v.reshape(B, n_log * P, Hkv, Dh)
    sp = sp.reshape(B, n_log * P)
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k.astype(jnp.float32))
    s = s / math.sqrt(Dh)
    valid = (sp >= 0) & (sp < kv_len[:, None])
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, Dh).astype(q.dtype)
