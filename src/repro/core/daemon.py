"""Supervisor daemon — the closed control loop over the declarative plane.

The paper's supervisor "can create, destroy, resize a subOS on-the-fly";
PR 2 made those verbs converge from declared state, but convergence only
happened when a caller remembered to tick ``reconcile()`` or
``maybe_act()`` by hand.  :class:`SupervisorDaemon` closes the loop: one
``tick()`` runs the whole management cycle, and ``start()`` runs it on a
timer, so the cluster self-heals and autoscales with ZERO manual
primitive calls — the application only ever declares specs.

Tick order (each stage feeds the next):

1. **health** — ``Supervisor.check_health()`` finds heartbeat-stale
   cells; the daemon marks them ``failed`` so the planner sees them.
2. **reconcile** — converge observed -> desired: failed cells are
   re-carved (``recover``), restoring state from the spec's ``ckpt_dir``
   when one is declared; degraded cells regrow; replica-count changes
   materialize as create/destroy.
3. **policies** — registered :class:`~repro.core.elastic.ReconcilePolicy`
   instances pull live TTFT/TPOT accounting and may rewrite + re-apply
   the spec (columns and/or replicas).  Threshold bands come from the
   spec's declared :class:`~repro.core.spec.SLOTarget` via
   :meth:`add_slo_policy` — the application states its latency
   objective, not scaling thresholds.  A policy-driven scale-down
   executes its ``destroy`` ops INSIDE this stage (apply -> reconcile),
   so drain-before-detach cannot wait for stage 4: the supervisor's
   ``drain_hooks`` fire from the reconciler's destroy branch while the
   doomed cell and its channels are still live, letting a migrating
   ``DisaggServer`` (``migrate=True``) hand the victim's hot KV pages
   and in-flight slots to survivors (``repro.serve.cacheplane``).
4. **sync** — attached :class:`~repro.serve.disagg.DisaggServer`\\ s
   converge their live replica surface to the (possibly rescaled) spec:
   fresh decode instances attach, vanished ones detach with their
   requests requeued.

Ticks are re-entrant-free and cheap when converged (an empty plan plus a
few deque reads), so interleaving ``tick()`` with traffic — e.g.
``DisaggServer.run_until_drained(on_step=daemon.tick)`` — is the
recommended pattern for in-process serving loops.  The threaded
``start()/stop()`` mode suits bookkeeping supervisors and real
deployments where cells run out-of-process; do not combine it with a
same-process JAX step loop (two threads would race on device state).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Deque, List, Optional, Tuple

from repro.core.elastic import ElasticPolicy, ReconcilePolicy
from repro.core.telemetry import DecisionAudit


class SupervisorDaemon:
    """Periodic health-check + reconcile + SLO autoscale + replica sync."""

    def __init__(self, supervisor, *, interval: float = 0.5,
                 history_limit: int = 10_000):
        self.sup = supervisor
        self.interval = interval
        self.policies: List[ReconcilePolicy] = []
        self.servers: List[Tuple[object, Optional[str]]] = []
        self.ticks = 0
        # bounded: a long-running threaded daemon must not leak one
        # record per tick forever
        self.history: Deque[dict] = deque(maxlen=history_limit)
        self.errors: Deque[dict] = deque(maxlen=1_000)
        # the decision audit: every tick's observed SLO signals + every
        # action taken with its reason, queryable after the fact and
        # folded into DisaggServer.trace_export(daemon=...)
        self.audit = DecisionAudit()
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- registration ---------------------------------------------------
    def add_policy(self, policy: ReconcilePolicy) -> ReconcilePolicy:
        """Register a hand-built policy (prefer :meth:`add_slo_policy`)."""
        self.policies.append(policy)
        return policy

    def add_slo_policy(self, server: str, donor: Optional[str] = None, *,
                       metric: str = "ttft", hysteresis: float = 0.5,
                       window: int = 50, percentile: float = 99.0,
                       cooldown: float = 0.0,
                       autoscale_replicas: bool = False,
                       queue_depth=None, queue_high: int = 4,
                       pool_occupancy=None, occupancy_high: float = 0.9,
                       tenant: Optional[str] = None
                       ) -> ReconcilePolicy:
        """Build a policy whose bands derive from the spec's SLOTarget.

        ``ut`` is the declared ``{metric}_p99`` target, ``lt`` is
        ``hysteresis * ut`` — nothing is hand-picked.  With ``donor``
        set, tail crossings move columns between ``server`` and
        ``donor``; with ``autoscale_replicas=True`` the ``tpot_p99``
        target (plus ``queue_depth``, e.g. ``lambda:
        len(disagg_server.pending)``, and optionally ``pool_occupancy``,
        e.g. ``disagg_server.pool_occupancy`` — KV-pool pressure) drives
        the server spec's desired replica count.

        With ``tenant`` set, the band derives from that tenant's own
        :class:`~repro.core.spec.SLOTarget` (``TenantSpec.slo`` on the
        server cell, falling back to the cell-level SLO) and the window
        ingests ONLY that tenant's samples — the cell autoscales for
        the tenant whose objective is actually violated.
        """
        spec = getattr(self.sup, "desired", None)
        if spec is None or not spec.has_cell(server):
            raise ValueError(f"no applied spec declares cell {server!r}")
        slo = self._resolve_slo(spec, server, tenant)
        policy = None
        if donor is not None:
            policy = ElasticPolicy.from_slo(
                slo, metric=metric, hysteresis=hysteresis, window=window,
                percentile=percentile, cooldown=cooldown)
        replica_policy = None
        if autoscale_replicas:
            replica_policy = ElasticPolicy.from_slo(
                slo, metric="tpot", hysteresis=hysteresis, window=window,
                percentile=percentile, cooldown=cooldown)
        pol = self.add_policy(ReconcilePolicy(
            self.sup, server, donor, policy,
            replica_policy=replica_policy, queue_depth=queue_depth,
            queue_high=queue_high, pool_occupancy=pool_occupancy,
            occupancy_high=occupancy_high, tenant=tenant))
        # remembered so tick() re-derives the band when the application
        # re-applies a spec with a CHANGED SLOTarget — the objective is
        # the spec's, never frozen at registration time
        pol._slo_conf = {"metric": metric, "hysteresis": hysteresis,
                         "window": window, "percentile": percentile,
                         "cooldown": cooldown, "seen": slo,
                         "tenant": tenant}
        return pol

    @staticmethod
    def _resolve_slo(spec, server: str, tenant: Optional[str]):
        """The SLO a policy bands against: the tenant's own declared
        target when one exists, else the cell-level target."""
        cell = spec.cell(server)
        if tenant is not None and getattr(cell, "has_tenant",
                                          lambda _n: False)(tenant):
            tslo = cell.tenant(tenant).slo
            if tslo is not None:
                return tslo
        return cell.slo

    def _refresh_slo_bands(self, pol: ReconcilePolicy):
        """Re-derive an add_slo_policy band after the spec's SLO changed."""
        conf = getattr(pol, "_slo_conf", None)
        if conf is None:
            return
        spec = getattr(self.sup, "desired", None)
        if spec is None or not spec.has_cell(pol.server):
            return
        slo = self._resolve_slo(spec, pol.server, conf.get("tenant"))
        if slo is None or slo == conf["seen"]:
            return
        kw = {k: conf[k] for k in
              ("hysteresis", "window", "percentile", "cooldown")}
        try:
            if pol.policy is not None:
                pol.policy = ElasticPolicy.from_slo(
                    slo, metric=conf["metric"], **kw)
            if pol.replica_policy is not None:
                pol.replica_policy = ElasticPolicy.from_slo(
                    slo, metric="tpot", **kw)
        except ValueError:
            return      # new SLO dropped the needed target; keep old band
        conf["seen"] = slo

    def attach_server(self, server, decode_spec: Optional[str] = None):
        """Keep a DisaggServer's replica surface synced to the spec on
        every tick (``decode_spec`` defaults to the server's own base)."""
        self.servers.append((server, decode_spec))
        return server

    # -- one management cycle -------------------------------------------
    def tick(self, now: Optional[float] = None) -> dict:
        """Run one full cycle: health -> reconcile -> policies -> sync.

        ``now`` overrides wall-clock for simulated-time benchmarks (it is
        forwarded to the policies' cooldown logic).  Returns the tick
        record, also appended to :attr:`history`.
        """
        now = time.monotonic() if now is None else now
        rec = {"tick": self.ticks, "ts": now, "dead": [], "plan": "noop",
               "actions": [], "sync": {}}
        audited: List[dict] = []        # this tick's audit actions
        # 1. health: heartbeat-stale cells become failed, so the planner
        #    below schedules their recover
        check = getattr(self.sup, "check_health", None)
        if check is not None:
            for name in check():
                cell = self.sup.cells.get(name)
                if cell is not None and cell.status == "running":
                    cell.status = "failed"
                rec["dead"].append(name)
                audited.append({"kind": "mark_failed", "cell": name,
                                "reason": "heartbeat stale"})
        # 2. converge observed -> desired (recover, regrow, re-channel)
        plan = self.sup.reconcile()
        rec["plan"] = plan.summary()
        for op in getattr(plan, "ops", ()):
            audited.append({"kind": f"plan:{op.verb}",
                            "cell": getattr(op, "cell", None),
                            "reason": (f"reconcile: {op.verb} "
                                       f"{getattr(op, 'cell', '?')} "
                                       f"[{op.status}]")})
        # 3. SLO policies may rewrite + re-apply the spec (bands track the
        #    spec's CURRENT SLOTarget, not the one seen at registration)
        signals: dict = {}
        for policy in self.policies:
            self._refresh_slo_bands(policy)
            act = policy.maybe_act(now)
            if act:
                rec["actions"].append(act)
                audited.append(act)
            # the signals the policy ACTUALLY saw this tick (post-pull),
            # whether or not it acted — the audit must explain inaction
            # as well as action (duck-typed: hand-built policies need not
            # expose the full ReconcilePolicy surface)
            srv_name = getattr(policy, "server", None)
            if srv_name is None:
                continue
            sig = signals.setdefault(srv_name, {})
            if callable(getattr(policy, "tail", None)):
                sig["tail"] = policy.tail()
            if callable(getattr(policy, "replica_tail", None)):
                sig["tpot_tail"] = policy.replica_tail()
            qd = getattr(policy, "queue_depth", None)
            if callable(qd):
                sig["queue_depth"] = int(qd())
            occ = getattr(policy, "pool_occupancy", None)
            if callable(occ):
                sig["pool_occupancy"] = float(occ())
        # 4. serving surfaces follow the (possibly rescaled) spec
        for srv, base in self.servers:
            s = srv.sync(getattr(self.sup, "desired", None), base)
            if s["attached"] or s["detached"]:
                rec["sync"][base or srv._decode_base] = s
                audited.append({
                    "kind": "sync", "cell": base or srv._decode_base,
                    "reason": (f"replica surface converged: attached "
                               f"{s['attached']} detached {s['detached']} "
                               f"requeued {s['requeued']}")})
        self.audit.record(self.ticks, now, signals, audited)
        self.ticks += 1
        self.history.append(rec)
        return rec

    # -- timer loop -------------------------------------------------------
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self):
        """Tick every ``interval`` seconds on a background thread."""
        if self.running:
            raise RuntimeError("daemon already running")
        self._stop_evt.clear()
        self._thread = threading.Thread(
            target=self._run, name="supervisor-daemon", daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop_evt.wait(self.interval):
            try:
                self.tick()
            except Exception as e:  # keep the loop alive; surface the error
                self.errors.append({"ts": time.monotonic(), "error": repr(e)})

    def stop(self, timeout: float = 5.0):
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            if self._thread.is_alive():
                # a tick is still running: clearing _thread here would let
                # start() race a second concurrent daemon over the same
                # supervisor state
                raise RuntimeError(
                    f"daemon thread did not stop within {timeout}s")
            self._thread = None

    def __enter__(self) -> "SupervisorDaemon":
        self.start()
        return self

    def __exit__(self, *exc):
        self.stop()
        return False
