"""Cell — the subOS abstraction for TPU computing.

A cell *directly manages* its resources: it owns a mesh over its zone,
compiles its own programs for that mesh, holds its train/serve state, and
runs steps without any supervisor involvement on the step path.  The
supervisor only creates/destroys/resizes it — and applications do not
call even those verbs directly: they declare a
:class:`~repro.core.spec.CellSpec` (arch, role, ``[min,max]`` column
bounds, replicas, SLO targets) inside a ClusterSpec, and the reconciler
(``Supervisor.apply``/``reconcile``) drives the primitives that keep
this cell converged to it.

Paper §4.3 properties implemented here:
  1. management facility      -> CellSpec desired state; the reconciler
                                 executes create/destroy/resize_cell as
                                 its plan-executor layer
  2. exact accounting         -> CellAccounting per compiled program +
                                 per-request TTFT/TPOT (what elastic
                                 ReconcilePolicies read)
  3. IPC-like channels        -> ArrayChannel / ControlPlane endpoints
                                 (declared via ChannelSpec or opened on
                                 demand)
  4. fork-like spawn          -> Supervisor.spawn_child (sub-zone carved
                                 from the parent; lineage() walks it)
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.accounting import CellAccounting
from repro.core.partition import DeviceGrid, Zone
from repro.core.resharding import reshard_tree
from repro.models.model import build_model
from repro.sharding.rules import make_ctx
from repro.train.optimizer import OptConfig
from repro.train.train_step import (
    TrainState,
    build_train_step,
    init_train_state,
    train_state_pspecs,
)


class CellError(Exception):
    pass


class Cell:
    def __init__(
        self,
        name: str,
        zone: Zone,
        grid: DeviceGrid,
        arch: ArchConfig,
        role: str,                       # "train" | "serve"
        *,
        epoch: int,
        opt_cfg: Optional[OptConfig] = None,
        parent: Optional[str] = None,
    ):
        self.name = name
        self.arch = arch
        self.role = role
        self.parent = parent
        self.grid = grid
        self.opt_cfg = opt_cfg or OptConfig()
        self.accounting = CellAccounting(name)
        self.status = "created"
        self.step = 0
        self.last_heartbeat = time.monotonic()
        self.state: Optional[TrainState] = None
        self.serve_params = None
        self.serve_cache = None
        self._programs: Dict[str, Any] = {}
        self._bind_zone(zone, epoch)

    # ------------------------------------------------------------------
    # zone binding / resize
    # ------------------------------------------------------------------
    def _bind_zone(self, zone: Zone, epoch: int):
        self.zone = zone
        self.mesh = self.grid.zone_mesh(zone)
        self.ctx = make_ctx(self.mesh)
        self.model = build_model(self.arch, self.ctx)
        self.bound_epoch = epoch      # epoch programs are compiled under
        self.zone_epoch = epoch       # epoch of the last zone change
        self._programs.clear()

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.mesh.devices.shape))

    def default_sharding(self, ndim: int):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return NamedSharding(self.mesh, P(*([None] * ndim)))

    def heartbeat(self):
        self.last_heartbeat = time.monotonic()

    # ------------------------------------------------------------------
    # training role
    # ------------------------------------------------------------------
    def init_train(self, rng=None, *, compress: bool = False):
        assert self.role == "train"
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        state = init_train_state(self.model, rng, self.opt_cfg, compress=compress)
        shardings = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(self.mesh, s),
            train_state_pspecs(self.model, compress=compress),
        )
        self.state, _ = reshard_tree(state, shardings, donate=True)
        self._compress = compress
        self.status = "running"
        return self.state

    def _get_train_step(self) -> Callable:
        key = "train_step"
        if key not in self._programs:
            if self.bound_epoch != self.zone_epoch:
                self.bound_epoch = self.zone_epoch
            pspecs = train_state_pspecs(self.model, compress=getattr(self, "_compress", False))
            shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(self.mesh, s), pspecs
            )
            fn = jax.jit(
                build_train_step(self.model, self.opt_cfg,
                                 compress=getattr(self, "_compress", False)),
                in_shardings=(shardings, None),
                out_shardings=(shardings, None),
                donate_argnums=(0,),
            )
            self._programs[key] = fn
        return self._programs[key]

    def train_steps(self, batches, n: int) -> dict:
        """Run n steps; batches: callable step -> batch.

        The first call AOT-compiles the step for this zone's mesh, runs the
        BoundaryGuard over the executable (device confinement + epoch
        binding — the Security-guard analogue) and registers its exact cost
        with the cell's accounting.
        """
        if self.state is None:
            self.init_train()
        fn = self._get_train_step()
        metrics = {}
        for _ in range(n):
            batch = batches(self.step)
            key = "train_step_compiled"
            if key not in self._programs:
                compiled = fn.lower(self.state, batch).compile()
                from repro.core.guard import BoundaryGuard
                BoundaryGuard(lambda: None).validate(self, compiled)
                self.accounting.register_program("train_step", compiled)
                self._programs[key] = compiled
            self.state, metrics = self._programs[key](self.state, batch)
            self.step += 1
            self.heartbeat()
        self.accounting.record_invocation("train_step", n)
        return {k: float(v) for k, v in metrics.items()}

    # ------------------------------------------------------------------
    # serving role
    # ------------------------------------------------------------------
    def init_serve(self, params=None, rng=None):
        assert self.role == "serve"
        if params is None:
            params = self.model.init(rng if rng is not None else jax.random.PRNGKey(0))
        shardings = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(self.mesh, s),
            self.model.params_pspecs(),
        )
        self.serve_params = jax.device_put(params, shardings)
        self.status = "running"
        return self.serve_params

    def make_batcher(self, *, batch_slots: int, max_len: int, **kw):
        from repro.serve.batcher import ContinuousBatcher
        if self.serve_params is None:
            self.init_serve()
        kw.setdefault("accounting", self.accounting)
        return ContinuousBatcher(
            self.model, self.serve_params,
            batch_slots=batch_slots, max_len=max_len, **kw,
        )

    # ------------------------------------------------------------------
    # resize: live reshard onto the new zone
    # ------------------------------------------------------------------
    def resize_to(self, zone: Zone, epoch: int) -> dict:
        old = self.zone
        state = self.state if self.role == "train" else self.serve_params
        self._bind_zone(zone, epoch)
        stats = {"bytes": 0, "seconds": 0.0}
        if state is not None:
            if self.role == "train":
                pspecs = train_state_pspecs(self.model, compress=getattr(self, "_compress", False))
            else:
                pspecs = self.model.params_pspecs()
            shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(self.mesh, s), pspecs
            )
            new_state, stats = reshard_tree(state, shardings, donate=True)
            if self.role == "train":
                self.state = new_state
            else:
                self.serve_params = new_state
        stats.update(old=f"{old.ncols}cols", new=f"{zone.ncols}cols")
        return stats

    # ------------------------------------------------------------------
    def snapshot_state(self):
        return self.state if self.role == "train" else self.serve_params

    def destroy(self):
        self.status = "destroyed"
        self.state = None
        self.serve_params = None
        self.serve_cache = None
        self._programs.clear()
