"""Per-cell resource accounting.

The paper argues the subOS abstraction makes accounting *exact*: a subOS
owns its resources, so consumption attribution is unambiguous.  The same
holds here — each cell's compiled programs yield per-device FLOPs/bytes
(``cost_analysis``) and collective traffic (parsed from HLO), all of which
belong to that cell alone because nothing is shared.
"""
from __future__ import annotations

import dataclasses
import itertools
import re
from collections import defaultdict
from typing import Dict, List, Optional

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.M,
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum per-device output bytes of every collective op in an HLO module.

    ``-start/-done`` pairs are counted once (on the ``-start``).
    """
    out: Dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        line = m.group(0)
        if "-done(" in line:
            continue
        out[op] += _shape_bytes(shape_str)
    return dict(out)


def _normalize_cost_analysis(ca) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` returns a dict on newer jax and a
    per-device *list* of dicts on older releases (one entry per local
    device, all identical under SPMD).  Normalize to one flat dict."""
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


@dataclasses.dataclass
class RequestMetrics:
    """Per-request serving latencies (seconds), attributed to one cell."""
    rid: int
    ttft: Optional[float] = None     # submission -> first output token
    tpot: Optional[float] = None     # per-token decode latency after that
    prompt_len: int = 0
    new_tokens: int = 0
    tenant: Optional[str] = None     # QoS attribution (None = untagged)


def summarize_requests(requests) -> dict:
    """p50/p99/p99.9 TTFT/TPOT over any collection carrying .ttft/.tpot
    (the per-cell request log, or a merged multi-replica one)."""
    import numpy as np
    ttfts = [r.ttft for r in requests if r.ttft is not None]
    tpots = [r.tpot for r in requests if r.tpot is not None]
    out = {"requests": len(requests)}
    for key, xs in (("ttft", ttfts), ("tpot", tpots)):
        if xs:
            out[f"{key}_p50"] = float(np.percentile(xs, 50))
            out[f"{key}_p99"] = float(np.percentile(xs, 99))
            out[f"{key}_p999"] = float(np.percentile(xs, 99.9))
    return out


def tenant_percentile(requests, metric: str, q: float,
                      tenant: Optional[str] = None) -> Optional[float]:
    """Percentile ``q`` of ``metric`` (``"ttft"``/``"tpot"``) over the
    subset of ``requests`` attributed to ``tenant`` (None = all).  The
    per-tenant SLO probe: ``tenant_percentile(acct.requests, "ttft", 99,
    "paid")`` is the number a tenant's SLOTarget is judged against."""
    import numpy as np
    xs = [getattr(r, metric) for r in requests
          if getattr(r, metric, None) is not None
          and (tenant is None or getattr(r, "tenant", None) == tenant)]
    return float(np.percentile(xs, q)) if xs else None


@dataclasses.dataclass
class ProgramCost:
    name: str
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    collective_per_device: Dict[str, int] = dataclasses.field(default_factory=dict)
    arg_bytes: int = 0
    temp_bytes: int = 0
    invocations: int = 0

    @property
    def total_collective_bytes(self) -> int:
        return sum(self.collective_per_device.values())


class CellAccounting:
    """Exact per-cell attribution of compiled-program costs."""

    _ids = itertools.count()

    def __init__(self, cell_name: str):
        self.cell = cell_name
        # process-unique, never reused (unlike id()): readers that cursor
        # into ``requests`` key on this to detect a recovered cell's
        # fresh log (see ReconcilePolicy.pull)
        self.uid = next(CellAccounting._ids)
        self.programs: Dict[str, ProgramCost] = {}
        self.requests: List[RequestMetrics] = []
        # named event counters (serving-path waste/degradation signals:
        # prefill_dummy_rows, prefill_fallback_requests, ...)
        self.counters: Dict[str, int] = {}
        # the same counters broken down by tenant label:
        # tenant -> name -> value
        self.tenant_counters: Dict[str, Dict[str, int]] = {}
        # the cell's private flight recorder (spans + latency sketches);
        # same ownership rule as every field above — strictly per-cell
        from .telemetry import FlightRecorder
        self.recorder = FlightRecorder(cell_name)

    def register_program(self, name: str, compiled, hlo_text: Optional[str] = None):
        ca = _normalize_cost_analysis(compiled.cost_analysis())
        ma = compiled.memory_analysis()
        text = hlo_text if hlo_text is not None else compiled.as_text()
        pc = ProgramCost(
            name=name,
            flops_per_device=float(ca.get("flops", 0.0)),
            bytes_per_device=float(ca.get("bytes accessed", 0.0)),
            collective_per_device=collective_bytes(text),
            arg_bytes=getattr(ma, "argument_size_in_bytes", 0),
            temp_bytes=getattr(ma, "temp_size_in_bytes", 0),
        )
        self.programs[name] = pc
        return pc

    def record_request(self, rid: int, *, ttft: Optional[float] = None,
                       tpot: Optional[float] = None, prompt_len: int = 0,
                       new_tokens: int = 0,
                       tenant: Optional[str] = None) -> RequestMetrics:
        rm = RequestMetrics(rid=rid, ttft=ttft, tpot=tpot,
                            prompt_len=prompt_len, new_tokens=new_tokens,
                            tenant=tenant)
        self.requests.append(rm)
        return rm

    def serving_summary(self) -> dict:
        """p50/p99 TTFT and TPOT over every request this cell served."""
        return summarize_requests(self.requests)

    def tenant_summary(self) -> Dict[str, dict]:
        """:func:`summarize_requests` broken down by tenant label.
        Untagged requests roll up under ``None``."""
        by: Dict[Optional[str], List[RequestMetrics]] = defaultdict(list)
        for r in self.requests:
            by[r.tenant].append(r)
        return {t: summarize_requests(rs) for t, rs in by.items()}

    def tenant_percentile(self, metric: str, q: float,
                          tenant: Optional[str] = None) -> Optional[float]:
        """Per-tenant tail probe over this cell's request log."""
        return tenant_percentile(self.requests, metric, q, tenant)

    def record_counter(self, name: str, n: int = 1,
                       tenant: Optional[str] = None):
        """Bump a named event counter (e.g. batch-padding dummy rows, or
        requests served over a degraded path) — cheap, exact attribution
        of serving overheads that program costs alone can't show.  With
        ``tenant=`` the bump is additionally recorded under that label
        in :attr:`tenant_counters` (the global counter still moves, so
        unlabeled readers see totals)."""
        self.counters[name] = self.counters.get(name, 0) + n
        if tenant is not None:
            tc = self.tenant_counters.setdefault(tenant, {})
            tc[name] = tc.get(name, 0) + n

    def record_gauge(self, name: str, value: int,
                     tenant: Optional[str] = None):
        """Set a point-in-time counter (e.g. ``pages_in_use`` of the
        cell's KV pool) — unlike :meth:`record_counter` it overwrites,
        reflecting current state rather than a cumulative total.  Like
        :meth:`record_counter`, the global entry always moves; with
        ``tenant=`` the value is additionally mirrored under that
        label, so unlabeled readers see the latest state either way."""
        self.counters[name] = value
        if tenant is not None:
            self.tenant_counters.setdefault(tenant, {})[name] = value

    def record_invocation(self, name: str, n: int = 1):
        if name in self.programs:
            self.programs[name].invocations += n

    def totals(self) -> dict:
        t = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}
        for pc in self.programs.values():
            t["flops"] += pc.flops_per_device * pc.invocations
            t["bytes"] += pc.bytes_per_device * pc.invocations
            t["collective_bytes"] += pc.total_collective_bytes * pc.invocations
        return t
