"""Elastic physical partitions: Zones + the PartitionTable.

The paper's supervisor shares one tiny lock-free structure with all
subOSes: the *descriptions of physical partitions*.  Here that is the
:class:`PartitionTable` — an **immutable, epoch-versioned snapshot**.
Readers (cells) never lock; every mutation publishes a new table with
``epoch + 1``.  A cell binds its compiled programs to the epoch it was
created under; the BoundaryGuard rejects stale-epoch executions after a
resize (the analogue of Security guard bounding ``mov-to-cr3`` by the
partition descriptions).

Resource model: the cluster is a grid of devices ``(pods, R, C)``.  The
isolation granularity is one **column** (R chips sharing an ICI ring) so a
zone = a contiguous column range on one or more pods; all collectives of a
cell stay inside its own columns/rows (the "TLB shootdown confined to a
subOS" analogue).  Column 0 of pod 0 is reserved for the supervisor, like
the paper's firstly-booted instance.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, FrozenSet, Sequence, Tuple

import numpy as np
import jax
from jax.sharding import Mesh


class PartitionError(Exception):
    pass


@dataclasses.dataclass(frozen=True)
class Zone:
    """A contiguous sub-grid: columns [c0, c1) on each pod in ``pods``."""

    name: str
    pods: Tuple[int, ...]
    c0: int
    c1: int

    @property
    def ncols(self) -> int:
        return self.c1 - self.c0

    def columns(self) -> FrozenSet[Tuple[int, int]]:
        return frozenset((p, c) for p in self.pods for c in range(self.c0, self.c1))


@dataclasses.dataclass(frozen=True)
class PartitionTable:
    """Immutable snapshot of the cluster partitioning."""

    grid_shape: Tuple[int, int, int]          # (pods, R, C)
    epoch: int = 0
    zones: Tuple[Zone, ...] = ()
    failed_columns: FrozenSet[Tuple[int, int]] = frozenset()

    # ---- queries ----------------------------------------------------------
    def zone(self, name: str) -> Zone:
        for z in self.zones:
            if z.name == name:
                return z
        raise PartitionError(f"no zone {name!r}")

    def has_zone(self, name: str) -> bool:
        return any(z.name == name for z in self.zones)

    def used_columns(self) -> FrozenSet[Tuple[int, int]]:
        out: set = set()
        for z in self.zones:
            cols = z.columns()
            if out & cols:
                raise PartitionError("overlapping zones (corrupt table)")
            out |= cols
        return frozenset(out)

    def free_columns(self, pods: Sequence[int]) -> Dict[int, list]:
        """Free (non-failed) columns per pod, ascending."""
        used = self.used_columns() | self.failed_columns
        P_, R, C = self.grid_shape
        return {
            p: [c for c in range(C) if (p, c) not in used] for p in pods
        }

    def check_invariants(self):
        P_, R, C = self.grid_shape
        used = self.used_columns()               # raises on overlap
        for (p, c) in used:
            if not (0 <= p < P_ and 0 <= c < C):
                raise PartitionError(f"zone column ({p},{c}) outside grid")
        if used & self.failed_columns:
            raise PartitionError("zone includes failed column")

    # ---- mutations (all return a new epoch) --------------------------------
    def _bump(self, zones: Tuple[Zone, ...], failed=None) -> "PartitionTable":
        t = PartitionTable(
            grid_shape=self.grid_shape,
            epoch=self.epoch + 1,
            zones=zones,
            failed_columns=self.failed_columns if failed is None else failed,
        )
        t.check_invariants()
        return t

    def carve(self, name: str, ncols: int, pods: Sequence[int] = (0,)) -> Tuple["PartitionTable", Zone]:
        """First-fit a contiguous [c0,c1) range free on every requested pod."""
        if self.has_zone(name):
            raise PartitionError(f"zone {name!r} exists")
        if ncols < 1:
            raise PartitionError("ncols must be >= 1")
        P_, R, C = self.grid_shape
        used = self.used_columns() | self.failed_columns
        for c0 in range(0, C - ncols + 1):
            cols = [(p, c) for p in pods for c in range(c0, c0 + ncols)]
            if not any(col in used for col in cols):
                z = Zone(name=name, pods=tuple(pods), c0=c0, c1=c0 + ncols)
                return self._bump(self.zones + (z,)), z
        raise PartitionError(
            f"no contiguous {ncols}-column range free on pods {list(pods)}"
        )

    def release(self, name: str) -> "PartitionTable":
        self.zone(name)                  # raises on unknown zone
        return self._bump(tuple(x for x in self.zones if x.name != name))

    def resize(self, name: str, new_ncols: int, *, shrink_side: str = "right"
               ) -> Tuple["PartitionTable", Zone]:
        """Grow/shrink a zone; falls back to re-carving when the adjacent
        columns are taken (production note: a real allocator would migrate;
        the cell reshards its state either way).  ``shrink_side`` picks the
        edge released when shrinking (the transfer path frees the edge
        adjacent to the taker)."""
        z = self.zone(name)
        if new_ncols == z.ncols:
            return self, z
        used = (self.used_columns() - z.columns()) | self.failed_columns
        P_, R, C = self.grid_shape
        if new_ncols < z.ncols:
            if shrink_side == "left":
                nz = Zone(z.name, z.pods, z.c1 - new_ncols, z.c1)
            else:
                nz = Zone(z.name, z.pods, z.c0, z.c0 + new_ncols)
            zones = tuple(nz if x.name == name else x for x in self.zones)
            return self._bump(zones), nz
        # try growing right, then left
        grow = new_ncols - z.ncols
        right_ok = z.c1 + grow <= C and not any(
            (p, c) in used for p in z.pods for c in range(z.c1, z.c1 + grow)
        )
        if right_ok:
            nz = Zone(z.name, z.pods, z.c0, z.c1 + grow)
        else:
            left_ok = z.c0 - grow >= 0 and not any(
                (p, c) in used for p in z.pods for c in range(z.c0 - grow, z.c0)
            )
            if left_ok:
                nz = Zone(z.name, z.pods, z.c0 - grow, z.c1)
            else:
                t = self.release(name)
                return t.carve(name, new_ncols, z.pods)
        zones = tuple(nz if x.name == name else x for x in self.zones)
        return self._bump(zones), nz

    def transfer(self, src: str, dst: str, ncols: int) -> Tuple["PartitionTable", Zone, Zone]:
        """Move columns from one zone to another (the paper's CPU handoff).

        Frees the donor edge adjacent to the taker when they neighbor each
        other; if the shapes still don't fit, relocates both zones (the
        cells live-reshard onto their new zones either way)."""
        s = self.zone(src)
        if s.ncols - ncols < 1:
            raise PartitionError(f"{src!r} would drop below 1 column")
        d = self.zone(dst)
        side = "left" if s.c0 >= d.c1 else "right"
        try:
            t, ns = self.resize(src, s.ncols - ncols, shrink_side=side)
            t, nd = t.resize(dst, d.ncols + ncols)
            return t, ns, nd
        except PartitionError:
            pass
        # relocate both zones within the union of their columns + free space
        t = self.release(src).release(dst)
        t, nd = t.carve(dst, d.ncols + ncols, d.pods)
        t, ns = t.carve(src, s.ncols - ncols, s.pods)
        return t, ns, nd

    def mark_failed(self, pod: int, col: int) -> "PartitionTable":
        """Record a failed column; zones using it must be re-carved."""
        failed = self.failed_columns | {(pod, col)}
        zones = tuple(
            z for z in self.zones if (pod, col) not in z.columns()
        )
        t = PartitionTable(
            grid_shape=self.grid_shape, epoch=self.epoch + 1,
            zones=zones, failed_columns=failed,
        )
        t.check_invariants()
        return t

    def mark_restored(self, pod: int, col: int) -> "PartitionTable":
        """Return a failed column to the allocatable pool (quarantine is
        reversible: a repaired host rejoins; no-op when not failed)."""
        if (pod, col) not in self.failed_columns:
            return self
        return self._bump(self.zones, failed=self.failed_columns - {(pod, col)})


# ---------------------------------------------------------------------------
# device grids and meshes
# ---------------------------------------------------------------------------
class DeviceGrid:
    """Physical device array (pods, R, C) -> meshes for zones."""

    def __init__(self, devices: np.ndarray):
        assert devices.ndim == 3, "expect (pods, R, C)"
        self.devices = devices

    @property
    def shape(self) -> Tuple[int, int, int]:
        return tuple(self.devices.shape)  # type: ignore[return-value]

    @classmethod
    def from_flat(cls, devices: Sequence, pods: int, rows: int, cols: int,
                  allow_reuse: bool = False) -> "DeviceGrid":
        need = pods * rows * cols
        devs = list(devices)
        if len(devs) < need:
            if not allow_reuse:
                raise PartitionError(f"need {need} devices, have {len(devs)}")
            devs = list(itertools.islice(itertools.cycle(devs), need))
        arr = np.array(devs[:need], dtype=object).reshape(pods, rows, cols)
        return cls(arr)

    def zone_devices(self, zone: Zone) -> np.ndarray:
        sub = self.devices[list(zone.pods), :, zone.c0:zone.c1]
        return sub  # (npods, R, ncols)

    def zone_mesh(self, zone: Zone) -> Mesh:
        sub = self.zone_devices(zone)
        if sub.shape[0] == 1:
            return Mesh(sub[0], ("data", "model"))
        return Mesh(sub, ("pod", "data", "model"))


def single_device_grid() -> DeviceGrid:
    """1x1x1 grid over the only device (logical zones for CPU tests)."""
    return DeviceGrid(np.array(jax.devices()[:1], dtype=object).reshape(1, 1, 1))
