"""Live resharding: move a pytree of arrays between meshes/shardings.

This is the mechanical core of "resizing a subOS": a cell's params,
optimizer state and KV caches are re-placed under the new zone's mesh.
``jax.device_put`` performs the cross-mesh transfer (ICI/DCN on real
hardware); no checkpoint round-trip is involved — mirroring the paper's
observation that the *elastic resize* path must be shorter than the
failure path.
"""
from __future__ import annotations

import time
from typing import Any, Tuple

import jax
import numpy as np


def tree_bytes(tree) -> int:
    return sum(
        int(np.prod(l.shape)) * l.dtype.itemsize for l in jax.tree.leaves(tree)
    )


def reshard_tree(tree: Any, target_shardings: Any, *, donate: bool = True) -> Tuple[Any, dict]:
    """Place every leaf under its target sharding.  Returns (tree, stats)."""
    t0 = time.monotonic()
    nbytes = tree_bytes(tree)
    out = jax.device_put(
        tree, target_shardings, donate=donate, may_alias=not donate
    )
    out = jax.block_until_ready(out)
    dt = time.monotonic() - t0
    return out, {"bytes": nbytes, "seconds": dt,
                 "gbps": nbytes / max(dt, 1e-9) / 1e9}
