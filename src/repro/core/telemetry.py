"""Per-cell flight recorder — request-path tracing, decision audit, export.

The paper makes accounting *exact* by making ownership exact: a subOS
owns its resources, so attribution is unambiguous.  `CellAccounting`
already exploits that for FLOPs/bytes; this module extends the same
principle to *time* and *decisions*:

* **Isolate first** — every cell records spans, events and latency
  sketches into its own bounded :class:`FlightRecorder` ring buffer.
  There is zero cross-cell shared state: span ids are scoped per
  recorder, clocks are injectable per recorder, and a cell that dies
  takes nothing from any other cell's log.
* **Then share** — the supervisor aggregates on demand over the
  existing control plane (:func:`collect_traces` mirrors the
  ``CachePlane.refresh`` advert round): each cell ships its *metadata*
  (span dicts, histogram buckets) as unicast messages to a
  supervisor-held endpoint; no recorder object ever crosses a cell
  boundary.  XOS (arXiv:1901.00825) makes the identical split —
  telemetry metadata in the trusted global plane, collection strictly
  application-owned.

One request yields ONE span tree.  The trace id is the request id; the
root ``request`` span is opened at the front door (the prefill cell in
disagg mode, the batcher's own cell colocated) and the *handle* rides
with the `Request` object across cells — like the request's latency
stamps already do — so whichever cell finishes (or sheds, or rejects)
the request closes the root.  Each span carries a backref to the
recorder that opened it; closing a span only ever touches that one
recorder, preserving isolation.

`HistogramSketch` is a DDSketch-style log-bucket histogram: O(1)
record, O(buckets) quantile, mergeable across cells — tail percentiles
(p50/p99/p99.9) stop being O(n) re-scans of the full request list.

`DecisionAudit` is the daemon's black box: every tick records the SLO
signals observed (ttft/tpot tails, queue depth, pool occupancy) and
each action taken with a human-readable reason
(``scale replicas 2->3: tpot_p99 0.0312 > ut 0.0250``), queryable
after the fact and folded into the Chrome trace export.

:func:`chrome_trace` emits the Chrome trace-event JSON format (the
``{"traceEvents": [...]}`` object form) — loadable in Perfetto /
``chrome://tracing``: one pid per cell, one tid per request (the trace
id), ``ph="X"`` complete events with microsecond ``ts``/``dur``.
"""
from __future__ import annotations

import json
import math
import time
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional


class TraceContext:
    """The propagated identity of a span: ``(trace_id, span_id)``.

    ``trace_id`` is the request id; ``span_id`` names a span within the
    recorder that opened it.  This is the only thing that crosses a
    cell boundary when a child span is opened remotely — two ints/strs,
    never a live object."""

    __slots__ = ("trace_id", "span_id")

    def __init__(self, trace_id, span_id: str):
        self.trace_id = trace_id
        self.span_id = span_id

    def __repr__(self):
        return f"TraceContext({self.trace_id!r}, {self.span_id!r})"


class Span:
    """One timed interval in a trace tree.

    Opened by :meth:`FlightRecorder.start_span`; closed by :meth:`end`.
    The backref ``_rec`` pins every mutation to the recorder that owns
    the span — a span handle may *ride* with a request across cells,
    but its storage never leaves its home cell."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "ts", "dur",
                 "attrs", "cell", "_rec")

    def __init__(self, name: str, trace_id, span_id: str,
                 parent_id: Optional[str], ts: float, cell: str, rec,
                 attrs: Optional[dict] = None):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.ts = ts
        self.dur: Optional[float] = None     # None while open
        self.attrs = dict(attrs) if attrs else {}
        self.cell = cell
        self._rec = rec

    @property
    def open(self) -> bool:
        return self.dur is None

    @property
    def ctx(self) -> TraceContext:
        return TraceContext(self.trace_id, self.span_id)

    def end(self, now: Optional[float] = None, **attrs):
        """Close the span (idempotent).  ``now`` overrides the owning
        recorder's clock for deterministic tests."""
        if self.dur is not None:
            return self
        t1 = self._rec.clock() if now is None else now
        self.dur = max(t1 - self.ts, 0.0)
        if attrs:
            self.attrs.update(attrs)
        self._rec._close(self)
        return self

    def to_dict(self) -> dict:
        return {"name": self.name, "trace_id": self.trace_id,
                "span_id": self.span_id, "parent_id": self.parent_id,
                "ts": self.ts, "dur": self.dur, "cell": self.cell,
                "attrs": dict(self.attrs)}


class _NullSpan:
    """The span returned by a disabled recorder: every operation no-ops
    so instrumentation sites never branch on enablement."""

    __slots__ = ()
    name = "null"
    trace_id = None
    span_id = "null/0"
    parent_id = None
    ts = 0.0
    dur = 0.0
    attrs: dict = {}
    cell = "null"
    open = False

    @property
    def ctx(self) -> TraceContext:
        return TraceContext(None, self.span_id)

    def end(self, now=None, **attrs):
        return self


NULL_SPAN = _NullSpan()


class EventLog:
    """Bounded ring buffer of span/event dicts.

    A cell's telemetry must never grow without bound (the recorder sits
    on the serving path): the ring keeps the most recent ``capacity``
    entries and counts what it dropped, so a reader can tell a complete
    log from a truncated one."""

    __slots__ = ("_ring", "appended")

    def __init__(self, capacity: int = 4096):
        self._ring: deque = deque(maxlen=capacity)
        self.appended = 0

    def append(self, item):
        self._ring.append(item)
        self.appended += 1

    @property
    def dropped(self) -> int:
        return self.appended - len(self._ring)

    def drain(self) -> list:
        out = list(self._ring)
        self._ring.clear()
        return out

    def __len__(self):
        return len(self._ring)

    def __iter__(self):
        return iter(self._ring)


class HistogramSketch:
    """Log-bucket histogram (DDSketch-flavoured): values land in bucket
    ``ceil(log(v)/log(gamma))``, giving a guaranteed relative error of
    ``(gamma-1)/(gamma+1)`` per quantile at O(1) record cost.  Buckets
    merge by index, so per-cell sketches combine across replicas (and
    across a detached replica's folded-in history) without re-scanning
    any request list."""

    __slots__ = ("gamma", "_lg", "buckets", "zeros", "count",
                 "total", "vmin", "vmax")

    def __init__(self, rel_err: float = 0.01):
        self.gamma = (1.0 + rel_err) / (1.0 - rel_err)
        self._lg = math.log(self.gamma)
        self.buckets: Dict[int, int] = {}
        self.zeros = 0           # non-positive values get their own bin
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, value: float, n: int = 1):
        value = float(value)
        self.count += n
        self.total += value * n
        if value < self.vmin:
            self.vmin = value
        if value > self.vmax:
            self.vmax = value
        if value <= 0.0:
            self.zeros += n
            return
        idx = math.ceil(math.log(value) / self._lg)
        self.buckets[idx] = self.buckets.get(idx, 0) + n

    def merge(self, other: "HistogramSketch"):
        for idx, n in other.buckets.items():
            self.buckets[idx] = self.buckets.get(idx, 0) + n
        self.zeros += other.zeros
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    def quantile(self, q: float) -> Optional[float]:
        """Value at quantile ``q`` in [0, 1] (None when empty).  Walks
        the sorted bucket indices once; rank semantics match
        ``np.percentile(..., interpolation='higher')`` up to the
        sketch's relative-error guarantee."""
        if self.count == 0:
            return None
        rank = min(max(int(math.ceil(q * self.count)), 1), self.count)
        if rank <= self.zeros:
            return max(min(0.0, self.vmax), self.vmin)
        seen = self.zeros
        for idx in sorted(self.buckets):
            seen += self.buckets[idx]
            if seen >= rank:
                # bucket idx covers (gamma^(idx-1), gamma^idx]; return
                # the midpoint estimate, clamped to observed extremes
                est = 2.0 * self.gamma ** idx / (self.gamma + 1.0)
                return min(max(est, self.vmin), self.vmax)
        return self.vmax

    def summary(self) -> dict:
        if self.count == 0:
            return {"count": 0}
        return {
            "count": self.count,
            "mean": self.total / self.count,
            "min": self.vmin,
            "max": self.vmax,
            "p50": self.quantile(0.50),
            "p99": self.quantile(0.99),
            "p999": self.quantile(0.999),
        }

    def to_dict(self) -> dict:
        return {"gamma": self.gamma, "zeros": self.zeros,
                "count": self.count, "total": self.total,
                "vmin": None if self.count == 0 else self.vmin,
                "vmax": None if self.count == 0 else self.vmax,
                "buckets": {str(k): v for k, v in self.buckets.items()}}

    @classmethod
    def from_dict(cls, d: dict) -> "HistogramSketch":
        h = cls()
        h.gamma = d["gamma"]
        h._lg = math.log(h.gamma)
        h.zeros = d["zeros"]
        h.count = d["count"]
        h.total = d["total"]
        h.vmin = math.inf if d["vmin"] is None else d["vmin"]
        h.vmax = -math.inf if d["vmax"] is None else d["vmax"]
        h.buckets = {int(k): v for k, v in d["buckets"].items()}
        return h


class FlightRecorder:
    """A cell's private telemetry plane: spans + events + sketches.

    * ``clock`` is injectable (default ``time.monotonic``) so tests can
      drive deterministic timestamps.
    * span ids are ``"{cell}/{n}"`` with a per-recorder counter — no
      global id state, so two cells can never contend or collide.
    * ``enabled=False`` turns every operation into a no-op returning
      :data:`NULL_SPAN`; the overhead gate in
      ``benchmarks/disagg_serving.py`` measures exactly this toggle.
    """

    def __init__(self, cell: str, *, clock: Callable[[], float] = time.monotonic,
                 capacity: int = 4096, enabled: bool = True):
        self.cell = cell
        self.clock = clock
        self.enabled = enabled
        self.log = EventLog(capacity)
        self.hists: Dict[str, HistogramSketch] = {}
        self._open: Dict[str, Span] = {}
        self._n = 0

    # -- spans ---------------------------------------------------------

    def start_span(self, name: str, trace_id=None,
                   parent: Optional[TraceContext] = None,
                   ts: Optional[float] = None, **attrs):
        if not self.enabled:
            return NULL_SPAN
        self._n += 1
        span = Span(
            name, trace_id, f"{self.cell}/{self._n}",
            parent.span_id if parent is not None else None,
            self.clock() if ts is None else ts, self.cell, self, attrs)
        self._open[span.span_id] = span
        return span

    def _close(self, span: Span):
        self._open.pop(span.span_id, None)
        self.log.append(span.to_dict())

    def add_complete(self, name: str, ts: float, dur: float, trace_id=None,
                     parent: Optional[TraceContext] = None, **attrs) -> None:
        """Record an already-finished interval in one call (batched
        invocations: one measured interval, one span per request)."""
        if not self.enabled:
            return
        self._n += 1
        self.log.append({
            "name": name, "trace_id": trace_id,
            "span_id": f"{self.cell}/{self._n}",
            "parent_id": parent.span_id if parent is not None else None,
            "ts": ts, "dur": max(dur, 0.0), "cell": self.cell,
            "attrs": dict(attrs)})

    @property
    def open_spans(self) -> List[Span]:
        return list(self._open.values())

    # -- scalars -------------------------------------------------------

    def record(self, name: str, value: float):
        if not self.enabled:
            return
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = HistogramSketch()
        h.record(value)

    # -- export --------------------------------------------------------

    def dump(self, reset: bool = False) -> dict:
        """The cell's telemetry metadata, as a plain dict safe to ship
        over the control plane.  ``reset=True`` drains the ring (used
        when a cell is detached and its history folds into the
        server-side archive)."""
        events = self.log.drain() if reset else list(self.log)
        out = {"cell": self.cell, "events": events,
               "dropped": self.log.dropped,
               "open_spans": [s.to_dict() for s in self._open.values()],
               "hists": {k: h.to_dict() for k, h in self.hists.items()}}
        if reset:
            self.hists = {}
        return out

    def summary(self) -> Dict[str, dict]:
        return {k: h.summary() for k, h in self.hists.items()}


#: Shared no-op recorder for instrumentation sites whose accounting is
#: absent (standalone batchers in unit tests pass ``accounting=None``).
DISABLED = FlightRecorder("disabled", enabled=False, capacity=1)


def recorder_of(accounting) -> FlightRecorder:
    """The recorder behind a ``CellAccounting`` (or :data:`DISABLED`
    when there is none) — the single lookup every instrumentation site
    uses, so sites never branch on wiring."""
    if accounting is None:
        return DISABLED
    return getattr(accounting, "recorder", None) or DISABLED


# -- request-scoped span helpers --------------------------------------
#
# The span tree of one request:
#
#   request                      (root; front-door cell)
#     queue                      (submit -> admit, re-opened on requeue)
#     route                      (disagg only: warm/cold decision)
#     prefill                    (cold | warm | warm_snapshot group)
#     channel:kv                 (disagg only: KV handoff bytes)
#     decode                     (admit-to-finish on the decode cell)
#     finish                     (zero-dur marker with ttft/tpot)
#
# The helpers stash live handles on the Request object itself
# (``req._tspans``) — the request already carries its latency stamps
# across cells, so its span handles ride the same way.

def open_request(rec: FlightRecorder, req, ts: Optional[float] = None):
    """Open the root ``request`` span plus its ``queue`` child at the
    front door.  No-op (returns the existing root) when the request
    already has one — resubmission via requeue must not fork the tree."""
    spans = getattr(req, "_tspans", None)
    if spans is not None and "request" in spans:
        return spans["request"]
    if ts is None:
        ts = getattr(req, "submitted_at", None)
    root = rec.start_span("request", trace_id=req.rid, ts=ts,
                          prompt_len=len(req.prompt),
                          tenant=getattr(req, "tenant", None))
    queue = rec.start_span("queue", trace_id=req.rid, parent=root.ctx,
                           ts=ts)
    req._tspans = {"request": root, "queue": queue}
    return root


def mark_admitted(req, ts: Optional[float] = None, **attrs):
    """Close the open ``queue`` span — the request got a slot."""
    spans = getattr(req, "_tspans", None)
    if spans:
        q = spans.pop("queue", None)
        if q is not None:
            q.end(now=ts, **attrs)


def open_decode(rec: FlightRecorder, req, ts: Optional[float] = None):
    """Open the ``decode`` span on the cell that owns the slot."""
    spans = getattr(req, "_tspans", None)
    if spans is None or "request" not in spans:
        return NULL_SPAN
    if "decode" in spans:
        return spans["decode"]
    d = rec.start_span("decode", trace_id=req.rid,
                       parent=spans["request"].ctx, ts=ts)
    spans["decode"] = d
    return d


def requeue_request(rec: FlightRecorder, req, reason: str,
                    ts: Optional[float] = None):
    """The request bounced back to the front door: close whatever phase
    was open (outcome recorded) and start a fresh ``queue`` wait."""
    spans = getattr(req, "_tspans", None)
    if not spans or "request" not in spans:
        return
    for phase in ("decode", "queue"):
        s = spans.pop(phase, None)
        if s is not None:
            s.end(now=ts, outcome=reason)
    spans["queue"] = rec.start_span("queue", trace_id=req.rid,
                                    parent=spans["request"].ctx, ts=ts,
                                    reason=reason)


def migrate_decode(req, new_rec: FlightRecorder,
                   ts: Optional[float] = None):
    """A drained slot moved replica-to-replica: the victim's decode
    span closes (``outcome="migrated"``) and a fresh one opens on the
    survivor — each half stored on the cell that actually ran it."""
    spans = getattr(req, "_tspans", None)
    if not spans or "request" not in spans:
        return
    old = spans.pop("decode", None)
    if old is not None:
        old.end(now=ts, outcome="migrated")
    spans["decode"] = new_rec.start_span(
        "decode", trace_id=req.rid, parent=spans["request"].ctx, ts=ts,
        migrated=True)


def finish_request(req, ts: Optional[float] = None, outcome: str = "ok"):
    """Close the request's whole tree: any open decode/queue child, a
    zero-duration ``finish`` marker with the latency stamps, then the
    root.  Safe to call for rejected/shed requests that never admitted."""
    spans = getattr(req, "_tspans", None)
    if not spans:
        return
    root = spans.get("request")
    if root is None or not root.open:
        return
    for phase in ("decode", "queue"):
        s = spans.pop(phase, None)
        if s is not None:
            s.end(now=ts, outcome=outcome)
    rec = root._rec
    end_ts = (rec.clock() if ts is None else ts)
    ttft = getattr(req, "ttft", None)
    tpot = getattr(req, "tpot", None)
    rec.add_complete("finish", end_ts, 0.0, trace_id=req.rid,
                     parent=root.ctx, outcome=outcome, ttft=ttft,
                     tpot=tpot,
                     new_tokens=len(getattr(req, "output", ()) or ()))
    root.end(now=end_ts, outcome=outcome)
    if ttft is not None:
        rec.record("ttft_s", ttft)
    if tpot is not None:
        rec.record("tpot_s", tpot)


def span_group(rec: FlightRecorder, name: str, reqs, t0: float, t1: float,
               parent_key: str = "request", **attrs):
    """One measured interval, one span per request (batched prefill /
    extend / restore invocations cover several requests at once)."""
    if not rec.enabled:
        return
    for r in reqs:
        spans = getattr(r, "_tspans", None)
        parent = None
        if spans and parent_key in spans:
            parent = spans[parent_key].ctx
        rec.add_complete(name, t0, t1 - t0, trace_id=r.rid,
                         parent=parent, **attrs)


# -- daemon decision audit --------------------------------------------

class DecisionAudit:
    """The daemon's black box: one bounded entry per tick holding the
    SLO signals observed and every action taken with its reason.

    Queryable after the fact (:meth:`query`) and folded into the Chrome
    trace export as instant events on the daemon's pid."""

    def __init__(self, capacity: int = 2048):
        self.log = EventLog(capacity)

    def record(self, tick: int, ts: float, signals: dict,
               actions: List[dict]):
        self.log.append({"tick": tick, "ts": ts,
                         "signals": dict(signals),
                         "actions": [dict(a) for a in actions]})

    def entries(self) -> List[dict]:
        return list(self.log)

    def query(self, kind: Optional[str] = None,
              cell: Optional[str] = None) -> List[dict]:
        """Flattened actions (each tagged with its tick/ts/signals),
        optionally filtered by action ``kind`` substring and/or cell."""
        out: List[dict] = []
        for e in self.log:
            for a in e["actions"]:
                if kind is not None and kind not in a.get("kind", ""):
                    continue
                if cell is not None and cell != a.get("cell"):
                    continue
                out.append({"tick": e["tick"], "ts": e["ts"],
                            "signals": e["signals"], **a})
        return out


# -- control-plane collection + Chrome export -------------------------

TELEMETRY_ENDPOINT = "telemetry"
TELEMETRY_DUMP = "telemetry_dump"


def collect_traces(supervisor, recorders: Dict[str, FlightRecorder],
                   ) -> List[dict]:
    """One collection round over the supervisor's control plane,
    mirroring ``CachePlane.refresh``: each cell unicasts its
    :meth:`FlightRecorder.dump` (metadata only) to the supervisor-held
    ``telemetry`` endpoint, which drains and returns the dumps.  Falls
    back to direct dumps when no supervisor is wired (colocated
    single-cell runs)."""
    if supervisor is None:
        return [rec.dump() for rec in recorders.values()]
    supervisor.control.register(TELEMETRY_ENDPOINT)
    for name, rec in recorders.items():
        supervisor.control.unicast(name, TELEMETRY_ENDPOINT,
                                   TELEMETRY_DUMP, rec.dump())
    return [msg.payload for msg in supervisor.control.drain(TELEMETRY_ENDPOINT)
            if msg.kind == TELEMETRY_DUMP]


def chrome_trace(dumps: Iterable[dict],
                 audit: Optional[DecisionAudit] = None) -> dict:
    """Chrome trace-event JSON (object form) from recorder dumps.

    One pid per cell, tid = trace id (the request id; 0 for untraced
    events), ``ph="X"`` complete events with microsecond timestamps
    offset from the earliest event, plus ``ph="M"`` process-name
    metadata and ``ph="i"`` instants for audit actions.  Every event
    carries ``ph``/``ts``/``pid``/``tid``."""
    dumps = list(dumps)
    events: List[dict] = []
    pids: Dict[str, int] = {}
    t0 = math.inf
    for d in dumps:
        for ev in list(d.get("events", ())) + list(d.get("open_spans", ())):
            if ev["ts"] < t0:
                t0 = ev["ts"]
    if audit is not None:
        for e in audit.entries():
            if e["ts"] < t0:
                t0 = e["ts"]
    if not math.isfinite(t0):
        t0 = 0.0

    def pid_of(cell: str) -> int:
        pid = pids.get(cell)
        if pid is None:
            pid = pids[cell] = len(pids) + 1
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": 0, "ts": 0,
                           "args": {"name": f"cell:{cell}"}})
        return pid

    for d in dumps:
        pid = pid_of(d.get("cell", "?"))
        for ev in d.get("events", ()):
            tid = ev.get("trace_id")
            events.append({
                "ph": "X", "name": ev["name"], "pid": pid,
                "tid": int(tid) if tid is not None else 0,
                "ts": (ev["ts"] - t0) * 1e6,
                "dur": (ev.get("dur") or 0.0) * 1e6,
                "args": {**ev.get("attrs", {}),
                         "span_id": ev.get("span_id"),
                         "parent_id": ev.get("parent_id")},
            })
        for ev in d.get("open_spans", ()):
            tid = ev.get("trace_id")
            events.append({
                "ph": "X", "name": ev["name"] + " (open)", "pid": pid,
                "tid": int(tid) if tid is not None else 0,
                "ts": (ev["ts"] - t0) * 1e6, "dur": 0.0,
                "args": {**ev.get("attrs", {}), "open": True,
                         "span_id": ev.get("span_id"),
                         "parent_id": ev.get("parent_id")},
            })
    audit_entries: List[dict] = []
    if audit is not None:
        pid = pid_of("daemon")
        for e in audit.entries():
            audit_entries.append(e)
            for a in e["actions"]:
                events.append({
                    "ph": "i", "name": a.get("kind", "action"), "pid": pid,
                    "tid": 0, "ts": (e["ts"] - t0) * 1e6, "s": "g",
                    "args": {**{k: v for k, v in a.items()},
                             "tick": e["tick"]},
                })
    out = {"traceEvents": events, "displayTimeUnit": "ms",
           "otherData": {"origin_ts": t0}}
    if audit is not None:
        out["otherData"]["decision_audit"] = audit_entries
    return out


def write_trace(path: str, trace: dict):
    with open(path, "w") as f:
        json.dump(trace, f)
