"""On-demand inter-cell communication (FICM / RFcom / RFloop analogues).

Confined state sharing: no channel exists until two endpoints open one, and
a channel's shared state is visible only to its two endpoints — mirroring
the paper's FICM message channels (unicast/multicast/broadcast) and
RFcom's ``rf_open/rf_read/rf_write/rf_map`` surface.

* Control plane (:class:`ControlPlane`): small messages over per-edge
  queues; on a real deployment this is the host network, here in-process.
* Data plane (:class:`ArrayChannel`): tensor transfer between two cells'
  meshes via ``jax.device_put`` (ICI/DCN path — the RFloop analogue:
  packets between co-located cells never leave the machine).  ``map``
  publishes an array to the peer without copying when the shardings are
  compatible (shared-memory mapping analogue).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import defaultdict, deque
from typing import Any, Dict, List, Optional

import jax

from repro.core.resharding import tree_bytes
from repro.core.telemetry import recorder_of


class ChannelError(Exception):
    pass


@dataclasses.dataclass
class Message:
    src: str
    kind: str
    payload: Any
    ts: float = dataclasses.field(default_factory=time.monotonic)


class ControlPlane:
    """FICM-style message channels between named endpoints."""

    def __init__(self):
        self._queues: Dict[str, deque] = defaultdict(deque)
        self._lock = threading.Lock()
        self._members: set = set()
        self.stats = defaultdict(int)

    def register(self, name: str):
        with self._lock:
            self._members.add(name)
            self._queues.setdefault(name, deque())

    def unregister(self, name: str):
        with self._lock:
            self._members.discard(name)
            self._queues.pop(name, None)

    def unicast(self, src: str, dst: str, kind: str, payload: Any = None):
        with self._lock:
            if dst not in self._members:
                raise ChannelError(f"unknown endpoint {dst!r}")
            self._queues[dst].append(Message(src, kind, payload))
            self.stats["unicast"] += 1

    def multicast(self, src: str, dsts, kind: str, payload: Any = None):
        for d in dsts:
            self.unicast(src, d, kind, payload)
        self.stats["multicast"] += 1

    def broadcast(self, src: str, kind: str, payload: Any = None):
        with self._lock:
            members = [m for m in self._members if m != src]
        for d in members:
            self.unicast(src, d, kind, payload)
        self.stats["broadcast"] += 1

    def poll(self, name: str) -> Optional[Message]:
        with self._lock:
            q = self._queues.get(name)
            return q.popleft() if q else None

    def drain(self, name: str) -> List[Message]:
        out = []
        while True:
            m = self.poll(name)
            if m is None:
                return out
            out.append(m)


@dataclasses.dataclass
class KVEnvelope:
    """One request's KV rows in flight on a channel (prefill -> decode)."""
    meta: dict
    cache: Any


class ArrayChannel:
    """RFcom-style typed array channel between two cells.

    ``send``/``recv`` move pytrees onto the destination cell's mesh;
    ``map`` hands over the buffer without copy when the destination
    sharding equals the source (zero-copy shared mapping); ``send_kv``/
    ``recv_kv`` carry per-request KV-cache rows for the disaggregated
    prefill-cell -> decode-cell handoff (see ``repro.serve.disagg``);
    ``send_pages``/``poll_pages`` carry interned page subtrees between
    decode replicas for live cache migration (``kind="pages"`` — see
    ``repro.serve.cacheplane``).
    """

    _ids = itertools.count()

    def __init__(self, src_cell, dst_cell, kind: str = "array"):
        self.cid = next(self._ids)
        self.src = src_cell
        self.dst = dst_cell
        self.kind = kind
        self._inbox: deque = deque()
        self.bytes_sent = 0
        self.transfers = 0
        self.seconds = 0.0
        self.open = True

    def _check_open(self):
        if not self.open:
            raise ChannelError("channel closed")

    def _shared_devices(self) -> bool:
        src = {id(d) for d in self.src.mesh.devices.flat}
        dst = {id(d) for d in self.dst.mesh.devices.flat}
        return bool(src & dst)

    def _transfer(self, tree: Any, target_shardings: Any = None):
        t0 = time.monotonic()
        if target_shardings is None:
            target_shardings = jax.tree.map(
                lambda l: self.dst.default_sharding(getattr(l, "ndim", 0)), tree
            )
        out = jax.device_put(tree, target_shardings)
        out = jax.block_until_ready(out)
        dt = time.monotonic() - t0
        nb = tree_bytes(out)
        self.bytes_sent += nb
        self.transfers += 1
        self.seconds += dt
        # per-transfer telemetry on the SENDING cell's recorder (the cell
        # whose devices sourced the bytes — exact attribution); page
        # migration (kind="pages") and weight fan-out land here too
        rec = recorder_of(getattr(self.src, "accounting", None))
        if rec.enabled:
            rec.add_complete(f"xfer:{self.kind}", t0, dt, bytes=nb,
                             dst=getattr(self.dst, "name", "?"))
            rec.record(f"xfer_{self.kind}_s", dt)
            rec.record(f"xfer_{self.kind}_bytes", nb)
        return out, {"bytes": nb, "seconds": dt, "gbps": nb / max(dt, 1e-9) / 1e9}

    def send(self, tree: Any, target_shardings: Any = None) -> dict:
        """Transfer a pytree to the destination cell's mesh."""
        self._check_open()
        out, stats = self._transfer(tree, target_shardings)
        self._inbox.append(out)
        return stats

    def send_kv(self, slot_cache: Any, target_shardings: Any = None,
                *, meta: Optional[dict] = None) -> dict:
        """Stream one request's per-slot KV rows onto the decode cell's
        mesh (the share-on-demand handoff).  ``slot_cache`` is a 1-row
        cache as produced by the prefill program / ``slice_cache_slots``;
        ``meta`` carries the request bookkeeping (rid, first token, ...)."""
        self._check_open()
        out, stats = self._transfer(slot_cache, target_shardings)
        self._inbox.append(KVEnvelope(meta=dict(meta or {}), cache=out))
        return stats

    def send_pages(self, stacks: Any, target_shardings: Any = None,
                   *, meta: Optional[dict] = None) -> dict:
        """Stream interned KV PAGE stacks replica-to-replica (the cluster
        cache plane's migration path — see ``repro.serve.cacheplane``).
        ``stacks`` is a canonical page-stack list as produced by
        ``KVPool.export_subtree``; ``meta`` carries the tree records /
        request bookkeeping needed to re-intern on the destination."""
        self._check_open()
        out, stats = self._transfer(stacks, target_shardings)
        self._inbox.append(KVEnvelope(meta=dict(meta or {}), cache=out))
        return stats

    def poll_pages(self) -> Optional[KVEnvelope]:
        """Non-raising pop of the next in-flight page envelope."""
        return self.poll_kv()

    def map(self, tree: Any) -> dict:
        """Zero-copy publish (shared mapping analogue): the peer sees the
        same buffers.  Only valid when both zones share devices."""
        self._check_open()
        if not self._shared_devices():
            raise ChannelError(
                f"map on channel {self.cid}: zones share no devices "
                "(zero-copy mapping needs co-located cells; use send())"
            )
        self._inbox.append(tree)
        self.transfers += 1
        return {"bytes": 0, "seconds": 0.0, "zero_copy": True}

    def recv(self) -> Any:
        self._check_open()
        if not self._inbox:
            raise ChannelError("empty channel")
        return self._inbox.popleft()

    def recv_kv(self) -> KVEnvelope:
        """Pop the next in-flight KV envelope (meta + per-slot cache)."""
        out = self.recv()
        if not isinstance(out, KVEnvelope):
            raise ChannelError("head of channel is not a KV envelope")
        return out

    def poll_kv(self) -> Optional[KVEnvelope]:
        """Non-raising recv_kv: None when the channel is empty."""
        self._check_open()
        if not self._inbox:
            return None
        return self.recv_kv()

    def close(self):
        self.open = False
        self._inbox.clear()
