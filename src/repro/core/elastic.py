"""SLO-driven continuous elasticity — paper Fig 10/11, declaratively.

The paper bounds a latency-critical workload's tail latency with two
thresholds: if the tail over the last window exceeds ``ut``, a CPU moves
from the batch OS instance to the serving instance; if it falls below
``lt``, one moves back.  Here the unit is a mesh column — but the policy
never touches the transfer primitive.  :class:`ReconcilePolicy` pulls
live per-request TTFT/TPOT samples out of the server cell's
:class:`~repro.core.accounting.CellAccounting`, and when the tail
crosses a threshold it rewrites the desired ``ncols`` of the server and
donor :class:`~repro.core.spec.CellSpec`\\ s (within their
``[min_ncols, max_ncols]`` bounds) and re-applies the spec; the
reconciler turns the +1/-1 into a single column ``transfer`` with live
resharding on both cells.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class ElasticPolicy:
    """Threshold band + windowing for a :class:`ReconcilePolicy`.

    Column bounds live on the :class:`~repro.core.spec.CellSpec`
    (``min_ncols``/``max_ncols``), not here — the policy can only move
    the desired state inside what the spec allows.
    """

    lt: float                    # lower tail-latency threshold (seconds)
    ut: float                    # upper threshold
    window: int = 50             # samples in the sliding window
    percentile: float = 99.0
    cooldown: float = 0.0        # min seconds between actions
    metric: str = "ttft"         # "ttft" | "tpot" (CellAccounting fields)


class ReconcilePolicy:
    """Continuous elasticity: accounting -> spec ``ncols`` -> reconcile.

    Reads new request samples from the server spec's cell(s) — all
    replica instances feed one window — and on a threshold crossing
    moves one desired column between ``server`` and ``donor`` specs,
    then ``Supervisor.apply``s the updated spec.  Zero direct primitive
    calls; the reconciler owns execution.
    """

    def __init__(self, supervisor, server: str, donor: str, policy: ElasticPolicy):
        self.sup = supervisor
        self.server = server
        self.donor = donor
        self.policy = policy
        self.samples: Deque[float] = deque(maxlen=policy.window)
        self.last_action_ts = -1e9
        self.actions: List[dict] = []
        self._cursors: Dict[str, int] = {}   # per-instance accounting cursor

    # ------------------------------------------------------------------
    def _server_instances(self) -> List[str]:
        spec = getattr(self.sup, "desired", None)
        if spec is not None and spec.has_cell(self.server):
            return spec.cell(self.server).instances()
        return [self.server]

    def pull(self) -> int:
        """Ingest new TTFT/TPOT samples from the server cells' accounting."""
        n = 0
        for inst in self._server_instances():
            cell = self.sup.cells.get(inst)
            if cell is None:
                continue
            reqs = cell.accounting.requests
            # a recovered cell restarts with a fresh (shorter) log: read it
            # from the beginning rather than skipping past its samples
            start = self._cursors.get(inst, 0)
            if len(reqs) < start:
                start = 0
            for r in reqs[start:]:
                v = getattr(r, self.policy.metric, None)
                if v is not None:
                    self.samples.append(float(v))
                    n += 1
            self._cursors[inst] = len(reqs)
        return n

    def observe(self, latency: float):
        """Directly feed one sample (simulation / external metric path)."""
        self.samples.append(latency)

    def tail(self) -> Optional[float]:
        if len(self.samples) < max(5, self.policy.window // 5):
            return None
        return float(np.percentile(np.asarray(self.samples), self.policy.percentile))

    # ------------------------------------------------------------------
    def _rescale(self, delta: int):
        """Move ``delta`` desired columns per server replica, donor-funded.

        Total columns are conserved: a server spec with R replicas takes
        ``delta * R`` columns in aggregate, so the donor spec absorbs
        exactly that many (scaled by its own replica count).  Returns the
        executed plan, or None when either side is pinned at a bound or
        the exchange cannot balance — desired state never changes unless
        the whole swap fits."""
        spec = self.sup.desired
        if spec is None or not (spec.has_cell(self.server)
                                and spec.has_cell(self.donor)):
            return None                   # a later apply() dropped a cell
        r_server = spec.cell(self.server).replicas
        r_donor = spec.cell(self.donor).replicas
        spec2, applied = spec.scale_by(self.server, delta)
        if applied == 0:
            return None
        need = -applied * r_server        # aggregate columns the donor funds
        if need % r_donor != 0:
            return None
        spec3, compensated = spec2.scale_by(self.donor, need // r_donor)
        if compensated != need // r_donor:
            return None
        plan = self.sup.apply(spec3)
        if plan.ops and all(op.status == "blocked" for op in plan.ops):
            # nothing could move (e.g. no adjacent free columns): roll the
            # desired state back so the miss is neither logged as an action
            # nor arms the cooldown; observed state is unchanged
            self.sup.desired = spec
            return None
        return plan

    def maybe_act(self, now: Optional[float] = None) -> Optional[dict]:
        now = time.monotonic() if now is None else now
        self.pull()
        if now - self.last_action_ts < self.policy.cooldown:
            return None
        p = self.tail()
        if p is None:
            return None
        action = None
        if p > self.policy.ut:
            plan = self._rescale(+1)
            if plan is not None:
                action = {"kind": "grow_server", "p_tail": p,
                          "plan": plan.summary()}
        elif p < self.policy.lt:
            plan = self._rescale(-1)
            if plan is not None:
                action = {"kind": "shrink_server", "p_tail": p,
                          "plan": plan.summary()}
        if action:
            action["ts"] = now
            self.last_action_ts = now
            self.actions.append(action)
            self.samples.clear()   # fresh window after a topology change
        return action
