"""Elastic (lt, ut) threshold scheduler — paper Fig 10/11.

The paper bounds a latency-critical workload's tail latency with two
thresholds: if the p99 over the last window exceeds ``ut``, a CPU moves
from the batch OS instance to the serving instance; if it falls below
``lt``, one moves back.  Here the unit is a mesh column and the move is
``Supervisor.transfer_columns`` (live reshard on both cells).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional

import numpy as np


@dataclasses.dataclass
class ElasticPolicy:
    lt: float                    # lower tail-latency threshold (seconds or ms)
    ut: float                    # upper threshold
    window: int = 50             # samples in the sliding window
    percentile: float = 99.0
    cooldown: float = 0.0        # min seconds between actions
    min_server_cols: int = 1
    min_donor_cols: int = 1


class ThresholdScheduler:
    def __init__(self, supervisor, server: str, donor: str, policy: ElasticPolicy):
        self.sup = supervisor
        self.server = server
        self.donor = donor
        self.policy = policy
        self.samples: Deque[float] = deque(maxlen=policy.window)
        self.last_action_ts = -1e9
        self.actions: List[dict] = []

    def observe(self, latency: float):
        self.samples.append(latency)

    def tail(self) -> Optional[float]:
        if len(self.samples) < max(5, self.policy.window // 5):
            return None
        return float(np.percentile(np.asarray(self.samples), self.policy.percentile))

    def maybe_act(self, now: Optional[float] = None) -> Optional[dict]:
        now = time.monotonic() if now is None else now
        if now - self.last_action_ts < self.policy.cooldown:
            return None
        p = self.tail()
        if p is None:
            return None
        server_cols = self.sup.cells[self.server].zone.ncols
        donor_cols = self.sup.cells[self.donor].zone.ncols
        action = None
        if p > self.policy.ut and donor_cols > self.policy.min_donor_cols:
            stats = self.sup.transfer_columns(self.donor, self.server, 1)
            action = {"kind": "grow_server", "p_tail": p, **stats}
        elif p < self.policy.lt and server_cols > self.policy.min_server_cols:
            stats = self.sup.transfer_columns(self.server, self.donor, 1)
            action = {"kind": "shrink_server", "p_tail": p, **stats}
        if action:
            action["ts"] = now
            self.last_action_ts = now
            self.actions.append(action)
            self.samples.clear()   # fresh window after a topology change
        return action
