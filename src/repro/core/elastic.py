"""SLO-driven continuous elasticity — paper Fig 10/11, declaratively.

The paper bounds a latency-critical workload's tail latency with two
thresholds: if the tail over the last window exceeds ``ut``, a CPU moves
from the batch OS instance to the serving instance; if it falls below
``lt``, one moves back.  Here the policy never touches a transfer
primitive — it rewrites *desired state* and reconciles — and it scales
TWO axes of a :class:`~repro.core.spec.CellSpec`:

* **columns** (``ncols``): :class:`ReconcilePolicy` pulls live
  per-request TTFT/TPOT samples out of the server cell's
  :class:`~repro.core.accounting.CellAccounting`, and when the tail
  crosses a threshold it moves one desired column between the server
  and a donor spec (within their ``[min_ncols, max_ncols]`` bounds);
  the reconciler turns the +1/-1 into a single column ``transfer`` with
  live resharding on both cells.
* **replicas** (``replicas``): with a ``replica_policy`` configured,
  queue depth plus the TPOT tail drive the desired replica count of the
  server spec within ``[min_replicas, max_replicas]`` — reconcile then
  creates/destroys uniform decode instances and
  :meth:`~repro.serve.disagg.DisaggServer.sync` live-attaches/detaches
  them.

Threshold bands need not be hand-picked: :meth:`ElasticPolicy.from_slo`
derives ``(lt, ut)`` from the spec's declared
:class:`~repro.core.spec.SLOTarget` — ``ut`` is the target itself and
``lt = hysteresis * ut``, so the policy grows while out of SLO and only
shrinks once comfortably inside it.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

VALID_METRICS = ("ttft", "tpot")


@dataclasses.dataclass
class ElasticPolicy:
    """Threshold band + windowing for a :class:`ReconcilePolicy` axis.

    Column/replica bounds live on the :class:`~repro.core.spec.CellSpec`
    (``min_ncols``/``max_ncols``, ``min_replicas``/``max_replicas``),
    not here — the policy can only move the desired state inside what
    the spec allows.
    """

    lt: float                    # lower tail-latency threshold (seconds)
    ut: float                    # upper threshold
    window: int = 50             # samples in the sliding window
    percentile: float = 99.0
    cooldown: float = 0.0        # min seconds between actions
    metric: str = "ttft"         # "ttft" | "tpot" (CellAccounting fields)

    def __post_init__(self):
        if self.metric not in VALID_METRICS:
            raise ValueError(
                f"metric {self.metric!r} is not one of {VALID_METRICS} — "
                "a typo here would make pull() ingest nothing and silently "
                "disable elasticity"
            )
        if self.lt > self.ut:
            raise ValueError(f"lt={self.lt} > ut={self.ut}: the band is empty")
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")

    @classmethod
    def from_slo(cls, slo, *, metric: str = "ttft", hysteresis: float = 0.5,
                 **kw) -> "ElasticPolicy":
        """Derive the threshold band from a declared SLO target.

        ``ut`` is the spec's ``{metric}_p99`` (the latency objective
        itself: above it the cell is out of SLO and must grow) and
        ``lt = hysteresis * ut`` (only shrink once the tail sits
        comfortably inside the objective — the hysteresis gap prevents
        grow/shrink oscillation around a single threshold).
        """
        target = getattr(slo, f"{metric}_p99", None) if slo is not None else None
        if target is None:
            raise ValueError(
                f"SLOTarget declares no {metric}_p99 to derive a band from")
        if not 0.0 < hysteresis < 1.0:
            raise ValueError(f"hysteresis must be in (0, 1), got {hysteresis}")
        return cls(lt=hysteresis * target, ut=target, metric=metric, **kw)


class ReconcilePolicy:
    """Continuous elasticity: accounting -> spec ``ncols``/``replicas``
    -> reconcile.

    Reads new request samples from the server spec's cell(s) — all
    replica instances feed one window — and on a threshold crossing
    rewrites the desired spec, then ``Supervisor.apply``s it.  Zero
    direct primitive calls; the reconciler owns execution.

    Axes (either or both):

    * ``donor`` + ``policy``: move one desired *column* between the
      ``server`` and ``donor`` specs on a tail-latency crossing.
    * ``replica_policy`` (+ optional ``queue_depth`` callable, e.g.
      ``lambda: len(disagg_server.pending)``): grow the server spec's
      desired *replicas* when the queue backs up past ``queue_high`` or
      the TPOT tail exceeds the band; shrink when the queue is empty
      and the tail is comfortably low.
    """

    def __init__(self, supervisor, server: str, donor: Optional[str] = None,
                 policy: Optional[ElasticPolicy] = None, *,
                 replica_policy: Optional[ElasticPolicy] = None,
                 queue_depth: Optional[Callable[[], int]] = None,
                 queue_high: int = 4,
                 pool_occupancy: Optional[Callable[[], float]] = None,
                 occupancy_high: float = 0.9,
                 tenant: Optional[str] = None):
        if policy is None and replica_policy is None:
            raise ValueError("need at least one of policy / replica_policy")
        if policy is not None and donor is None:
            raise ValueError("the column axis needs a donor spec to fund it")
        if not 0.0 < occupancy_high <= 1.0:
            raise ValueError(
                f"occupancy_high must be in (0, 1], got {occupancy_high}")
        self.sup = supervisor
        self.server = server
        self.donor = donor
        self.policy = policy
        self.replica_policy = replica_policy
        self.queue_depth = queue_depth
        self.queue_high = queue_high
        # third replica-scaling signal: committed KV-pool pressure (e.g.
        # ``DisaggServer.pool_occupancy``) — latency tails lag a memory
        # squeeze, but a near-full pool blocks admissions RIGHT NOW
        self.pool_occupancy = pool_occupancy
        self.occupancy_high = occupancy_high
        # tenant-scoped elasticity: only that tenant's request samples
        # feed the window, so the cell grows for the tenant whose SLO is
        # actually violated — a noisy co-tenant's good latency can't mask
        # a victim's bad tail (and vice versa).  None = all traffic.
        self.tenant = tenant
        window = policy.window if policy is not None else replica_policy.window
        self.samples: Deque[float] = deque(maxlen=window)
        self.replica_samples: Deque[float] = deque(
            maxlen=replica_policy.window if replica_policy is not None else 1)
        self.last_action_ts = -1e9
        self.actions: List[dict] = []
        # per-instance cursor: (accounting identity, read offset)
        self._cursors: Dict[str, Tuple[int, int]] = {}

    # ------------------------------------------------------------------
    def _server_instances(self) -> List[str]:
        spec = getattr(self.sup, "desired", None)
        if spec is not None and spec.has_cell(self.server):
            return spec.cell(self.server).instances()
        return [self.server]

    def pull(self) -> int:
        """Ingest new TTFT/TPOT samples from the server cells' accounting."""
        n = 0
        for inst in self._server_instances():
            cell = self.sup.cells.get(inst)
            if cell is None:
                continue
            reqs = cell.accounting.requests
            # cursors are keyed on the accounting log's identity, not just
            # its length: a recovered cell restarts with a FRESH log that
            # may already have grown past the old cursor — a length check
            # alone would silently skip those samples forever.  uid is a
            # never-reused counter (id() can be recycled after GC).
            ident = getattr(cell.accounting, "uid", id(cell.accounting))
            prev_ident, start = self._cursors.get(inst, (ident, 0))
            if prev_ident != ident or len(reqs) < start:
                start = 0
            for r in reqs[start:]:
                if (self.tenant is not None
                        and getattr(r, "tenant", None) != self.tenant):
                    continue
                if self.policy is not None:
                    v = getattr(r, self.policy.metric, None)
                    if v is not None:
                        self.samples.append(float(v))
                        n += 1
                if self.replica_policy is not None and r.tpot is not None:
                    self.replica_samples.append(float(r.tpot))
                    if self.policy is None:
                        n += 1
            self._cursors[inst] = (ident, len(reqs))
        return n

    def observe(self, latency: float):
        """Directly feed one sample (simulation / external metric path)."""
        self.samples.append(latency)

    def _tail_of(self, samples: Deque[float], policy: ElasticPolicy
                 ) -> Optional[float]:
        if len(samples) < max(5, policy.window // 5):
            return None
        return float(np.percentile(np.asarray(samples), policy.percentile))

    def tail(self) -> Optional[float]:
        if self.policy is None:
            return None
        return self._tail_of(self.samples, self.policy)

    def replica_tail(self) -> Optional[float]:
        if self.replica_policy is None:
            return None
        return self._tail_of(self.replica_samples, self.replica_policy)

    # ------------------------------------------------------------------
    def _rescale(self, delta: int):
        """Move ``delta`` desired columns per server replica, donor-funded.

        Total columns are conserved: a server spec with R replicas takes
        ``delta * R`` columns in aggregate, so the donor spec absorbs
        exactly that many (scaled by its own replica count).  Returns the
        executed plan, or None when either side is pinned at a bound or
        the exchange cannot balance — desired state never changes unless
        the whole swap fits."""
        spec = self.sup.desired
        if spec is None or not (spec.has_cell(self.server)
                                and spec.has_cell(self.donor)):
            return None                   # a later apply() dropped a cell
        r_server = spec.cell(self.server).replicas
        r_donor = spec.cell(self.donor).replicas
        spec2, applied = spec.scale_by(self.server, delta)
        if applied == 0:
            return None
        need = -applied * r_server        # aggregate columns the donor funds
        if need % r_donor != 0:
            return None
        spec3, compensated = spec2.scale_by(self.donor, need // r_donor)
        if compensated != need // r_donor:
            return None
        plan = self.sup.apply(spec3)
        if plan.ops and all(op.status == "blocked" for op in plan.ops):
            # nothing could move (e.g. no adjacent free columns): roll the
            # desired state back so the miss is neither logged as an action
            # nor arms the cooldown; observed state is unchanged
            self.sup.desired = spec
            return None
        return plan

    def _rescale_replicas(self, delta: int):
        """Adjust the server spec's desired replica count within bounds."""
        spec = self.sup.desired
        if spec is None or not spec.has_cell(self.server):
            return None
        spec2, applied = spec.scale_replicas_by(self.server, delta)
        if applied == 0:
            return None
        old = set(spec.cell(self.server).instances())
        new = set(spec2.cell(self.server).instances())
        if not (old <= new or new <= old):
            # an UNBOUNDED spec crossing the instance-naming boundary
            # ("name" <-> "name/i") would make the reconciler destroy
            # every live replica and start cold — a full teardown (and a
            # zero-capacity window) is never worth a nominal +-1 step.
            # Replica-bounded specs use indexed names throughout (see
            # CellSpec.instances) and never hit this; crossing the
            # boundary stays an explicit apply().
            return None
        plan = self.sup.apply(spec2)
        if plan.ops and all(op.status == "blocked" for op in plan.ops):
            self.sup.desired = spec
            return None
        return plan

    # ------------------------------------------------------------------
    def _ncols(self) -> Optional[int]:
        spec = self.sup.desired
        if spec is not None and spec.has_cell(self.server):
            return spec.cell(self.server).ncols
        return None

    def _nreplicas(self) -> Optional[int]:
        spec = self.sup.desired
        if spec is not None and spec.has_cell(self.server):
            return spec.cell(self.server).replicas
        return None

    def _maybe_scale_cols(self, now: float) -> Optional[dict]:
        if self.policy is None:
            return None
        if now - self.last_action_ts < self.policy.cooldown:
            return None
        p = self.tail()
        if p is None:
            return None
        pct = self.policy.percentile
        metric = self.policy.metric
        if p > self.policy.ut:
            old = self._ncols()
            plan = self._rescale(+1)
            if plan is not None:
                self.samples.clear()   # fresh window after topology change
                return {"kind": "grow_server", "p_tail": p,
                        "cell": self.server,
                        "reason": (f"grow {self.server} cols "
                                   f"{old}->{self._ncols()}: "
                                   f"{metric}_p{pct:g} {p:.4f} > "
                                   f"ut {self.policy.ut:.4f}"),
                        "plan": plan.summary()}
        elif p < self.policy.lt:
            old = self._ncols()
            plan = self._rescale(-1)
            if plan is not None:
                self.samples.clear()
                return {"kind": "shrink_server", "p_tail": p,
                        "cell": self.server,
                        "reason": (f"shrink {self.server} cols "
                                   f"{old}->{self._ncols()}: "
                                   f"{metric}_p{pct:g} {p:.4f} < "
                                   f"lt {self.policy.lt:.4f}"),
                        "plan": plan.summary()}
        return None

    def _maybe_scale_replicas(self, now: float) -> Optional[dict]:
        rp = self.replica_policy
        if rp is None:
            return None
        if now - self.last_action_ts < rp.cooldown:
            return None
        qd = int(self.queue_depth()) if self.queue_depth is not None else 0
        occ = (float(self.pool_occupancy())
               if self.pool_occupancy is not None else None)
        tail = self.replica_tail()
        # grow on queue pressure alone (no decode samples flow while every
        # replica is saturated or gone), an out-of-band TPOT tail, OR a
        # near-exhausted KV pool (admissions are about to block)
        if (qd > self.queue_high
                or (tail is not None and tail > rp.ut)
                or (occ is not None and occ > self.occupancy_high)):
            # which signal(s) actually tripped — the audit's "why"
            why = []
            if qd > self.queue_high:
                why.append(f"queue_depth {qd} > {self.queue_high}")
            if tail is not None and tail > rp.ut:
                why.append(f"tpot_p{rp.percentile:g} {tail:.4f} > "
                           f"ut {rp.ut:.4f}")
            if occ is not None and occ > self.occupancy_high:
                why.append(f"pool_occupancy {occ:.2f} > "
                           f"{self.occupancy_high:.2f}")
            old = self._nreplicas()
            plan = self._rescale_replicas(+1)
            if plan is not None:
                self.replica_samples.clear()
                return {"kind": "grow_replicas", "p_tail": tail,
                        "queue_depth": qd, "pool_occupancy": occ,
                        "cell": self.server,
                        "reason": (f"scale replicas {old}->"
                                   f"{self._nreplicas()}: "
                                   + " | ".join(why)),
                        "plan": plan.summary()}
        elif (qd == 0 and tail is not None and tail < rp.lt
                and (occ is None or occ < self.occupancy_high / 2)):
            # never shrink into a memory squeeze: the surviving replicas
            # would inherit the victim's requeued requests' pages
            old = self._nreplicas()
            plan = self._rescale_replicas(-1)
            if plan is not None:
                self.replica_samples.clear()
                return {"kind": "shrink_replicas", "p_tail": tail,
                        "queue_depth": qd, "pool_occupancy": occ,
                        "cell": self.server,
                        "reason": (f"scale replicas {old}->"
                                   f"{self._nreplicas()}: queue empty, "
                                   f"tpot_p{rp.percentile:g} {tail:.4f} < "
                                   f"lt {rp.lt:.4f}"),
                        "plan": plan.summary()}
        return None

    def maybe_act(self, now: Optional[float] = None) -> Optional[dict]:
        now = time.monotonic() if now is None else now
        self.pull()
        action = self._maybe_scale_cols(now)
        if action is None:
            action = self._maybe_scale_replicas(now)
        if action:
            action["ts"] = now
            if self.tenant is not None:
                action["tenant"] = self.tenant
            self.last_action_ts = now
            self.actions.append(action)
        return action
