"""Supervisor — discovers, monitors, and provisions; never on the step path.

Owns the PartitionTable (epoch-versioned) and the cell registry.  Two API
layers:

* **Declarative control plane** (the one applications use):
  :meth:`Supervisor.apply` adopts a :class:`~repro.core.spec.ClusterSpec`
  as the desired state and :meth:`Supervisor.reconcile` continuously
  converges the cluster toward it — diffing desired vs. observed (cells,
  zones, health) and executing an ordered plan of primitive ops.  Elastic
  policies (:class:`~repro.core.elastic.ReconcilePolicy`) never call
  primitives; they rewrite the spec's desired ``ncols`` from live
  TTFT/TPOT accounting and reconcile.
* **Primitive plan-executor layer** (the paper's verbs): create /
  destroy / resize / transfer (preemption), fault detection via
  heartbeats, failed-column quarantine + checkpoint-restore recovery,
  ``restore_column`` to lift a quarantine, and straggler mitigation by
  resizing away from slow columns.  The reconciler is their only
  in-tree caller outside benchmarks of the primitives themselves.

Every operation is timestamped into an event log (the Table-4 elasticity
measurements read from it).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro.configs.base import ArchConfig
from repro.core.cell import Cell, CellError
from repro.core.channels import ArrayChannel, ControlPlane
from repro.core.guard import BoundaryGuard
from repro.core.partition import DeviceGrid, PartitionError, PartitionTable
from repro.core.reconciler import Plan, Reconciler
from repro.core.spec import ClusterSpec, SpecError
from repro.train.optimizer import OptConfig


class Supervisor:
    def __init__(self, grid: DeviceGrid, *, heartbeat_timeout: float = 30.0):
        self.grid = grid
        self.table = PartitionTable(grid_shape=grid.shape)
        self.cells: Dict[str, Cell] = {}
        self.control = ControlPlane()
        self.control.register("supervisor")
        self.guard = BoundaryGuard(lambda: self.table)
        self.heartbeat_timeout = heartbeat_timeout
        self.events: List[dict] = []
        self.channels: List[ArrayChannel] = []
        self.desired: Optional[ClusterSpec] = None
        # drain-before-destroy hooks: each is called with the doomed
        # cell's name while the cell and its channels are still live —
        # the serving plane's chance to hand state (hot KV pages,
        # in-flight requests) to survivors before the zone is released
        # (the paper's live subOS resize; see repro.serve.cacheplane)
        self.drain_hooks: List = []

    # ------------------------------------------------------------------
    # declarative control plane
    # ------------------------------------------------------------------
    def apply(self, spec: ClusterSpec) -> Plan:
        """Adopt ``spec`` as the desired state and reconcile toward it.

        The spec is total: cells it does not name are destroyed.  Returns
        the executed :class:`~repro.core.reconciler.Plan`.
        """
        self._validate_tenancy(spec)
        self.desired = spec
        self._log("apply", cells=[c.name for c in spec.cells])
        return self.reconcile()

    @staticmethod
    def _validate_tenancy(spec: ClusterSpec):
        """Tenancy is a property of a serving SURFACE, not one cell: a kv
        channel makes its two ends (prefill feeding decode) one surface,
        so a tenant contract declared on both ends must be identical —
        otherwise admission and quota decisions would disagree about the
        same request depending on which cell looks at it.  Declaring the
        contract on only one end is fine (the surface adopts it)."""
        for ch in spec.channels:
            if ch.kind != "kv":
                continue
            a, b = spec.cell(ch.src), spec.cell(ch.dst)
            if a.tenants and b.tenants and a.tenants != b.tenants:
                raise SpecError(
                    f"kv-joined cells {a.name!r} and {b.name!r} declare "
                    "conflicting tenant contracts — one serving surface, "
                    "one contract")

    def reconcile(self) -> Plan:
        """Converge observed state toward the last applied spec.

        Safe to call in a loop: an empty plan means converged; degraded
        cells keep a pending grow that lands once columns free up.
        """
        plan = Reconciler(self).reconcile(self.desired)
        if not plan.empty:
            self._log("reconcile", plan=plan.summary())
        return plan

    # ------------------------------------------------------------------
    def _log(self, op: str, **kw):
        evt = {"ts": time.monotonic(), "op": op, "epoch": self.table.epoch, **kw}
        self.events.append(evt)
        return evt

    # ------------------------------------------------------------------
    # lifecycle primitives
    # ------------------------------------------------------------------
    def create_cell(
        self,
        name: str,
        arch: ArchConfig,
        role: str,
        *,
        ncols: int = 1,
        pods: Sequence[int] = (0,),
        opt_cfg: Optional[OptConfig] = None,
        parent: Optional[str] = None,
    ) -> Cell:
        t0 = time.monotonic()
        self.table, zone = self.table.carve(name, ncols, pods)
        cell = Cell(
            name, zone, self.grid, arch, role,
            epoch=self.table.epoch, opt_cfg=opt_cfg, parent=parent,
        )
        self.cells[name] = cell
        self.control.register(name)
        self._log("create", cell=name, ncols=ncols, seconds=time.monotonic() - t0)
        return cell

    def destroy_cell(self, name: str):
        t0 = time.monotonic()
        cell = self.cells.pop(name)
        cell.destroy()
        for ch in self.channels:
            if ch.open and (ch.src is cell or ch.dst is cell):
                ch.close()
        if self.table.has_zone(name):   # a failed cell's zone is already gone
            self.table = self.table.release(name)
        self.control.unregister(name)
        self._log("destroy", cell=name, seconds=time.monotonic() - t0)

    def resize_cell(self, name: str, new_ncols: int) -> dict:
        t0 = time.monotonic()
        cell = self.cells[name]
        self.table, zone = self.table.resize(name, new_ncols)
        stats = cell.resize_to(zone, self.table.epoch)
        stats["seconds_total"] = time.monotonic() - t0
        self._log("resize", cell=name, **stats)
        return stats

    def transfer_columns(self, src: str, dst: str, ncols: int = 1) -> dict:
        """Preemption path: move columns from a donor to a taker cell."""
        t0 = time.monotonic()
        self.table, zs, zd = self.table.transfer(src, dst, ncols)
        s1 = self.cells[src].resize_to(zs, self.table.epoch)
        s2 = self.cells[dst].resize_to(zd, self.table.epoch)
        out = {
            "seconds_total": time.monotonic() - t0,
            "shrink": s1, "grow": s2,
        }
        self._log("transfer", src=src, dst=dst, ncols=ncols,
                  seconds=out["seconds_total"])
        return out

    def spawn_child(self, parent_name: str, child_name: str, arch: ArchConfig,
                    role: str, ncols: int = 1) -> Cell:
        """Fork-like spawn: the child's zone is carved out of the parent's."""
        parent = self.cells[parent_name]
        if parent.zone.ncols - ncols < 1:
            raise CellError("parent too small to fork")
        self.table, pz = self.table.resize(parent_name, parent.zone.ncols - ncols)
        parent.resize_to(pz, self.table.epoch)
        child = self.create_cell(
            child_name, arch, role, ncols=ncols, pods=parent.zone.pods,
            parent=parent_name,
        )
        self._log("spawn_child", parent=parent_name, child=child_name)
        return child

    # ------------------------------------------------------------------
    # health / fault tolerance
    # ------------------------------------------------------------------
    def check_health(self) -> List[str]:
        now = time.monotonic()
        dead = [
            c.name for c in self.cells.values()
            if c.status == "running" and now - c.last_heartbeat > self.heartbeat_timeout
        ]
        for name in dead:
            self._log("dead_cell", cell=name)
        return dead

    def fail_column(self, pod: int, col: int) -> List[str]:
        """A column (host/ICI ring) failed: evict affected cells."""
        affected = [
            z.name for z in self.table.zones if (pod, col) in z.columns()
        ]
        self.table = self.table.mark_failed(pod, col)
        for name in affected:
            cell = self.cells.get(name)
            if cell:
                cell.status = "failed"
        self._log("fail_column", pod=pod, col=col, affected=affected)
        return affected

    def restore_column(self, pod: int, col: int) -> bool:
        """Lift the quarantine from ``fail_column``/``mitigate_straggler``.

        Returns True when the column was quarantined.  The column is only
        made allocatable again — run :meth:`reconcile` afterwards to grow
        degraded cells back to their desired widths.
        """
        restored = (pod, col) in self.table.failed_columns
        self.table = self.table.mark_restored(pod, col)
        if restored:
            self._log("restore_column", pod=pod, col=col)
        return restored

    def recover_cell(self, name: str, *, ncols: Optional[int] = None,
                     ckpt_dir: Optional[str] = None) -> Cell:
        """Re-carve a zone for a failed cell and restore from checkpoint."""
        t0 = time.monotonic()
        old = self.cells[name]
        arch, role, opt_cfg = old.arch, old.role, old.opt_cfg
        pods = old.zone.pods
        for ch in self.channels:     # channels bound to the dead cell object
            if ch.open and (ch.src is old or ch.dst is old):
                ch.close()
        want = ncols if ncols is not None else old.zone.ncols
        if self.table.has_zone(name):
            self.table = self.table.release(name)
        del self.cells[name]
        self.control.unregister(name)
        cell = None
        for try_cols in range(want, 0, -1):
            try:
                cell = self.create_cell(name, arch, role, ncols=try_cols,
                                        pods=pods, opt_cfg=opt_cfg)
                break
            except PartitionError:
                continue
        if cell is None:
            raise PartitionError(
                f"cannot recover {name!r}: no free columns on pods {list(pods)}"
            )
        if cell.zone.ncols < want:
            self._log("recover_degraded", cell=name, want=want,
                      got=cell.zone.ncols)
        if ckpt_dir is not None:
            self.restore_from_ckpt(cell, ckpt_dir)
        self._log("recover", cell=name, seconds=time.monotonic() - t0)
        return cell

    def restore_from_ckpt(self, cell: Cell, ckpt_dir: str) -> bool:
        """Restore a cell's state from its latest checkpoint, by role.

        Train cells restore a full TrainState; serve cells checkpoint
        bare params (``snapshot_state``), so restoring those through
        ``abstract_train_state`` would raise on the leaf-count mismatch.
        Returns True when a checkpoint was restored; when none exists
        the cell comes back empty and ``recover_no_ckpt`` is logged so a
        misconfigured ``ckpt_dir`` is visible, not silent.
        """
        import jax
        from repro.checkpoint import checkpoint as ckpt
        step = ckpt.latest_step(ckpt_dir)
        if step is None:
            self._log("recover_no_ckpt", cell=cell.name, ckpt_dir=ckpt_dir)
            return False
        if cell.role == "train":
            from repro.train.train_step import (
                abstract_train_state,
                train_state_pspecs,
            )
            target = abstract_train_state(cell.model, cell.opt_cfg)
            shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(cell.mesh, s),
                train_state_pspecs(cell.model),
            )
            cell.state = ckpt.restore(ckpt_dir, step, target, shardings)
        else:
            target = cell.model.abstract_params()
            shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(cell.mesh, s),
                cell.model.params_pspecs(),
            )
            cell.serve_params = ckpt.restore(ckpt_dir, step, target, shardings)
        cell.step = step
        cell.status = "running"
        self._log("restore_ckpt", cell=cell.name, ckpt_dir=ckpt_dir, step=step)
        return True

    def mitigate_straggler(self, name: str, slow_col: int) -> dict:
        """Straggler policy: shrink the cell off a slow column and re-grow
        elsewhere (resize-away)."""
        cell = self.cells[name]
        pod = cell.zone.pods[0]
        affected = self.fail_column(pod, slow_col)  # quarantine slow column
        if name in affected:
            return {"action": "recovered", "cell": self.recover_cell(name).name}
        return {"action": "none"}

    def add_drain_hook(self, fn):
        """Register a drain-before-destroy hook (``fn(cell_name)``), run
        by the reconciler right before ``destroy_cell`` executes."""
        self.drain_hooks.append(fn)

    # ------------------------------------------------------------------
    # channels (on-demand sharing)
    # ------------------------------------------------------------------
    def open_channel(self, src: str, dst: str, kind: str = "array") -> ArrayChannel:
        """Open an on-demand data channel between two cells.

        ``kind`` is a label for the event log / introspection: "array" for
        generic pytree transfer (weight sync), "kv" for the disaggregated
        prefill->decode KV handoff (see ``repro.serve.disagg``), "pages"
        for replica-to-replica KV page migration (``repro.serve.cacheplane``).
        """
        ch = ArrayChannel(self.cells[src], self.cells[dst], kind=kind)
        self.channels.append(ch)
        self._log("open_channel", src=src, dst=dst, cid=ch.cid, kind=kind)
        return ch

    def find_channel(self, src: str, dst: str, kind: str = "array"
                     ) -> Optional[ArrayChannel]:
        """First still-open channel matching (src, dst, kind), else None."""
        for ch in self.channels:
            if (ch.open and ch.kind == kind
                    and ch.src.name == src and ch.dst.name == dst):
                return ch
        return None

    # ------------------------------------------------------------------
    def lineage(self, name: str) -> List[str]:
        """Fork ancestry of a cell: [name, parent, grandparent, ...]."""
        out = [name]
        cell = self.cells[name]
        while cell is not None and cell.parent is not None:
            out.append(cell.parent)
            cell = self.cells.get(cell.parent)
        return out

    def validate_cell_programs(self, name: str) -> int:
        """Run the BoundaryGuard over a cell's compiled programs.

        Jitted-but-not-yet-compiled entries carry no shardings and are
        skipped; every compiled executable is checked for device
        confinement + epoch freshness.  Returns the number validated.
        """
        cell = self.cells[name]
        checked = 0
        for prog in cell._programs.values():
            if hasattr(prog, "input_shardings") or hasattr(prog, "output_shardings"):
                self.guard.validate(cell, prog)
                checked += 1
        self.guard.validate_epoch(name, cell.bound_epoch)
        return checked
