"""BoundaryGuard — the Security-guard analogue.

The paper's Security guard maps the partition descriptions read-only and
checks privileged operations (page-table updates, ``mov-to-cr3``) against
them.  Here the "privileged operation" is *running a compiled program*:
the guard checks that

1. every device the executable touches lies inside the cell's zone
   (physical confinement), and
2. the program was compiled under the current partition-table epoch for
   that zone (no stale executables survive a resize — the resize is the
   analogue of a page-table change).

Like the paper (whose implementation omits enforcement), this is a
validation layer: it raises on violation rather than sandboxing XLA.
"""
from __future__ import annotations

from typing import Iterable

import jax


class BoundaryViolation(Exception):
    pass


def _sharding_devices(obj) -> set:
    devs: set = set()
    for leaf in jax.tree.leaves(obj):
        mesh = getattr(leaf, "mesh", None)
        if mesh is not None:
            devs.update(d.id for d in mesh.devices.flat)
        else:
            ds = getattr(leaf, "device_set", None)
            if ds:
                devs.update(d.id for d in ds)
    return devs


def executable_device_ids(compiled) -> set:
    """Device ids a compiled program will touch (from its shardings)."""
    devs: set = set()
    try:
        ins = compiled.input_shardings
        devs |= _sharding_devices(ins)
    except Exception:
        pass
    try:
        outs = compiled.output_shardings
        devs |= _sharding_devices(outs)
    except Exception:
        pass
    return devs


class BoundaryGuard:
    def __init__(self, table_provider):
        """table_provider: zero-arg callable returning the current table."""
        self._table = table_provider

    def validate_devices(self, compiled, zone_device_ids: Iterable[int], cell_name: str):
        used = executable_device_ids(compiled)
        allowed = set(zone_device_ids)
        extra = used - allowed
        if extra:
            raise BoundaryViolation(
                f"cell {cell_name!r}: executable touches devices {sorted(extra)} "
                f"outside its zone {sorted(allowed)}"
            )

    def validate_epoch(self, cell_name: str, bound_epoch: int):
        table = self._table()
        # A cell's programs bind to the epoch at compile time.  Any table
        # mutation that touched this cell's zone bumps its bound epoch via
        # the supervisor; mismatch => stale program.
        current = table.epoch
        if bound_epoch > current:
            raise BoundaryViolation(
                f"cell {cell_name!r}: program bound to future epoch {bound_epoch} > {current}"
            )

    def validate(self, cell, compiled):
        self.validate_devices(
            compiled,
            (d.id for d in cell.mesh.devices.flat),
            cell.name,
        )
        if cell.bound_epoch != cell.zone_epoch:
            raise BoundaryViolation(
                f"cell {cell.name!r}: program compiled at epoch {cell.bound_epoch} "
                f"but zone changed at epoch {cell.zone_epoch} (stale executable)"
            )
