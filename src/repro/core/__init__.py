"""IFTS core: supervisor + cells (subOSes) + declarative specs + channels."""
from repro.core.partition import (  # noqa: F401
    DeviceGrid,
    PartitionError,
    PartitionTable,
    Zone,
    single_device_grid,
)
from repro.core.cell import Cell, CellError  # noqa: F401
from repro.core.spec import (  # noqa: F401
    CellSpec,
    ChannelSpec,
    ClusterSpec,
    SLOTarget,
    SpecError,
    TenantSpec,
)
from repro.core.reconciler import Plan, PlanOp, Reconciler  # noqa: F401
from repro.core.supervisor import Supervisor  # noqa: F401
from repro.core.channels import (  # noqa: F401
    ArrayChannel,
    ChannelError,
    ControlPlane,
    KVEnvelope,
)
from repro.core.elastic import ElasticPolicy, ReconcilePolicy  # noqa: F401
from repro.core.daemon import SupervisorDaemon  # noqa: F401
from repro.core.guard import BoundaryGuard, BoundaryViolation  # noqa: F401
from repro.core.accounting import (  # noqa: F401
    CellAccounting,
    collective_bytes,
    tenant_percentile,
)
from repro.core.resharding import reshard_tree, tree_bytes  # noqa: F401
from repro.core.telemetry import (  # noqa: F401
    DecisionAudit,
    EventLog,
    FlightRecorder,
    HistogramSketch,
    Span,
    TraceContext,
    chrome_trace,
    collect_traces,
)
