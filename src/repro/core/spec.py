"""Declarative desired state: CellSpec / ClusterSpec.

The paper's supervisor "can create, destroy, resize a subOS on-the-fly";
the declarative layer turns those verbs into *state*: an application
writes down the cells it wants (arch, role, column bounds, replicas, SLO
targets) and the reconciler (``repro.core.reconciler``) continuously
diffs that desired state against the observed cluster and executes the
primitive ops that close the gap.  Nothing here touches devices — specs
are plain immutable data, cheap to copy and diff.

Conventions:

* A :class:`CellSpec` with ``replicas == N > 1`` materializes as N
  cells named ``"{name}/0" .. "{name}/N-1"`` — uniform instances that
  share arch/role/bounds (the Nanvix-style "density from uniform
  lifecycle" pattern); ``DisaggServer`` routes requests across them.
  Replica-BOUNDED specs (``max_replicas >= 2``) keep the indexed names
  even at ``replicas == 1``, so autoscaling only ever adds/removes
  instances and never renames the survivors; only an unbounded
  single-replica spec materializes as the bare ``spec.name``.
* ``ncols`` is the *desired* column count; ``min_ncols``/``max_ncols``
  bound what any policy may request and what a degraded cell may shrink
  to.  Policies never call resize primitives — they rewrite ``ncols``
  (see :class:`~repro.core.elastic.ReconcilePolicy`) and reconcile.
  ``replicas`` is bounded the same way by ``min_replicas``/
  ``max_replicas`` — the second elastic axis.
* ``ckpt_dir`` names where the cell's state checkpoints live.  It is
  *recovery metadata*: the reconciler threads it into the ``recover``
  op, so a re-carved cell comes back with its latest checkpointed state
  (train state for ``role="train"``, params for ``role="serve"``) —
  not just an empty zone.  Whoever runs the cell is still responsible
  for writing checkpoints there (``repro.checkpoint.checkpoint.save``);
  the spec only says where to look on recovery.
* A :class:`ChannelSpec` between replicated specs expands to the cross
  product of instances (one prefill cell fanning out to N decode cells
  declares a single channel spec).

Tenancy — the subOS model one level up the stack: a serving
:class:`CellSpec` may carry :class:`TenantSpec`\\ s, and each tenant is
to the cell what a subOS is to the machine.  *Isolate first*: a tenant's
``page_quota`` is a physical-resource partition of the cell's KV pool (a
pocket it can exhaust without ever touching another tenant's pages), its
``rate``/``burst`` token bucket bounds the work it may inject, and its
``weight`` sets its deficit-round-robin share of decode slots.  *Then
share*: the only cross-tenant surface is the pool's **public prefix
namespace** (e.g. a common system prompt) — a read-only, explicitly
granted mapping (``share_public``), the exact analogue of the paper's
supervisor-mediated inter-subOS memory grant.  Per-tenant ``slo``
targets feed :class:`~repro.core.elastic.ReconcilePolicy` so autoscale
defends the tenant that is out of SLO, not the aggregate.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.train.optimizer import OptConfig


class SpecError(Exception):
    pass


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """Latency objectives a serving cell should hold (seconds).

    ``ttft_p99``/``tpot_p99`` are upper bounds on the tail over the
    policy window; a reconcile policy grows the cell while the tail is
    above target and shrinks it when comfortably below (hysteresis is
    the policy's, not the target's).
    """

    ttft_p99: Optional[float] = None
    tpot_p99: Optional[float] = None


#: reserved pocket/namespace names (see ``repro.serve.tenancy``)
RESERVED_TENANTS = ("__public__", "__shared__")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """Per-tenant QoS contract carried on a serving :class:`CellSpec`.

    * ``weight`` — deficit-round-robin share of decode slots / prefill
      batches (relative to the other tenants on the cell).
    * ``page_quota`` — fraction of the cell's KV pool reserved as this
      tenant's private pocket; the tenant can exhaust its pocket but
      never the pool.  ``None`` = the tenant draws from the shared
      leftover commons.
    * ``rate``/``burst`` — token-bucket admission: at most ``burst``
      tokens of queued work admitted instantly, refilling at ``rate``
      tokens/second (a token ≈ one prompt or output position).
      ``rate=None`` = unthrottled.
    * ``slo`` — this tenant's own latency objective; feeds per-tenant
      :class:`~repro.core.elastic.ReconcilePolicy` windows.
    * ``share_public`` — the supervisor grant: may this tenant map the
      pool's public prefix namespace read-only?
    """

    name: str
    weight: float = 1.0
    page_quota: Optional[float] = None
    rate: Optional[float] = None
    burst: Optional[float] = None
    slo: Optional[SLOTarget] = None
    share_public: bool = True

    def __post_init__(self):
        if not self.name or "/" in self.name:
            raise SpecError(f"bad tenant name {self.name!r}")
        if self.name in RESERVED_TENANTS:
            raise SpecError(f"tenant name {self.name!r} is reserved")
        if not self.weight > 0:
            raise SpecError(f"tenant {self.name}: weight must be > 0")
        if self.page_quota is not None and not 0.0 < self.page_quota <= 1.0:
            raise SpecError(
                f"tenant {self.name}: page_quota must be in (0, 1]")
        if self.rate is not None and not self.rate > 0:
            raise SpecError(f"tenant {self.name}: rate must be > 0")
        if self.burst is not None and not self.burst > 0:
            raise SpecError(f"tenant {self.name}: burst must be > 0")
        if self.burst is not None and self.rate is None:
            raise SpecError(
                f"tenant {self.name}: burst without rate builds no bucket "
                "— declare the rate it caps, or drop it")


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """Desired state of one (possibly replicated) cell."""

    name: str
    arch: Any                          # ArchConfig (opaque to the spec layer)
    role: str                          # "train" | "serve"
    ncols: int = 1
    min_ncols: int = 1
    max_ncols: Optional[int] = None
    pods: Tuple[int, ...] = (0,)
    replicas: int = 1
    min_replicas: int = 1
    max_replicas: Optional[int] = None
    opt_cfg: Optional[OptConfig] = None
    slo: Optional[SLOTarget] = None
    ckpt_dir: Optional[str] = None
    tenants: Tuple[TenantSpec, ...] = ()

    def __post_init__(self):
        if self.tenants:
            if self.role != "serve":
                raise SpecError(
                    f"{self.name}: tenants only apply to serve cells")
            names = [t.name for t in self.tenants]
            if len(names) != len(set(names)):
                raise SpecError(f"{self.name}: duplicate tenants {names}")
            reserved = sum(t.page_quota or 0.0 for t in self.tenants)
            if reserved > 1.0 + 1e-9:
                raise SpecError(
                    f"{self.name}: tenant page quotas sum to {reserved:.3f} "
                    "> 1.0 — pockets may never oversubscribe the pool")
        if "/" in self.name:
            raise SpecError(f"cell name {self.name!r} may not contain '/' "
                            "(reserved for replica instances)")
        if self.min_replicas < 1:
            raise SpecError(f"{self.name}: min_replicas must be >= 1")
        if self.max_replicas is not None and self.max_replicas < self.min_replicas:
            raise SpecError(f"{self.name}: max_replicas < min_replicas")
        if not (self.min_replicas <= self.replicas
                <= (self.max_replicas if self.max_replicas is not None
                    else self.replicas)):
            raise SpecError(
                f"{self.name}: replicas={self.replicas} outside "
                f"[{self.min_replicas}, {self.max_replicas}]"
            )
        if self.min_ncols < 1:
            raise SpecError(f"{self.name}: min_ncols must be >= 1")
        if self.max_ncols is not None and self.max_ncols < self.min_ncols:
            raise SpecError(f"{self.name}: max_ncols < min_ncols")
        if not (self.min_ncols <= self.ncols
                <= (self.max_ncols if self.max_ncols is not None else self.ncols)):
            raise SpecError(
                f"{self.name}: ncols={self.ncols} outside "
                f"[{self.min_ncols}, {self.max_ncols}]"
            )

    # ------------------------------------------------------------------
    def clamp(self, ncols: int) -> int:
        hi = self.max_ncols if self.max_ncols is not None else ncols
        return max(self.min_ncols, min(ncols, hi))

    def with_ncols(self, ncols: int) -> "CellSpec":
        return dataclasses.replace(self, ncols=self.clamp(ncols))

    def clamp_replicas(self, replicas: int) -> int:
        hi = self.max_replicas if self.max_replicas is not None else replicas
        return max(self.min_replicas, min(replicas, hi))

    def with_replicas(self, replicas: int) -> "CellSpec":
        return dataclasses.replace(self, replicas=self.clamp_replicas(replicas))

    def instances(self) -> List[str]:
        """Concrete cell names this spec materializes as.

        Replica-BOUNDED specs (``max_replicas >= 2``) use indexed names
        even at ``replicas == 1``: scaling then only ever adds or
        removes ``name/i`` instances, never renames the survivors — a
        rename would force the reconciler to destroy every live replica
        for a nominal +-1 step.  Only unbounded single-replica specs
        keep the bare name."""
        if self.replicas == 1 and (self.max_replicas is None
                                   or self.max_replicas == 1):
            return [self.name]
        return [f"{self.name}/{i}" for i in range(self.replicas)]

    def tenant(self, name: str) -> TenantSpec:
        for t in self.tenants:
            if t.name == name:
                return t
        raise SpecError(f"{self.name}: no tenant spec {name!r}")

    def has_tenant(self, name: str) -> bool:
        return any(t.name == name for t in self.tenants)


@dataclasses.dataclass(frozen=True)
class ChannelSpec:
    """Desired on-demand channel between two cell specs (by spec name)."""

    src: str
    dst: str
    kind: str = "array"                # "array" | "kv" | "pages"


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """The whole desired world: named cell specs + channels between them.

    ``Supervisor.apply(spec)`` adopts this as the desired state; every
    ``reconcile()`` afterwards converges the cluster toward it.  Cells
    not named here are destroyed by reconcile — the spec is total, not
    additive.
    """

    cells: Tuple[CellSpec, ...] = ()
    channels: Tuple[ChannelSpec, ...] = ()

    def __post_init__(self):
        names = [c.name for c in self.cells]
        if len(names) != len(set(names)):
            raise SpecError(f"duplicate cell specs: {names}")
        for ch in self.channels:
            for end in (ch.src, ch.dst):
                if end not in names:
                    raise SpecError(f"channel endpoint {end!r} names no cell spec")

    # ---- queries ----------------------------------------------------------
    def cell(self, name: str) -> CellSpec:
        for c in self.cells:
            if c.name == name:
                return c
        raise SpecError(f"no cell spec {name!r}")

    def has_cell(self, name: str) -> bool:
        return any(c.name == name for c in self.cells)

    def instance_specs(self) -> Dict[str, CellSpec]:
        """Expand replicas: concrete cell name -> its (shared) spec."""
        out: Dict[str, CellSpec] = {}
        for c in self.cells:
            for inst in c.instances():
                out[inst] = c
        return out

    def instance_channels(self) -> List[Tuple[str, str, str]]:
        """Expand channels over replica instances: (src, dst, kind).

        A self-referential spec (``src == dst``, e.g. a replicated decode
        cell's peer "pages" mesh) expands to every ORDERED pair of
        distinct instances — a channel from an instance to itself is
        meaningless and is skipped."""
        out = []
        for ch in self.channels:
            for s in self.cell(ch.src).instances():
                for d in self.cell(ch.dst).instances():
                    if s == d:
                        continue
                    out.append((s, d, ch.kind))
        return out

    # ---- functional updates ----------------------------------------------
    def with_cell(self, spec: CellSpec) -> "ClusterSpec":
        """Add or replace the spec with the same name."""
        rest = tuple(c for c in self.cells if c.name != spec.name)
        return dataclasses.replace(self, cells=rest + (spec,))

    def without_cell(self, name: str) -> "ClusterSpec":
        cells = tuple(c for c in self.cells if c.name != name)
        channels = tuple(ch for ch in self.channels
                         if ch.src != name and ch.dst != name)
        return dataclasses.replace(self, cells=cells, channels=channels)

    def with_channel(self, channel: ChannelSpec) -> "ClusterSpec":
        return dataclasses.replace(self, channels=self.channels + (channel,))

    def scale(self, name: str, ncols: int) -> "ClusterSpec":
        """Set a cell spec's desired ncols (clamped to its bounds)."""
        return self.with_cell(self.cell(name).with_ncols(ncols))

    def scale_by(self, name: str, delta: int) -> Tuple["ClusterSpec", int]:
        """Adjust desired ncols by ``delta`` within bounds.

        Returns ``(new_spec, applied_delta)`` — applied_delta is 0 when
        the spec is already pinned at the relevant bound.
        """
        c = self.cell(name)
        new = c.clamp(c.ncols + delta)
        if new == c.ncols:
            return self, 0
        return self.with_cell(dataclasses.replace(c, ncols=new)), new - c.ncols

    def scale_replicas_by(self, name: str, delta: int) -> Tuple["ClusterSpec", int]:
        """Adjust desired replica count by ``delta`` within
        ``[min_replicas, max_replicas]``; same contract as :meth:`scale_by`."""
        c = self.cell(name)
        new = c.clamp_replicas(c.replicas + delta)
        if new == c.replicas:
            return self, 0
        return (self.with_cell(dataclasses.replace(c, replicas=new)),
                new - c.replicas)
