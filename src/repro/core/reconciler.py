"""Reconciler — diffs desired state (ClusterSpec) against observed cells.

``Supervisor.apply(spec)`` / ``Supervisor.reconcile()`` run through here:
the reconciler reads the supervisor's observed world (cells, their zones
and health) and the desired :class:`~repro.core.spec.ClusterSpec`, and
emits an ordered :class:`Plan` of primitive ops

    destroy -> shrink -> transfer -> grow -> create -> recover -> open_channel

executed via the supervisor's existing primitives (``destroy_cell``,
``resize_cell``, ``transfer_columns``, ``create_cell``, ``recover_cell``,
``open_channel``) — those verbs are now the *plan-executor layer*, no
caller outside ``core/`` sequences them by hand.

Convergence properties:

* **Idempotent**: once observed == desired, ``plan()`` is empty.
* **Degrading**: grows/creates that cannot be satisfied (no free
  columns) land as many columns as fit and stay in the plan — the cell
  re-expands on a later reconcile when columns free up (e.g. after
  ``Supervisor.restore_column`` lifts a quarantine).
* **Pairing**: a shrink on one cell and a grow on another become one
  ``transfer`` (the paper's CPU-handoff path, live reshard both sides).

The reconciler only needs a duck-typed supervisor (``cells`` mapping +
the primitive verbs), so pure-bookkeeping supervisors (the Table-5
simulation, unit tests) reuse the exact planning/execution logic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.partition import PartitionError
from repro.core.spec import CellSpec, ClusterSpec

VERB_ORDER = ("destroy", "shrink", "transfer", "grow", "create", "recover",
              "open_channel")


@dataclasses.dataclass
class PlanOp:
    """One primitive step of a plan."""

    verb: str                          # one of VERB_ORDER
    cell: Optional[str] = None         # target (dst for transfer)
    args: dict = dataclasses.field(default_factory=dict)
    status: str = "pending"            # pending | ok | degraded | blocked
    result: Optional[dict] = None

    def __repr__(self):
        extra = f" {self.args}" if self.args else ""
        return f"<{self.verb} {self.cell or ''}{extra} [{self.status}]>"


@dataclasses.dataclass
class Plan:
    """Ordered op list + per-op execution results."""

    ops: List[PlanOp] = dataclasses.field(default_factory=list)
    epoch: Optional[int] = None        # table epoch the plan was computed at

    @property
    def empty(self) -> bool:
        return not self.ops

    def by_verb(self, verb: str) -> List[PlanOp]:
        return [op for op in self.ops if op.verb == verb]

    def summary(self) -> str:
        counts: Dict[str, int] = {}
        for op in self.ops:
            counts[op.verb] = counts.get(op.verb, 0) + 1
        return " ".join(f"{v}:{counts[v]}" for v in VERB_ORDER if v in counts) or "noop"


class Reconciler:
    """Plans and executes the desired-vs-observed diff for a supervisor."""

    def __init__(self, supervisor):
        self.sup = supervisor

    # ------------------------------------------------------------------
    # planning (pure: reads observed state, emits ops, mutates nothing)
    # ------------------------------------------------------------------
    def plan(self, spec: Optional[ClusterSpec]) -> Plan:
        table = getattr(self.sup, "table", None)
        out = Plan(epoch=getattr(table, "epoch", None))
        if spec is None:
            return out
        desired = spec.instance_specs()
        observed = dict(self.sup.cells)

        # cells the spec no longer names — and existing cells whose
        # arch/role changed, which must be recreated
        recreate = set()
        for name, cell in observed.items():
            if name not in desired:
                out.ops.append(PlanOp("destroy", name))
            elif (getattr(cell, "role", None) != desired[name].role
                  or getattr(cell, "arch", None) is not desired[name].arch
                  and getattr(cell, "arch", None) != desired[name].arch):
                out.ops.append(PlanOp("destroy", name))
                recreate.add(name)

        # column deltas for healthy cells that stay
        deltas: Dict[str, int] = {}
        for name, cs in desired.items():
            cell = observed.get(name)
            if cell is None or name in recreate:
                continue
            if getattr(cell, "status", "running") == "failed":
                continue                           # handled by recover below
            deltas[name] = cs.ncols - cell.zone.ncols

        donors = [[n, -d] for n, d in deltas.items() if d < 0]
        takers = [[n, d] for n, d in deltas.items() if d > 0]
        shrinks, transfers, grows = [], [], []
        transferred: Dict[str, int] = {}     # donor -> cols leaving by transfer
        for taker in takers:
            for donor in donors:
                if taker[1] == 0:
                    break
                n = min(donor[1], taker[1])
                if n > 0:
                    transfers.append(PlanOp(
                        "transfer", taker[0],
                        {"src": donor[0], "dst": taker[0], "ncols": n},
                    ))
                    donor[1] -= n
                    taker[1] -= n
                    transferred[donor[0]] = transferred.get(donor[0], 0) + n
            if taker[1] > 0:
                grows.append(PlanOp(
                    "grow", taker[0], {"ncols": desired[taker[0]].ncols}))
        for donor in donors:
            if donor[1] > 0:
                # shrink only the residual: transfers execute AFTER this op
                # and take the remaining surplus, landing the donor exactly
                # on its desired width
                target = desired[donor[0]].ncols + transferred.get(donor[0], 0)
                shrinks.append(PlanOp("shrink", donor[0], {"ncols": target}))
        out.ops.extend(shrinks)
        out.ops.extend(transfers)
        out.ops.extend(grows)

        # new cells / failed cells to re-carve; recover threads the spec's
        # ckpt_dir through so the cell comes back with state, not just a zone
        for name, cs in desired.items():
            cell = observed.get(name)
            if cell is None or name in recreate:
                out.ops.append(PlanOp("create", name, {"ncols": cs.ncols}))
            elif getattr(cell, "status", "running") == "failed":
                out.ops.append(PlanOp(
                    "recover", name,
                    {"ncols": cs.ncols, "ckpt_dir": cs.ckpt_dir}))

        # declared channels not yet open — or whose endpoint is being
        # recreated this plan (destroy closes its channels mid-execution,
        # so an open channel observed NOW will be gone by then)
        find = getattr(self.sup, "find_channel", None)
        if find is not None:
            refreshed = {op.cell for op in out.ops
                         if op.verb in ("create", "recover")}
            live = {name for name in desired if name in observed} | refreshed
            for src, dst, kind in spec.instance_channels():
                if src not in live or dst not in live:
                    continue
                if (src in refreshed or dst in refreshed
                        or find(src, dst, kind) is None):
                    out.ops.append(PlanOp(
                        "open_channel", dst, {"src": src, "dst": dst, "kind": kind}))
        return out

    # ------------------------------------------------------------------
    # execution (runs the primitives; degrades instead of failing)
    # ------------------------------------------------------------------
    def execute(self, plan: Plan, spec: Optional[ClusterSpec]) -> Plan:
        desired = spec.instance_specs() if spec is not None else {}
        for op in plan.ops:
            try:
                if op.verb == "destroy":
                    # drain-before-destroy: the serving plane may hand the
                    # doomed cell's state to survivors while its channels
                    # are still open (live subOS resize — cacheplane)
                    for hook in getattr(self.sup, "drain_hooks", ()):
                        hook(op.cell)
                    op.result = self.sup.destroy_cell(op.cell) or {}
                    op.status = "ok"
                elif op.verb == "shrink":
                    op.result = self.sup.resize_cell(op.cell, op.args["ncols"])
                    op.status = "ok"
                elif op.verb == "transfer":
                    op.result = self.sup.transfer_columns(
                        op.args["src"], op.args["dst"], op.args["ncols"])
                    op.status = "ok"
                elif op.verb == "grow":
                    op.status, op.result = self._grow(op.cell, op.args["ncols"])
                elif op.verb == "create":
                    op.status, op.result = self._create(desired[op.cell], op.cell)
                elif op.verb == "recover":
                    cell = self.sup.recover_cell(
                        op.cell, ncols=op.args["ncols"],
                        ckpt_dir=op.args.get("ckpt_dir"))
                    op.status = ("ok" if cell.zone.ncols >= op.args["ncols"]
                                 else "degraded")
                    op.result = {"ncols": cell.zone.ncols}
                elif op.verb == "open_channel":
                    src, dst = op.args["src"], op.args["dst"]
                    if src not in self.sup.cells or dst not in self.sup.cells:
                        # an endpoint's create was blocked earlier in this
                        # plan; retry on a later reconcile
                        op.status = "blocked"
                        op.result = {"error": f"endpoint missing: "
                                     f"{src if src not in self.sup.cells else dst}"}
                    else:
                        ch = self.sup.open_channel(src, dst, kind=op.args["kind"])
                        op.status = "ok"
                        op.result = {"cid": ch.cid}
            except PartitionError as e:
                op.status = "blocked"
                op.result = {"error": str(e)}
        return plan

    def _grow(self, name: str, want: int):
        have = self.sup.cells[name].zone.ncols
        for n in range(want, have, -1):
            try:
                stats = self.sup.resize_cell(name, n)
                return ("ok" if n == want else "degraded"), stats
            except PartitionError:
                continue
        return "blocked", {"ncols": have}

    def _create(self, cs: CellSpec, instance: str):
        # degrade below min_ncols rather than not exist at all (mirrors
        # recover_cell); later reconciles grow the cell back to spec
        for n in range(cs.ncols, 0, -1):
            try:
                cell = self.sup.create_cell(
                    instance, cs.arch, cs.role, ncols=n, pods=cs.pods,
                    opt_cfg=cs.opt_cfg,
                )
                # boot from checkpoint when the spec declares one: a failed
                # cell whose recover could not re-carve degrades to a
                # create on a later reconcile, and must still come back
                # with its state
                restore = getattr(self.sup, "restore_from_ckpt", None)
                if cs.ckpt_dir is not None and restore is not None:
                    restore(cell, cs.ckpt_dir)
                return ("ok" if n == cs.ncols else "degraded"), \
                    {"ncols": cell.zone.ncols}
            except PartitionError:
                continue
        return "blocked", {}

    # ------------------------------------------------------------------
    def reconcile(self, spec: Optional[ClusterSpec]) -> Plan:
        return self.execute(self.plan(spec), spec)
