"""Training driver: boot a supervisor, spawn a training cell, run.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
        --smoke --steps 50 [--ckpt-dir /tmp/ckpt] [--resume]

``--smoke`` uses the reduced same-family config (CPU-friendly); the full
configs are exercised via the dry-run.  The cell checkpoints periodically
and ``--resume`` continues from the latest checkpoint (the data pipeline
is step-deterministic, so restarts don't skew batches).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ShapeConfig, smoke_config, with_opt_level
from repro.configs.registry import get_arch
from repro.core import Supervisor, single_device_grid
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.train.optimizer import OptConfig
from repro.train.train_step import abstract_train_state, train_state_pspecs


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=25)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--compress-grads", action="store_true")
    args = p.parse_args(argv)

    arch = get_arch(args.arch)
    if args.smoke:
        arch = smoke_config(arch)
    arch = with_opt_level(arch, True)

    sup = Supervisor(single_device_grid())
    cell = sup.create_cell(
        arch.name, arch, "train", ncols=1,
        opt_cfg=OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps,
                          m_dtype=arch.optimizer_m_dtype),
    )
    print(f"[train] {arch.name}: {cell.model.n_params()/1e6:.1f}M params on "
          f"{cell.n_devices} device(s)")
    shape = ShapeConfig("train", "train", args.seq, args.batch)
    pipe = SyntheticPipeline(DataConfig(kind="bigram"), arch, shape)

    if args.resume and args.ckpt_dir:
        step = ckpt.latest_step(args.ckpt_dir)
        if step is not None:
            target = abstract_train_state(cell.model, cell.opt_cfg)
            shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(cell.mesh, s),
                train_state_pspecs(cell.model))
            cell.state = ckpt.restore(args.ckpt_dir, step, target, shardings)
            cell.step = step
            print(f"[train] resumed from step {step}")

    t0 = time.time()
    while cell.step < args.steps:
        n = min(10, args.steps - cell.step)
        m = cell.train_steps(pipe.get_batch, n)
        if args.ckpt_dir and cell.step % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, cell.step, cell.state, blocking=False)
        tput = args.batch * args.seq * cell.step / (time.time() - t0)
        print(f"[{cell.step:5d}] xent={m['xent']:.3f} lr={m['lr']:.2e} "
              f"({tput:,.0f} tok/s)")
    print(f"[train] done; floor={pipe.bigram_entropy():.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
