"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: 256 chips as (16 data x 16 model).  Multi
pod: 2 pods x 256 chips, the "pod" axis being an extra data-parallel (or
pipeline) dimension that crosses the DCN boundary.
"""
from __future__ import annotations

import jax


def _axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types=`` only exists on newer jax (``jax.sharding.AxisType``);
    older releases default every axis to Auto, so omitting the kwarg there
    is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def make_mesh_for_devices(n_data: int, n_model: int, pods: int = 1):
    """Smaller meshes for tests (same axis conventions)."""
    if pods > 1:
        return jax.make_mesh(
            (pods, n_data, n_model), ("pod", "data", "model"),
            **_axis_types_kwargs(3),
        )
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"), **_axis_types_kwargs(2)
    )


# v5e-class hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
