"""Serving driver: spawn a serving cell and run batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --requests 32 --slots 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.base import smoke_config, with_opt_level
from repro.configs.registry import get_arch
from repro.core import CellSpec, ClusterSpec, Supervisor, single_device_grid
from repro.serve.batcher import Request


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--prefill-chunk", type=int, default=32,
                   help="chunked-prefill bucket size; 0 = token-at-a-time")
    args = p.parse_args(argv)

    arch = get_arch(args.arch)
    if args.smoke:
        arch = smoke_config(arch)
    arch = with_opt_level(arch, True)

    sup = Supervisor(single_device_grid())
    sup.apply(ClusterSpec(cells=(CellSpec(arch.name, arch, "serve", ncols=1),)))
    cell = sup.cells[arch.name]
    cell.init_serve()
    bat = cell.make_batcher(batch_slots=args.slots, max_len=args.max_len,
                            temperature=args.temperature,
                            prefill_chunk=args.prefill_chunk or None)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(0, arch.vocab, size=rng.integers(2, 12)).astype(np.int32)
        bat.submit(Request(rid=rid, prompt=prompt, max_new_tokens=args.max_new))
    done = bat.run_until_drained()
    dt = time.time() - t0

    lats = sorted(r.latency for r in done)
    toks = sum(len(r.output) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    print(f"[serve] latency p50={lats[len(lats)//2]*1e3:.1f}ms "
          f"p99={lats[int(len(lats)*0.99)-1]*1e3:.1f}ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
