import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST stay first — jax locks the device count on
# first init.  (That is also why this file has no `from __future__` import.)
_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we record, from the compiled artifact:
  * memory_analysis  (bytes/device — proves it fits)
  * cost_analysis    (per-device HLO FLOPs / bytes accessed)
  * per-collective traffic parsed from the post-SPMD HLO text

Results go to ``experiments/dryrun/<mesh>/<arch>__<shape>.json`` and feed
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both]
"""
__doc__ = _DOC

import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs.base import ArchConfig, ShapeConfig, shapes_for, with_opt_level
from repro.configs.registry import ARCHS, get_arch
from repro.core.accounting import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.sharding.rules import make_ctx
from repro.train.optimizer import OptConfig
from repro.train.train_step import (
    abstract_train_state,
    build_train_step,
    train_state_pspecs,
)


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )


def lower_cell(arch: ArchConfig, shape: ShapeConfig, mesh, *, opt_cfg=None):
    """Lower the right program for a (arch, shape) cell on a mesh."""
    # serving cells skip FSDP weight sharding (no optimizer state; avoids
    # a per-step weight all-gather) unless the arch needs it to fit
    fsdp = True if shape.kind == "train" else arch.serve_fsdp
    zero3_ok = (shape.kind == "train" and arch.train_layout == "zero3"
                and shape.global_batch % int(mesh.devices.size) == 0)
    ctx = make_ctx(mesh, fsdp=fsdp, dp_over_model=zero3_ok)
    model = build_model(arch, ctx)
    batch_sds, batch_pspecs = model.batch_specs(shape)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        opt_cfg = opt_cfg or OptConfig(m_dtype=arch.optimizer_m_dtype)
        state_sds = abstract_train_state(model, opt_cfg)
        state_ps = train_state_pspecs(model)
        fn = build_train_step(model, opt_cfg)
        jitted = jax.jit(
            fn,
            in_shardings=(_ns(mesh, state_ps), _ns(mesh, batch_pspecs)),
            out_shardings=(_ns(mesh, state_ps), None),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state_sds, batch_sds)
    elif shape.kind == "prefill":
        params_sds = model.abstract_params()
        params_ps = model.params_pspecs()
        cache_sds = model.abstract_cache(B, S)
        cache_ps = model.cache_pspecs(B, S)
        jitted = jax.jit(
            model.prefill,
            in_shardings=(_ns(mesh, params_ps), _ns(mesh, batch_pspecs),
                          _ns(mesh, cache_ps)),
            out_shardings=(None, _ns(mesh, cache_ps)),
            donate_argnums=(2,),
        )
        lowered = jitted.lower(params_sds, batch_sds, cache_sds)
    else:  # decode
        params_sds = model.abstract_params()
        params_ps = model.params_pspecs()
        cache_sds = model.abstract_cache(B, S)
        cache_ps = model.cache_pspecs(B, S)
        jitted = jax.jit(
            model.decode,
            in_shardings=(_ns(mesh, params_ps), _ns(mesh, cache_ps),
                          _ns(mesh, batch_pspecs)),
            out_shardings=(None, _ns(mesh, cache_ps)),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_sds, cache_sds, batch_sds)
    return model, lowered


def run_cell(arch: ArchConfig, shape: ShapeConfig, mesh, mesh_name: str,
             *, verbose: bool = True) -> dict:
    n_dev = int(mesh.devices.size)
    t0 = time.monotonic()
    model, lowered = lower_cell(arch, shape, mesh)
    t_lower = time.monotonic() - t0
    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    colls = collective_bytes(hlo)

    rec = {
        "arch": arch.name,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": mesh_name,
        "devices": n_dev,
        "n_params": model.n_params(),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": colls,
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_estimate_bytes": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
        },
    }
    if verbose:
        mem_gb = rec["memory"]["peak_estimate_bytes"] / 2**30
        print(
            f"[dryrun] {arch.name:24s} {shape.name:12s} {mesh_name:6s} "
            f"compile={t_compile:6.1f}s flops/dev={rec['flops_per_device']:.3e} "
            f"mem/dev={mem_gb:6.2f}GiB coll={sum(colls.values())/2**20:8.1f}MiB"
        )
    return rec


def out_path(root: str, mesh_name: str, arch: str, shape: str) -> str:
    d = os.path.join(root, mesh_name)
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape}.json")


def main(argv: Optional[list] = None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, help="arch id (default: all)")
    p.add_argument("--shape", default=None, help="shape name (default: all)")
    p.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--level", default="optimized", choices=["baseline", "optimized"])
    p.add_argument("--skip-existing", action="store_true")
    args = p.parse_args(argv)

    archs = [get_arch(args.arch)] if args.arch else list(ARCHS.values())
    archs = [with_opt_level(a, args.level == "optimized") for a in archs]
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    failures = []
    for arch in archs:
        shapes = shapes_for(arch)
        if args.shape:
            shapes = [s for s in shapes if s.name == args.shape]
            if not shapes:
                print(f"[dryrun] {arch.name}: shape {args.shape} skipped "
                      f"(not applicable — see DESIGN.md)")
                continue
        for shape in shapes:
            for mesh_name, mesh in meshes:
                path = out_path(args.out, mesh_name, arch.name, shape.name)
                if args.skip_existing and os.path.exists(path):
                    continue
                try:
                    rec = run_cell(arch, shape, mesh, mesh_name)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch.name, shape.name, mesh_name, str(e)[:200]))
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print("\nAll dry-run cells compiled successfully.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
