"""Encoder-decoder layers (SeamlessM4T backbone).

Encoder: bidirectional self-attention + FFN over precomputed source frame
embeddings (audio frontend stub).  Decoder: causal self-attention +
cross-attention to the encoder output + FFN.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (
    KVSlice,
    attention_block,
    attn_specs,
    chunked_attention,
    mlp_block,
    mlp_specs,
    norm_spec,
    rms_norm,
)
from repro.models.param import PSpec


def cross_attn_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    dh = cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    return {
        "wq": PSpec((d, hq, dh), ("embed", "heads", None), ("normal", 0)),
        "wk": PSpec((d, hkv, dh), ("embed", "kv_heads", None), ("normal", 0)),
        "wv": PSpec((d, hkv, dh), ("embed", "kv_heads", None), ("normal", 0)),
        "wo": PSpec((hq, dh, d), ("heads", None, "embed"), ("normal", 0)),
    }


def enc_layer_specs(cfg: ArchConfig) -> dict:
    return {
        "attn_norm": norm_spec(cfg.d_model),
        "attn": attn_specs(cfg),
        "mlp_norm": norm_spec(cfg.d_model),
        "mlp": mlp_specs(cfg),
    }


def dec_layer_specs(cfg: ArchConfig) -> dict:
    return {
        "attn_norm": norm_spec(cfg.d_model),
        "attn": attn_specs(cfg),
        "cross_norm": norm_spec(cfg.d_model),
        "cross": cross_attn_specs(cfg),
        "mlp_norm": norm_spec(cfg.d_model),
        "mlp": mlp_specs(cfg),
    }


class DecCache(NamedTuple):
    self_kv: KVSlice
    cross_k: jnp.ndarray   # (B, S_src, Hkv, Dh)
    cross_v: jnp.ndarray


def enc_layer(lp, x, cfg: ArchConfig, ctx=None) -> Tuple[jnp.ndarray, None, jnp.ndarray]:
    h = rms_norm(x, lp["attn_norm"], cfg.rms_eps)
    a, _ = attention_block(lp["attn"], h, cfg, ctx, mode="train", causal=False)
    x = x + a
    h = rms_norm(x, lp["mlp_norm"], cfg.rms_eps)
    x = x + mlp_block(lp["mlp"], h, cfg)
    return x, None, jnp.float32(0.0)


def cross_attend(cp, x, ck, cv, cfg: ArchConfig):
    """x: (B,Sq,D); ck/cv: (B,Skv,Hkv,Dh) precomputed; full (unmasked) attn."""
    q = jnp.einsum("bsd,dhk->bshk", x, cp["wq"])
    out = chunked_attention(
        q, ck, cv, causal=False,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        unroll=cfg.unroll_attn,
    )
    return jnp.einsum("bshk,hkd->bsd", out, cp["wo"])


def cross_kv(cp, memory):
    ck = jnp.einsum("bsd,dhk->bshk", memory, cp["wk"])
    cv = jnp.einsum("bsd,dhk->bshk", memory, cp["wv"])
    return ck, cv


def dec_layer(
    lp, x, cfg: ArchConfig, ctx=None, *, mode: str,
    memory: Optional[jnp.ndarray] = None,       # encoder output (train/prefill)
    cache: Optional[DecCache] = None, pos=None,
) -> Tuple[jnp.ndarray, Optional[DecCache], jnp.ndarray]:
    h = rms_norm(x, lp["attn_norm"], cfg.rms_eps)
    a, new_self = attention_block(
        lp["attn"], h, cfg, ctx, mode=mode,
        cache=None if cache is None else cache.self_kv, pos=pos,
    )
    x = x + a

    h = rms_norm(x, lp["cross_norm"], cfg.rms_eps)
    if mode in ("train", "prefill"):
        assert memory is not None
        ck, cv = cross_kv(lp["cross"], memory)
    else:
        assert cache is not None
        ck, cv = cache.cross_k, cache.cross_v
    x = x + cross_attend(lp["cross"], h, ck, cv, cfg)

    h = rms_norm(x, lp["mlp_norm"], cfg.rms_eps)
    x = x + mlp_block(lp["mlp"], h, cfg)

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = DecCache(self_kv=new_self, cross_k=ck, cross_v=cv)
    return x, new_cache, jnp.float32(0.0)
