"""Encoder-decoder layers (SeamlessM4T backbone).

Encoder: bidirectional self-attention + FFN over precomputed source frame
embeddings (audio frontend stub).  Decoder: causal self-attention +
cross-attention to the encoder output + FFN.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (
    KVSlice,
    attention_block,
    attn_specs,
    chunked_attention,
    mlp_block,
    mlp_specs,
    norm_spec,
    rms_norm,
)
from repro.models.param import PSpec


def cross_attn_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    dh = cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    return {
        "wq": PSpec((d, hq, dh), ("embed", "heads", None), ("normal", 0)),
        "wk": PSpec((d, hkv, dh), ("embed", "kv_heads", None), ("normal", 0)),
        "wv": PSpec((d, hkv, dh), ("embed", "kv_heads", None), ("normal", 0)),
        "wo": PSpec((hq, dh, d), ("heads", None, "embed"), ("normal", 0)),
    }


def enc_layer_specs(cfg: ArchConfig) -> dict:
    return {
        "attn_norm": norm_spec(cfg.d_model),
        "attn": attn_specs(cfg),
        "mlp_norm": norm_spec(cfg.d_model),
        "mlp": mlp_specs(cfg),
    }


def dec_layer_specs(cfg: ArchConfig) -> dict:
    return {
        "attn_norm": norm_spec(cfg.d_model),
        "attn": attn_specs(cfg),
        "cross_norm": norm_spec(cfg.d_model),
        "cross": cross_attn_specs(cfg),
        "mlp_norm": norm_spec(cfg.d_model),
        "mlp": mlp_specs(cfg),
    }


class DecCache(NamedTuple):
    self_kv: KVSlice
    cross_k: jnp.ndarray   # (B, S_src, Hkv, Dh)
    cross_v: jnp.ndarray
    # (B,) valid source-frame count behind cross_k/cross_v; positions
    # >= src_len are padding and masked out of cross attention.  0 (the
    # init value) masks everything — with zero-init cross memory that
    # degrades to the pre-src-plumbing behaviour (cross output 0).
    src_len: jnp.ndarray


def enc_layer(lp, x, cfg: ArchConfig, ctx=None,
              src_len: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, None, jnp.ndarray]:
    """Bidirectional encoder layer; ``src_len`` (B,) masks pad frames out
    of self-attention so a row's encoding never depends on how far its
    batch bucket was padded (outputs AT pad positions stay garbage and
    are masked downstream by the same ``src_len``)."""
    h = rms_norm(x, lp["attn_norm"], cfg.rms_eps)
    a, _ = attention_block(lp["attn"], h, cfg, ctx, mode="train", causal=False,
                           kv_len=src_len)
    x = x + a
    h = rms_norm(x, lp["mlp_norm"], cfg.rms_eps)
    x = x + mlp_block(lp["mlp"], h, cfg)
    return x, None, jnp.float32(0.0)


def cross_attend(cp, x, ck, cv, cfg: ArchConfig,
                 src_len: Optional[jnp.ndarray] = None):
    """x: (B,Sq,D); ck/cv: (B,Skv,Hkv,Dh) precomputed; full (non-causal)
    attention over the valid source prefix (``src_len`` rows masked)."""
    q = jnp.einsum("bsd,dhk->bshk", x, cp["wq"])
    out = chunked_attention(
        q, ck, cv, causal=False,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        unroll=cfg.unroll_attn, kv_len=src_len,
    )
    return jnp.einsum("bshk,hkd->bsd", out, cp["wo"])


def cross_kv(cp, memory):
    ck = jnp.einsum("bsd,dhk->bshk", memory, cp["wk"])
    cv = jnp.einsum("bsd,dhk->bshk", memory, cp["wv"])
    return ck, cv


def dec_layer(
    lp, x, cfg: ArchConfig, ctx=None, *, mode: str,
    memory: Optional[jnp.ndarray] = None,       # encoder output (train/prefill)
    cache: Optional[DecCache] = None, pos=None,
    src_len: Optional[jnp.ndarray] = None,      # (B,) valid memory prefix
) -> Tuple[jnp.ndarray, Optional[DecCache], jnp.ndarray]:
    """``src_len`` is taken from the caller in train/prefill (None = the
    whole memory is valid) and from the CACHE in decode, so the mask that
    shaped prefill cross-attention is replayed bit-identically at every
    decode step."""
    h = rms_norm(x, lp["attn_norm"], cfg.rms_eps)
    a, new_self = attention_block(
        lp["attn"], h, cfg, ctx, mode=mode,
        cache=None if cache is None else cache.self_kv, pos=pos,
    )
    x = x + a

    h = rms_norm(x, lp["cross_norm"], cfg.rms_eps)
    if mode in ("train", "prefill"):
        assert memory is not None
        ck, cv = cross_kv(lp["cross"], memory)
        if src_len is None:
            src_len = jnp.full((x.shape[0],), memory.shape[1], jnp.int32)
    else:
        assert cache is not None
        ck, cv = cache.cross_k, cache.cross_v
        src_len = cache.src_len
    x = x + cross_attend(lp["cross"], h, ck, cv, cfg, src_len=src_len)

    h = rms_norm(x, lp["mlp_norm"], cfg.rms_eps)
    x = x + mlp_block(lp["mlp"], h, cfg)

    new_cache = None
    if mode in ("prefill", "decode", "extend"):
        new_cache = DecCache(self_kv=new_self, cross_k=ck, cross_v=cv,
                             src_len=src_len)
    return x, new_cache, jnp.float32(0.0)
