"""Decoder layer definitions for dense / MoE transformer families."""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import moe as moe_mod
from repro.models.layers import (
    KVSlice,
    attention_block,
    attn_specs,
    mlp_block,
    mlp_specs,
    norm_spec,
    rms_norm,
)
from repro.sharding.rules import ShardCtx


def dense_layer_specs(cfg: ArchConfig, d_ff: Optional[int] = None) -> dict:
    return {
        "attn_norm": norm_spec(cfg.d_model),
        "attn": attn_specs(cfg),
        "mlp_norm": norm_spec(cfg.d_model),
        "mlp": mlp_specs(cfg, d_ff=d_ff),
    }


def moe_layer_specs(cfg: ArchConfig, ctx: ShardCtx) -> dict:
    return {
        "attn_norm": norm_spec(cfg.d_model),
        "attn": attn_specs(cfg),
        "mlp_norm": norm_spec(cfg.d_model),
        "moe": moe_mod.moe_specs(cfg, ctx),
    }


def dense_layer(
    lp, x, cfg: ArchConfig, ctx=None, *, mode: str,
    cache: Optional[KVSlice] = None, pos=None,
) -> Tuple[jnp.ndarray, Optional[KVSlice], jnp.ndarray]:
    h = rms_norm(x, lp["attn_norm"], cfg.rms_eps)
    a, new_cache = attention_block(lp["attn"], h, cfg, ctx, mode=mode, cache=cache, pos=pos)
    x = x + a
    h = rms_norm(x, lp["mlp_norm"], cfg.rms_eps)
    x = x + mlp_block(lp["mlp"], h, cfg)
    return x, new_cache, jnp.float32(0.0)


def moe_layer(
    lp, x, cfg: ArchConfig, ctx: ShardCtx, *, mode: str,
    cache: Optional[KVSlice] = None, pos=None,
) -> Tuple[jnp.ndarray, Optional[KVSlice], jnp.ndarray]:
    h = rms_norm(x, lp["attn_norm"], cfg.rms_eps)
    a, new_cache = attention_block(lp["attn"], h, cfg, ctx, mode=mode, cache=cache, pos=pos)
    x = x + a
    h = rms_norm(x, lp["mlp_norm"], cfg.rms_eps)
    y, aux = moe_mod.moe_block(lp["moe"], h, cfg, ctx, train=(mode == "train"))
    return x + y, new_cache, aux
