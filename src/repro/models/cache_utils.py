"""KV-cache slot slicing / merging — the data plane of disaggregated serving.

A continuous batcher's cache is a pytree whose leaves carry a batch ("slot")
dimension at a family-dependent axis (layer-stacked KV slices put it at
axis 1, doubly-stacked hybrid caches at axis 2, ...).  These helpers derive
the batch-axis index per leaf from the cache *specs* (each :class:`PSpec`
names its logical axes, so the position of ``"batch"`` is exact, not
guessed) and then slice whole per-request rows out of one cache or merge
them into free slots of another.

This is what moves over an :class:`~repro.core.channels.ArrayChannel` in the
prefill-cell -> decode-cell handoff: the prefill cell slices one request's
KV rows, the channel reshards them onto the decode cell's mesh, and the
decode cell merges them into a free batcher slot.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.models.layers import KVSlice
from repro.models.param import tree_map_pspec


def cache_batch_axes(model, batch: int, max_len: int) -> Any:
    """Tree (same structure as the cache) of per-leaf batch-axis indices."""
    return tree_map_pspec(
        lambda s: s.logical.index("batch"),
        model.cache_specs(batch, max_len),
    )


def slice_cache_slots(cache: Any, axes: Any, slots: Sequence[int]) -> Any:
    """Gather the given slot rows out of every cache leaf.

    Returns a cache whose batch dimension is ``len(slots)``; the original
    cache is untouched.
    """
    idx = jnp.asarray(slots, jnp.int32)
    return jax.tree.map(lambda c, a: jnp.take(c, idx, axis=a), cache, axes)


def merge_cache_slots(dst: Any, src: Any, axes: Any, slots: Sequence[int]) -> Any:
    """Write ``src`` rows (batch dim == len(slots)) into ``dst`` at ``slots``.

    Runs eagerly; on a multi-device cache the scatter may gather/reshard —
    the handoff path sends per-request rows already placed on the
    destination mesh, so this stays local in the common case.
    """
    idx = jnp.asarray(slots, jnp.int32)

    def put(d, s, a):
        return d.at[(slice(None),) * a + (idx,)].set(s)

    return jax.tree.map(put, dst, src, axes)


def install_cross_memory(cache: Any, mem, slots: Sequence[int]) -> Any:
    """Write per-request encdec cross-attention memory into batcher slots.

    ``mem`` = (cross_k (L, B, S_src, Hkv, Dh), cross_v, src_len (B,)) with
    B == len(slots) — the return shape of ``Model.encode_cross_rows``.
    Used by the token-at-a-time prompt path: the chunked path gets its
    cross memory from ``prefill_ranged``'s cache instead.
    """
    ck, cv, src_len = mem
    dec = cache["dec_layers"]
    idx = jnp.asarray(slots, jnp.int32)
    out = dict(cache)
    out["dec_layers"] = dec._replace(
        cross_k=dec.cross_k.at[:, idx].set(ck.astype(dec.cross_k.dtype)),
        cross_v=dec.cross_v.at[:, idx].set(cv.astype(dec.cross_v.dtype)),
        src_len=dec.src_len.at[:, idx].set(src_len[None, :]),
    )
    return out


def mask_pad_slots(cache: Any, length: jnp.ndarray) -> Any:
    """Invalidate cache slots beyond each row's true prompt length.

    Chunked prefill pads prompts to a bucket length, so positions
    ``length[b] .. S_pad-1`` hold garbage K/V.  Marking their ``slot_pos``
    as -1 makes the decode attention mask them out (``valid &= pos >= 0``)
    until the decode loop overwrites them with real tokens.
    """
    def fix(node):
        if isinstance(node, KVSlice):
            s_c = node.slot_pos.shape[-1]
            valid = jnp.arange(s_c, dtype=jnp.int32) < length[:, None]
            return node._replace(
                slot_pos=jnp.where(valid, node.slot_pos, jnp.int32(-1))
            )
        return node

    return jax.tree.map(fix, cache, is_leaf=lambda x: isinstance(x, KVSlice))
