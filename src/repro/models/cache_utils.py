"""KV-cache slot slicing / merging — the data plane of disaggregated serving.

A continuous batcher's cache is a pytree whose leaves carry a batch ("slot")
dimension at a family-dependent axis (layer-stacked KV slices put it at
axis 1, doubly-stacked hybrid caches at axis 2, ...).  These helpers derive
the batch-axis index per leaf from the cache *specs* (each :class:`PSpec`
names its logical axes, so the position of ``"batch"`` is exact, not
guessed) and then slice whole per-request rows out of one cache or merge
them into free slots of another.

This is what moves over an :class:`~repro.core.channels.ArrayChannel` in the
prefill-cell -> decode-cell handoff: the prefill cell slices one request's
KV rows, the channel reshards them onto the decode cell's mesh, and the
decode cell merges them into a free batcher slot.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.models.layers import KVSlice, PagedKVCache
from repro.models.param import tree_map_pspec


def cache_batch_axes(model, batch: int, max_len: int) -> Any:
    """Tree (same structure as the cache) of per-leaf batch-axis indices."""
    return tree_map_pspec(
        lambda s: s.logical.index("batch"),
        model.cache_specs(batch, max_len),
    )


def slice_cache_slots(cache: Any, axes: Any, slots: Sequence[int]) -> Any:
    """Gather the given slot rows out of every cache leaf.

    Returns a cache whose batch dimension is ``len(slots)``; the original
    cache is untouched.
    """
    idx = jnp.asarray(slots, jnp.int32)
    return jax.tree.map(lambda c, a: jnp.take(c, idx, axis=a), cache, axes)


def merge_cache_slots(dst: Any, src: Any, axes: Any, slots: Sequence[int]) -> Any:
    """Write ``src`` rows (batch dim == len(slots)) into ``dst`` at ``slots``.

    Runs eagerly; on a multi-device cache the scatter may gather/reshard —
    the handoff path sends per-request rows already placed on the
    destination mesh, so this stays local in the common case.
    """
    idx = jnp.asarray(slots, jnp.int32)

    def put(d, s, a):
        return d.at[(slice(None),) * a + (idx,)].set(s)

    return jax.tree.map(put, dst, src, axes)


def install_cross_memory(cache: Any, mem, slots: Sequence[int]) -> Any:
    """Write per-request encdec cross-attention memory into batcher slots.

    ``mem`` = (cross_k (L, B, S_src, Hkv, Dh), cross_v, src_len (B,)) with
    B == len(slots) — the return shape of ``Model.encode_cross_rows``.
    Used by the token-at-a-time prompt path: the chunked path gets its
    cross memory from ``prefill_ranged``'s cache instead.
    """
    ck, cv, src_len = mem
    dec = cache["dec_layers"]
    idx = jnp.asarray(slots, jnp.int32)
    out = dict(cache)
    out["dec_layers"] = dec._replace(
        cross_k=dec.cross_k.at[:, idx].set(ck.astype(dec.cross_k.dtype)),
        cross_v=dec.cross_v.at[:, idx].set(cv.astype(dec.cross_v.dtype)),
        src_len=dec.src_len.at[:, idx].set(src_len[None, :]),
    )
    return out


# --------------------------------------------------------------------------
# paged KV: canonical page layout + block-table indirection
# --------------------------------------------------------------------------
# A *page* is ``page_size`` consecutive positions of ONE request's KV across
# every positional cache leaf (all layers at once).  The canonical page
# layout moves each KVSlice leaf's (batch, seq) axes to the front —
# ``(num_pages, page_size, *rest)`` — so one integer page id addresses the
# same positions in every leaf, whatever that leaf's stacking depth is
# (layer-stacked dense caches, group-stacked hybrid shared KV, ...).  The
# block table maps ``(slot, logical_page) -> physical_page``; entries >=
# ``num_pages`` are UNMAPPED sentinels: gathers fill (k/v = 0, slot_pos =
# -1, i.e. position-masked) and scatters drop, so an unmapped page is
# indistinguishable from an empty one and a write to it is a no-op.


def _is_kv(x) -> bool:
    return isinstance(x, (KVSlice, PagedKVCache))


def kv_cache_nodes(cache: Any) -> list:
    """The cache's KVSlice nodes in pytree flatten order."""
    return [n for n in jax.tree.leaves(cache, is_leaf=_is_kv) if _is_kv(n)]


def strip_kv_nodes(cache: Any) -> Any:
    """The cache with every KVSlice subtree pruned (replaced by None) —
    the *resident* part that stays dense per-slot (encdec cross memory;
    nothing at all for dense/moe)."""
    return jax.tree.map(lambda n: None if _is_kv(n) else n, cache,
                        is_leaf=_is_kv)


def rebuild_kv_nodes(template: Any, resident: Any, nodes: list) -> Any:
    """Inverse of ``strip_kv_nodes``: splice ``nodes`` (flatten order)
    back into ``resident`` using the spec ``template`` for structure."""
    it = iter(nodes)
    return jax.tree.map(
        lambda t, r: next(it) if _is_kv(t) else r, template, resident,
        is_leaf=_is_kv,
    )


def kv_node_axes(model, batch: int, max_len: int) -> list:
    """Per-KVSlice-node batch-axis index (seq is always batch+1)."""
    return [n.k.logical.index("batch")
            for n in kv_cache_nodes(model.cache_specs(batch, max_len))]


def kv_position_bytes(model, max_len: int) -> int:
    """Bytes of KV cache held per token position (all layers, one slot) —
    the unit behind the ``kv_bytes_saved`` accounting."""
    total = 0
    for node in kv_cache_nodes(model.cache_specs(1, max_len)):
        for spec in (node.k, node.v, node.slot_pos):
            n = 1
            for d in spec.shape:
                n *= d
            itemsize = jnp.dtype(spec.dtype or model.cfg.dtype).itemsize
            total += n * itemsize // max_len
    return total


def recurrent_state_bytes(model, max_len: int) -> int:
    """Bytes of one slot's NON-positional cache state (everything that is
    not a KVSlice: mamba conv + ssm tensors, hybrid group states) — the
    size of one recurrent-state snapshot, and the unit behind the
    ``snapshot_bytes_saved`` accounting."""
    total = 0
    for spec in jax.tree.leaves(strip_kv_nodes(model.cache_specs(1, max_len))):
        n = 1
        for d in spec.shape:
            n *= d
        total += n * jnp.dtype(spec.dtype or model.cfg.dtype).itemsize
    return total


def clear_kv_row(cache: Any, axes: list, row: int) -> Any:
    """Invalidate every KV position of one slot row (``slot_pos`` -> -1)
    so a snapshot restore into a recycled slot can never leave stale
    attendable positions behind the restored prefix."""
    nodes = kv_cache_nodes(cache)
    resident = strip_kv_nodes(cache)
    out_nodes = []
    for node, a in zip(nodes, axes):
        sp = _to_canonical(node.slot_pos, a)
        sp = sp.at[row].set(-1)
        out_nodes.append(node._replace(slot_pos=_from_canonical(sp, a)))
    return rebuild_kv_nodes(cache, resident, out_nodes)


def _to_canonical(leaf: jnp.ndarray, axis: int) -> jnp.ndarray:
    return jnp.moveaxis(leaf, (axis, axis + 1), (0, 1))


def _from_canonical(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    return jnp.moveaxis(x, (0, 1), (axis, axis + 1))


def page_arena(model, num_pages: int, page_size: int) -> list:
    """Physical page arena: one canonical ``(num_pages, page_size, *rest)``
    KVSlice per positional cache node.  Built from ``init_cache`` so k/v
    start zeroed and ``slot_pos`` starts -1 (every page empty)."""
    full = model.init_cache(num_pages, page_size)
    axes = kv_node_axes(model, num_pages, page_size)
    return [
        KVSlice(k=_to_canonical(n.k, a), v=_to_canonical(n.v, a),
                slot_pos=_to_canonical(n.slot_pos, a))
        for n, a in zip(kv_cache_nodes(full), axes)
    ]


def gather_pages(arena: list, axes: list, block_table: jnp.ndarray,
                 page_size: int) -> list:
    """Materialize dense per-slot KV nodes from the arena through the
    block table (jit-traceable; THE indirection in front of the existing
    decode kernels).  ``block_table``: (B, n_logical) int32, entries >=
    num_pages gather as empty (k/v 0, slot_pos -1)."""
    B, n_log = block_table.shape
    out = []
    for node, a in zip(arena, axes):
        def g(x, fill):
            y = jnp.take(x, block_table, axis=0, mode="fill",
                         fill_value=fill)                 # (B, n_log, P, *rest)
            y = y.reshape((B, n_log * page_size) + x.shape[2:])
            return _from_canonical(y, a)
        out.append(KVSlice(k=g(node.k, 0), v=g(node.v, 0),
                           slot_pos=g(node.slot_pos, -1)))
    return out


def scatter_current_pages(arena: list, nodes: list, axes: list,
                          block_table: jnp.ndarray, pos: jnp.ndarray,
                          page_size: int) -> list:
    """Write each slot's CURRENT page (the one holding position ``pos``)
    from dense nodes back into the arena (jit-traceable).  Only the
    current page can have changed during a decode step, and by the
    copy-on-write invariant it is always a private page — shared
    (interned) pages are never written.  Unmapped entries drop."""
    B = pos.shape[0]
    pg = pos // page_size                                  # (B,)
    phys = jnp.take_along_axis(block_table, pg[:, None], axis=1)[:, 0]
    out = []
    for arena_node, node, a in zip(arena, nodes, axes):
        def s(dst, leaf):
            c = _to_canonical(leaf, a)                     # (B, S, *rest)
            c = c.reshape((B, c.shape[1] // page_size, page_size) + c.shape[2:])
            cur = c[jnp.arange(B), pg]                     # (B, P, *rest)
            return dst.at[phys].set(cur, mode="drop")
        out.append(KVSlice(k=s(arena_node.k, node.k),
                           v=s(arena_node.v, node.v),
                           slot_pos=s(arena_node.slot_pos, node.slot_pos)))
    return out


def extract_row_pages(cache: Any, axes: list, row: int, start_page: int,
                      n_pages: int, page_size: int) -> list:
    """Slice ``n_pages`` canonical page stacks (one (n_pages, P, *rest)
    array per k/v/slot_pos of each KV node) out of one row of a dense
    cache — the page-granular payload of the prefill -> decode handoff."""
    out = []
    lo, hi = start_page * page_size, (start_page + n_pages) * page_size
    for node, a in zip(kv_cache_nodes(cache), axes):
        def e(leaf):
            x = _to_canonical(leaf, a)[row, lo:hi]
            return x.reshape((n_pages, page_size) + x.shape[1:])
        out.append(KVSlice(k=e(node.k), v=e(node.v), slot_pos=e(node.slot_pos)))
    return out


def write_arena_pages(arena: list, page_ids, stacks: list) -> list:
    """Write canonical page stacks into the arena at ``page_ids``."""
    idx = jnp.asarray(page_ids, jnp.int32)
    return [
        KVSlice(k=a.k.at[idx].set(s.k.astype(a.k.dtype)),
                v=a.v.at[idx].set(s.v.astype(a.v.dtype)),
                slot_pos=a.slot_pos.at[idx].set(s.slot_pos))
        for a, s in zip(arena, stacks)
    ]


def read_arena_pages(arena: list, page_ids) -> list:
    """Canonical page stacks for ``page_ids`` (inverse of write)."""
    idx = jnp.asarray(page_ids, jnp.int32)
    return [KVSlice(k=a.k[idx], v=a.v[idx], slot_pos=a.slot_pos[idx])
            for a in arena]


def clean_arena_pages(arena: list, page_ids) -> list:
    """Mark every position of the given pages empty (``slot_pos`` -1) so
    a recycled page's stale contents can never be attended."""
    idx = jnp.asarray(page_ids, jnp.int32)
    return [a._replace(slot_pos=a.slot_pos.at[idx].set(-1)) for a in arena]


# --------------------------------------------------------------------------
# native paged views: the arena itself flows through Model.decode
# --------------------------------------------------------------------------


def paged_view(template: Any, resident: Any, arena: list,
               block_table: jnp.ndarray, scales=None) -> Any:
    """Build the cache pytree that carries the arena THROUGH the model.

    Each positional KV node becomes a :class:`PagedKVCache` wrapping the
    whole arena node plus the batch's block table (``layer`` starts 0; the
    layer scan rebinds it per step — see ``Model._scan_stack``).  The
    resident tree contributes everything that stays dense per-slot (encdec
    cross memory).  ``scales``: per-node ``(k_scale, v_scale)`` list for
    int8 arenas, or None.
    """
    nodes = []
    for i, a in enumerate(arena):
        ks, vs = (scales[i] if scales is not None else (None, None))
        nodes.append(PagedKVCache(
            k=a.k, v=a.v, slot_pos=a.slot_pos, block_table=block_table,
            layer=jnp.zeros((), jnp.int32), k_scale=ks, v_scale=vs,
        ))
    return rebuild_kv_nodes(template, resident, nodes)


def extract_paged(cache: Any):
    """Inverse of :func:`paged_view`: (arena nodes, scales, resident)."""
    nodes = kv_cache_nodes(cache)
    arena = [KVSlice(k=n.k, v=n.v, slot_pos=n.slot_pos) for n in nodes]
    scales = [(n.k_scale, n.v_scale) for n in nodes]
    if all(k is None for k, _ in scales):
        scales = None
    return arena, scales, strip_kv_nodes(cache)


# --------------------------------------------------------------------------
# int8 KV pages: per-page symmetric quantization
# --------------------------------------------------------------------------


def _bshape(ndim: int, keep_axes, scale_shape) -> tuple:
    shape = [1] * ndim
    for a, s in zip(keep_axes, scale_shape):
        shape[a] = s
    return tuple(shape)


def quantize_page(x: jnp.ndarray, *, keep_axes=(0,)):
    """Symmetric int8 quantization with one scale per kept-axes index.

    ``keep_axes`` (sorted ascending) name the axes that keep their own
    scale — e.g. ``(0, 2)`` on a canonical ``(n_pages, P, L, Hkv, Dh)``
    page stack gives one scale per (page, layer).  Returns
    ``(q int8, scale f32)`` with ``scale.shape == tuple(x.shape[a] for a
    in keep_axes)``.  All-zero groups get scale 0 (dequantizes to 0).
    """
    x32 = x.astype(jnp.float32)
    red = tuple(a for a in range(x.ndim) if a not in keep_axes)
    amax = jnp.max(jnp.abs(x32), axis=red)
    scale = amax / 127.0
    b = scale.reshape(_bshape(x.ndim, keep_axes, scale.shape))
    q = jnp.clip(jnp.round(x32 / jnp.maximum(b, 1e-8)), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_page(q: jnp.ndarray, scale: jnp.ndarray, *, keep_axes=(0,)):
    """Inverse of :func:`quantize_page` (f32 output)."""
    b = scale.reshape(_bshape(q.ndim, keep_axes, scale.shape))
    return q.astype(jnp.float32) * b


def load_pages_into_row(cache: Any, template: Any, axes: list, row: int,
                        stacks: list, start_page: int, page_size: int) -> Any:
    """Write canonical page stacks into one row of a dense cache at
    logical pages ``start_page..`` — how a shared prefix becomes the
    resident context of an extend-prefill scratch row."""
    nodes = kv_cache_nodes(cache)
    resident = strip_kv_nodes(cache)
    out_nodes = []
    for node, stack, a in zip(nodes, stacks, axes):
        n_pages = stack.k.shape[0]
        lo = start_page * page_size

        def w(leaf, s):
            x = _to_canonical(leaf, a)
            flat = s.reshape((n_pages * page_size,) + s.shape[2:])
            x = x.at[row, lo:lo + n_pages * page_size].set(
                flat.astype(leaf.dtype))
            return _from_canonical(x, a)

        out_nodes.append(KVSlice(k=w(node.k, stack.k), v=w(node.v, stack.v),
                                 slot_pos=w(node.slot_pos, stack.slot_pos)))
    return rebuild_kv_nodes(template, resident, out_nodes)


def mask_pad_slots(cache: Any, length: jnp.ndarray) -> Any:
    """Invalidate cache slots beyond each row's true prompt length.

    Chunked prefill pads prompts to a bucket length, so positions
    ``length[b] .. S_pad-1`` hold garbage K/V.  Marking their ``slot_pos``
    as -1 makes the decode attention mask them out (``valid &= pos >= 0``)
    until the decode loop overwrites them with real tokens.
    """
    def fix(node):
        if isinstance(node, KVSlice):
            s_c = node.slot_pos.shape[-1]
            valid = jnp.arange(s_c, dtype=jnp.int32) < length[:, None]
            return node._replace(
                slot_pos=jnp.where(valid, node.slot_pos, jnp.int32(-1))
            )
        return node

    return jax.tree.map(fix, cache, is_leaf=lambda x: isinstance(x, KVSlice))
