"""Zamba2 hybrid: Mamba-2 backbone + a weight-shared attention block.

Every ``cfg.hybrid_attn_every`` SSM layers, one shared transformer block
(attention + MLP) is applied to ``concat(x, x0)`` (x0 = the embedding-layer
output — the Zamba concat trick), projected back to d_model and added to the
residual stream.  The shared block's weights are reused by every invocation;
each invocation keeps its own KV cache.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (
    KVSlice,
    attention_block,
    attn_specs,
    mlp_block,
    mlp_specs,
    norm_spec,
    rms_norm,
)
from repro.models.mamba2 import MambaState, mamba_block, mamba_specs
from repro.models.param import PSpec


def shared_block_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    return {
        "proj_in": PSpec((2 * d, d), ("embed", None), ("normal", 0)),
        "attn_norm": norm_spec(d),
        "attn": attn_specs(cfg),
        "mlp_norm": norm_spec(d),
        "mlp": mlp_specs(cfg),
        "proj_out": PSpec((d, d), (None, "embed"), ("normal", 0)),
    }


def mamba_layer_specs(cfg: ArchConfig) -> dict:
    return {"norm": norm_spec(cfg.d_model), "mamba": mamba_specs(cfg)}


class ZambaGroupCache(NamedTuple):
    mamba: MambaState          # stacked over the group's SSM layers
    shared: KVSlice            # this invocation's KV cache


def shared_block(
    sp, x, x0, cfg: ArchConfig, ctx=None, *, mode: str,
    cache: Optional[KVSlice] = None, pos=None,
) -> Tuple[jnp.ndarray, Optional[KVSlice]]:
    h = jnp.concatenate([x, x0], axis=-1) @ sp["proj_in"]
    h1 = rms_norm(h, sp["attn_norm"], cfg.rms_eps)
    a, new_cache = attention_block(sp["attn"], h1, cfg, ctx, mode=mode, cache=cache, pos=pos)
    h = h + a
    h2 = rms_norm(h, sp["mlp_norm"], cfg.rms_eps)
    h = h + mlp_block(sp["mlp"], h2, cfg)
    return x + h @ sp["proj_out"], new_cache


def mamba_layer(
    lp, x, cfg: ArchConfig, *, mode: str,
    state: Optional[MambaState] = None,
    mask: Optional[jnp.ndarray] = None,
    ckpt_every: Optional[int] = None,
) -> Tuple[jnp.ndarray, Optional[MambaState], jnp.ndarray]:
    h = rms_norm(x, lp["norm"], cfg.rms_eps)
    y, new_state = mamba_block(lp["mamba"], h, cfg, mode=mode, state=state,
                               mask=mask, ckpt_every=ckpt_every)
    return x + y, new_state, jnp.float32(0.0)
