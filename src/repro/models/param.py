"""Parameter specification trees.

A model is described by a pytree of :class:`PSpec` leaves.  From that one
tree we derive (a) real initialized parameters, (b) abstract
``ShapeDtypeStruct`` stand-ins for dry-run lowering, and (c) logical-axis
trees that the sharding rules resolve into ``PartitionSpec``s.  This keeps
shape, init, and sharding in one place per parameter.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PSpec:
    """One parameter: shape + logical axes + initializer."""

    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: Tuple[Any, ...] = ("normal", -2)  # ("normal", fan_in_axis) | ("const", v) | ("alog",) | ("dt_bias",)
    dtype: Optional[str] = None          # None -> model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def tree_map_pspec(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_pspec)


def _resolve_dtype(spec: PSpec, default_dtype: str):
    return jnp.dtype(spec.dtype or default_dtype)


def abstract_params(spec_tree, default_dtype: str):
    """ShapeDtypeStruct tree for dry-run lowering (no allocation)."""
    return tree_map_pspec(
        lambda s: jax.ShapeDtypeStruct(s.shape, _resolve_dtype(s, default_dtype)),
        spec_tree,
    )


def logical_axes(spec_tree):
    """Tree of logical-axis tuples (resolved by sharding rules)."""
    return tree_map_pspec(lambda s: s.logical, spec_tree)


def _init_leaf(spec: PSpec, key, default_dtype: str):
    dtype = _resolve_dtype(spec, default_dtype)
    kind = spec.init[0]
    if kind == "normal":
        fan_axis = spec.init[1]
        fan_in = spec.shape[fan_axis]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)
    if kind == "const":
        return jnp.full(spec.shape, spec.init[1], dtype)
    if kind == "alog":
        # Mamba A_log: A ~ Uniform[1, 16), stored as log
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(jnp.float32)  # keep fp32 for stability
    if kind == "dt_bias":
        # Mamba dt bias: softplus^-1 of dt ~ LogUniform[1e-3, 1e-1]
        dt = jnp.exp(
            jax.random.uniform(key, spec.shape, jnp.float32)
            * (math.log(1e-1) - math.log(1e-3))
            + math.log(1e-3)
        )
        inv = dt + jnp.log(-jnp.expm1(-dt))
        return inv.astype(jnp.float32)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(spec_tree, key, default_dtype: str):
    """Materialize real parameters (used by tests/examples, not dry-run)."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_pspec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(s, k, default_dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def count_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_pspec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))
