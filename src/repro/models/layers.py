"""Shared model layers: norms, RoPE, attention, MLPs, embeddings.

All attention paths are memory-bounded by construction: the baseline is a
chunked flash-style attention written in pure jnp (XLA-visible FLOPs so the
roofline terms from ``cost_analysis`` are exact).  The Pallas kernel path
(``cfg.use_flash_kernel``) swaps in ``repro.kernels``.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.param import PSpec  # noqa: F401  (re-exported for layer specs)

F32 = jnp.float32
NEG_INF = -1e30


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rms_norm(x, w, eps: float):
    x32 = x.astype(F32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * w.astype(F32)).astype(x.dtype)


def norm_spec(d: int) -> PSpec:
    return PSpec((d,), (None,), ("const", 1.0))


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=F32) / half)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                      # (Dh/2,)
    angles = positions.astype(F32)[..., None] * freqs        # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# chunked flash-style attention (pure jnp baseline)
# --------------------------------------------------------------------------
def _block_mask(q_pos, k_pos, causal: bool, window: Optional[int], kv_len=None):
    """(qc, kc) bool mask of VALID entries from absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        m &= k_pos[None, :] < kv_len
    return m


def chunked_attention(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    unroll: bool = False,
    kv_len: Optional[jnp.ndarray] = None,
):
    """Flash-algorithm attention in jnp (running max/sum over KV chunks).

    q: (B, Sq, Hq, Dh);  k, v: (B, Skv, Hkv, Dh);  GQA via head grouping.
    kv_len: optional (B,) per-row valid KV count — keys at positions
    >= kv_len[b] are masked out (ragged/padded memory, e.g. encdec source
    features batched to a common length).  A fully-masked q row degrades
    to a uniform average over the masked values (never NaN); callers must
    not read such rows.
    Returns (B, Sq, Hq, Dh).
    """
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(Dh)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0

    qg = q.reshape(B, nq, q_chunk, Hkv, G, Dh)
    kg = k.reshape(B, nk, kv_chunk, Hkv, Dh)
    vg = v.reshape(B, nk, kv_chunk, Hkv, Dh)

    def q_body(_, qi):
        qblk, qidx = qi                                       # (B,qc,Hkv,G,Dh)
        q_pos = q_offset + qidx * q_chunk + jnp.arange(q_chunk)

        def kv_body(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki
            k_pos = kidx * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qblk.astype(F32), kblk.astype(F32)
            ) * scale                                         # (B,Hkv,G,qc,kc)
            mask = _block_mask(q_pos, k_pos, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            if kv_len is not None:
                row_ok = k_pos[None, :] < kv_len[:, None]     # (B, kc)
                s = jnp.where(row_ok[:, None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vblk.astype(F32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, F32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), F32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, Dh), F32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0),
            (kg.swapaxes(0, 1), vg.swapaxes(0, 1), jnp.arange(nk)),
            unroll=nk if unroll else 1,
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,Hkv,G,qc,Dh)
        return None, out.transpose(0, 3, 1, 2, 4)             # (B,qc,Hkv,G,Dh)

    _, outs = jax.lax.scan(q_body, None, (qg.swapaxes(0, 1), jnp.arange(nq)),
                           unroll=nq if unroll else 1)
    out = outs.swapaxes(0, 1).reshape(B, Sq, Hq, Dh)
    return out.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, kv_len, *, window: Optional[int] = None,
                         slot_pos: Optional[jnp.ndarray] = None):
    """Single-position attention against a (possibly rolling) KV cache.

    q: (B, 1, Hq, Dh);  k/v_cache: (B, S, Hkv, Dh);  kv_len: (B,) valid count.
    slot_pos: (B, S) absolute position stored in each slot (rolling SWA
    buffers), or None meaning slot i holds position i.
    Returns (B, 1, Hq, Dh).
    """
    B, S, Hkv, Dh = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(F32), k_cache.astype(F32)) * scale
    if slot_pos is None:
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    else:
        pos = slot_pos
    valid = pos < kv_len[:, None]
    if window is not None:
        valid &= pos > (kv_len[:, None] - 1 - window)
    valid &= pos >= 0
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(F32))
    return out.reshape(B, 1, Hq, Dh).astype(q.dtype)


def extend_attention_ref(q, k_cache, v_cache, slot_pos, q_pos, *,
                         window: Optional[int] = None):
    """Multi-position attention against an absolute-position KV cache.

    The S>1 generalization of :func:`decode_attention_ref`, used by the
    suffix-extend prefill path (paged prefix sharing): ``q`` holds a
    request's suffix positions, the cache already holds its shared prefix
    (plus the just-written suffix K/V).  Masking is purely ``slot_pos``
    driven — a slot is attended iff it holds a valid position <= the
    query's absolute position — so gathered pool pages and freshly
    written slots need no separate treatment.

    q: (B, S, Hq, Dh);  k/v_cache: (B, S_c, Hkv, Dh);
    slot_pos: (B, S_c) absolute position per slot (-1 = empty);
    q_pos: (B, S) absolute position per query row.
    Returns (B, S, Hq, Dh).
    """
    B, S, Hq, Dh = q.shape
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, S, Hkv, G, Dh)
    s = jnp.einsum("bshgd,bkhd->bshgk", qg.astype(F32),
                   k_cache.astype(F32)) * scale        # (B,S,Hkv,G,S_c)
    valid = (slot_pos[:, None, :] >= 0) & \
        (slot_pos[:, None, :] <= q_pos[:, :, None])    # (B,S,S_c)
    if window is not None:
        valid &= slot_pos[:, None, :] > q_pos[:, :, None] - window
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bshgk,bkhd->bshgd", p, v_cache.astype(F32))
    return out.reshape(B, S, Hq, Dh).astype(q.dtype)


# --------------------------------------------------------------------------
# attention block (QKV proj + rope + attn + out proj)
# --------------------------------------------------------------------------
def attn_specs(cfg: ArchConfig, d_in: Optional[int] = None) -> dict:
    d = d_in or cfg.d_model
    dh = cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    specs = {
        "wq": PSpec((d, hq, dh), ("embed", "heads", None), ("normal", 0)),
        "wk": PSpec((d, hkv, dh), ("embed", "kv_heads", None), ("normal", 0)),
        "wv": PSpec((d, hkv, dh), ("embed", "kv_heads", None), ("normal", 0)),
        "wo": PSpec((hq, dh, cfg.d_model), ("heads", None, "embed"), ("normal", 0)),
    }
    if cfg.qkv_bias:
        specs["bq"] = PSpec((hq, dh), ("heads", None), ("const", 0.0))
        specs["bk"] = PSpec((hkv, dh), ("kv_heads", None), ("const", 0.0))
        specs["bv"] = PSpec((hkv, dh), ("kv_heads", None), ("const", 0.0))
    if cfg.qk_norm:
        specs["q_norm"] = norm_spec(dh)
        specs["k_norm"] = norm_spec(dh)
    return specs


class KVSlice(NamedTuple):
    """Per-layer KV cache slice carried through the layer scan."""
    k: jnp.ndarray          # (B, S_cache, Hkv, Dh)
    v: jnp.ndarray
    # absolute position stored in each slot; -1 = empty (for SWA rolling)
    slot_pos: jnp.ndarray   # (B, S_cache) int32


class PagedKVCache(NamedTuple):
    """Paged KV view: the whole physical page arena + one batch's block table.

    The native-paged calling convention (see ``serve/kvpool.py``): instead
    of gathering pool pages into a dense per-slot cache, the serving layer
    hands attention the arena itself plus a ``(B, n_log)`` block table.
    Attention writes the current token(s) straight into their physical
    pages (`.at[...].set(mode="drop")` — sentinel entries ``>= N`` drop the
    write) and reads by walking the block-table row, so no contiguous KV
    copy is ever materialized.  ``layer`` selects the arena layer slice this
    view reads/writes; the layer scan rebinds it per step so one arena
    rides the scan carry (see ``Model._scan_stack``).

    Precondition: absolute-position layout only — slot ``i`` of logical
    page ``j`` holds position ``j*P + i``.  The KVPool gate guarantees it
    (``sliding_window`` is None or >= max_len), so window masking never
    binds and the paged kernels ignore it.

    k/v: (N, P, L, Hkv, Dh) arena (float, or int8 with per-page scales);
    slot_pos: (N, P, L) int32 absolute position per slot (-1 = empty);
    block_table: (B, n_log) int32 physical page per logical page;
    layer: () int32 arena layer of this view;
    k_scale/v_scale: (N, L) f32 per-(page, layer) scales for int8 arenas.
    """
    k: jnp.ndarray
    v: jnp.ndarray
    slot_pos: jnp.ndarray
    block_table: jnp.ndarray
    layer: jnp.ndarray
    k_scale: Optional[jnp.ndarray] = None
    v_scale: Optional[jnp.ndarray] = None


def paged_gather(cache: "PagedKVCache"):
    """Walk a block table in pure jnp: (B, n_log*P) dense K/V/slot_pos view.

    The interpret-mode half of the paged attention contract — identical
    masking semantics to the Pallas kernels (sentinel pages contribute
    slot_pos -1, i.e. masked zeros), and bit-identical inputs to the dense
    refs, so CPU serving keeps token-identical output vs the dense path.
    Int8 arenas are dequantized with their per-page scales on gather.
    """
    N, P = cache.k.shape[0], cache.k.shape[1]
    layer, bt = cache.layer, cache.block_table
    B, n_log = bt.shape
    btc = jnp.minimum(bt, N - 1)                      # clamp sentinels
    k_l = jnp.take(cache.k, layer, axis=2)            # (N, P, Hkv, Dh)
    v_l = jnp.take(cache.v, layer, axis=2)
    sp_l = jnp.take(cache.slot_pos, layer, axis=2)    # (N, P)
    k_pg = k_l[btc]                                   # (B, n_log, P, Hkv, Dh)
    v_pg = v_l[btc]
    if cache.k_scale is not None:
        ks = jnp.take(cache.k_scale, layer, axis=1)[btc]   # (B, n_log)
        vs = jnp.take(cache.v_scale, layer, axis=1)[btc]
        k_pg = k_pg.astype(F32) * ks[..., None, None, None]
        v_pg = v_pg.astype(F32) * vs[..., None, None, None]
    sp = jnp.where((bt < N)[:, :, None], sp_l[btc], -1)
    return (k_pg.reshape(B, n_log * P, *k_pg.shape[3:]),
            v_pg.reshape(B, n_log * P, *v_pg.shape[3:]),
            sp.reshape(B, n_log * P))


def _quantize_to(arena_dtype, vals, scale):
    """Quantize (..., Hkv, Dh) floats with broadcast (...,) scales."""
    q = jnp.round(vals.astype(F32) / jnp.maximum(scale, 1e-8)[..., None, None])
    return jnp.clip(q, -127, 127).astype(arena_dtype)


def _paged_write_decode(cache: "PagedKVCache", k, v, pos):
    """Write one token per row into its physical page; returns new cache.

    k/v: (B, Hkv, Dh) values for position ``pos`` (B,).  Sentinel/unmapped
    target pages drop the write.  Int8 arenas lazily initialize the
    per-page scale on first touch (scale 0 = untouched page).
    """
    N, P = cache.k.shape[0], cache.k.shape[1]
    layer, bt = cache.layer, cache.block_table
    phys = jnp.take_along_axis(bt, (pos // P)[:, None], axis=1)[:, 0]  # (B,)
    off = pos % P
    ks, vs = cache.k_scale, cache.v_scale
    if ks is not None:
        physc = jnp.minimum(phys, N - 1)
        amax_k = jnp.max(jnp.abs(k.astype(F32)), axis=(1, 2))          # (B,)
        amax_v = jnp.max(jnp.abs(v.astype(F32)), axis=(1, 2))
        sck = jnp.where(ks[physc, layer] > 0, ks[physc, layer], amax_k / 127.0)
        scv = jnp.where(vs[physc, layer] > 0, vs[physc, layer], amax_v / 127.0)
        ks = ks.at[phys, layer].set(sck, mode="drop")
        vs = vs.at[phys, layer].set(scv, mode="drop")
        k = _quantize_to(cache.k.dtype, k, sck)
        v = _quantize_to(cache.v.dtype, v, scv)
    k_a = cache.k.at[phys, off, layer].set(k, mode="drop")
    v_a = cache.v.at[phys, off, layer].set(v, mode="drop")
    sp_a = cache.slot_pos.at[phys, off, layer].set(pos, mode="drop")
    return cache._replace(k=k_a, v=v_a, slot_pos=sp_a, k_scale=ks, v_scale=vs)


def _paged_write_extend(cache: "PagedKVCache", k, v, positions):
    """Write S suffix tokens per row into their physical pages.

    k/v: (B, S, Hkv, Dh); positions: (B, S) absolute.  Positions whose
    logical page is beyond the block-table width or unmapped drop the
    write.  Int8 scales use a scatter-max per target page.
    """
    N, P = cache.k.shape[0], cache.k.shape[1]
    layer, bt = cache.layer, cache.block_table
    n_log = bt.shape[1]
    lp = positions // P
    phys = jnp.where(
        lp < n_log,
        jnp.take_along_axis(bt, jnp.minimum(lp, n_log - 1), axis=1),
        N,
    )                                                             # (B, S)
    off = positions % P
    ks, vs = cache.k_scale, cache.v_scale
    if ks is not None:
        physc = jnp.minimum(phys, N - 1)
        amax_k = jnp.max(jnp.abs(k.astype(F32)), axis=(2, 3))     # (B, S)
        amax_v = jnp.max(jnp.abs(v.astype(F32)), axis=(2, 3))
        ks = ks.at[phys, layer].max(amax_k / 127.0, mode="drop")
        vs = vs.at[phys, layer].max(amax_v / 127.0, mode="drop")
        k = _quantize_to(cache.k.dtype, k, ks[physc, layer])
        v = _quantize_to(cache.v.dtype, v, vs[physc, layer])
    k_a = cache.k.at[phys, off, layer].set(k, mode="drop")
    v_a = cache.v.at[phys, off, layer].set(v, mode="drop")
    sp_a = cache.slot_pos.at[phys, off, layer].set(positions, mode="drop")
    return cache._replace(k=k_a, v=v_a, slot_pos=sp_a, k_scale=ks, v_scale=vs)


def qkv_project(p, x, cfg: ArchConfig, positions):
    """x: (B,S,D) -> q (B,S,Hq,Dh), k,v (B,S,Hkv,Dh), roped."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(
    p, x, cfg: ArchConfig, ctx=None, *,
    mode: str,                       # train | prefill | decode
    cache: Optional[KVSlice] = None,
    pos: Optional[jnp.ndarray] = None,   # (B,) next position (decode) or 0-base
    causal: bool = True,
    kv_len: Optional[jnp.ndarray] = None,  # (B,) ragged-memory mask (non-causal)
) -> Tuple[jnp.ndarray, Optional[KVSlice]]:
    """Full attention sublayer.  Returns (out (B,S,D), updated cache)."""
    B, S, _ = x.shape
    window = cfg.sliding_window

    # Attention parallelism: shard heads over the model axis when the head
    # count divides it (Megatron TP).  Otherwise (56/40-head archs on a
    # 16-wide axis) fall back to context parallelism: q/out sharded along
    # the sequence, KV replicated — each shard computes its q rows against
    # the full KV.  Without either, GSPMD replicates heads AND seq and the
    # score matrices blow past HBM.
    msz = ctx.model_size() if ctx is not None else 1
    heads_div = msz <= 1 or (cfg.num_heads % msz == 0)

    def head_shard(t):
        if ctx is None:
            return t
        return jax.lax.with_sharding_constraint(
            t, ctx.sharding(("batch", None, "heads", None), t.shape)
        )

    def seq_shard(t):
        if ctx is None:
            return t
        return jax.lax.with_sharding_constraint(
            t, ctx.sharding(("batch", "act_seq", None, None), t.shape)
        )

    if mode in ("train", "prefill"):
        positions = jnp.arange(S)[None, :]
        if heads_div and ctx is not None:
            # Megatron-SP all-gather placement: restore full-seq *before*
            # the QKV projection so its output lands head-sharded directly.
            # Resharding seq->heads after the fact makes GSPMD fall back to
            # "involuntary full rematerialization" (replicate + repartition).
            x = jax.lax.with_sharding_constraint(
                x, ctx.sharding(("batch", None, None), x.shape)
            )
        q, k, v = qkv_project(p, x, cfg, positions)
        G = cfg.num_heads // max(cfg.num_kv_heads, 1)
        if G > 1:
            # expand KV to full heads so the head dim (divisible by the
            # model axis) shards; the expansion is local under head sharding
            ke = jnp.repeat(k, G, axis=2)
            ve = jnp.repeat(v, G, axis=2)
        else:
            ke, ve = k, v
        if heads_div:
            q, ke, ve = head_shard(q), head_shard(ke), head_shard(ve)
            out = chunked_attention(
                q, ke, ve, causal=causal, window=window,
                q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
                unroll=cfg.unroll_attn, kv_len=kv_len,
            )
            out = head_shard(out)
        else:
            q, ke, ve = seq_shard(q), ke, ve
            # single q chunk: q stays sequence-sharded through the whole
            # attention (no per-chunk dynamic-slice resharding)
            out = chunked_attention(
                q, ke, ve, causal=causal, window=window, q_chunk=S,
                kv_chunk=cfg.attn_kv_chunk, unroll=cfg.unroll_attn,
                kv_len=kv_len,
            )
            out = seq_shard(out)
        new_cache = None
        if mode == "prefill":
            assert cache is not None
            S_c = cache.k.shape[1]
            if S_c >= S:
                kpad = jnp.zeros((B, S_c - S) + k.shape[2:], k.dtype)
                new_cache = KVSlice(
                    k=jnp.concatenate([k, kpad], axis=1),
                    v=jnp.concatenate([v, kpad], axis=1),
                    slot_pos=jnp.where(
                        jnp.arange(S_c)[None] < S,
                        jnp.arange(S_c)[None],
                        -1,
                    ) * jnp.ones((B, 1), jnp.int32),
                )
            else:
                # rolling (SWA) cache: keep the last S_c positions
                new_cache = KVSlice(
                    k=k[:, -S_c:], v=v[:, -S_c:],
                    slot_pos=(jnp.arange(S - S_c, S)[None]
                              * jnp.ones((B, 1), jnp.int32)),
                )
    elif mode == "extend":
        # Suffix continuation for paged prefix sharing: S new positions
        # appended at per-row offsets ``pos`` behind a prefix already
        # resident in the cache.  Requires an absolute-position cache
        # layout (no rolling SWA buffer — the KVPool gate guarantees it:
        # window is None or >= the cache length, so slot i holds
        # position i).  Writes beyond a row's true suffix are later
        # overwritten by decode before its position becomes attendable,
        # so no extra validity mask is needed (see serve/kvpool.py).
        assert cache is not None and pos is not None
        positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        q, k, v = qkv_project(p, x, cfg, positions)
        if isinstance(cache, PagedKVCache):
            # Native paged suffix extension: write straight into the
            # arena's physical pages, attend via the block table.
            new_cache = _paged_write_extend(cache, k, v, positions)
            if jax.default_backend() == "tpu":
                from repro.kernels.flash_attention.ops import (
                    paged_extend_attention,
                )
                out = paged_extend_attention(
                    q, new_cache.k, new_cache.v, new_cache.slot_pos,
                    new_cache.block_table, pos, new_cache.layer,
                    k_scale=new_cache.k_scale, v_scale=new_cache.v_scale,
                )
            else:
                k_d, v_d, sp_d = paged_gather(new_cache)
                out = extend_attention_ref(q, k_d, v_d, sp_d, positions,
                                           window=window)
        else:
            bidx = jnp.arange(B)[:, None]
            k_c = cache.k.at[bidx, positions].set(k, mode="drop")
            v_c = cache.v.at[bidx, positions].set(v, mode="drop")
            sp = cache.slot_pos.at[bidx, positions].set(positions, mode="drop")
            out = extend_attention_ref(q, k_c, v_c, sp, positions, window=window)
            new_cache = KVSlice(k=k_c, v=v_c, slot_pos=sp)
    elif mode == "decode":
        assert cache is not None and pos is not None
        positions = pos[:, None]                              # (B,1)
        q, k, v = qkv_project(p, x, cfg, positions)           # S == 1
        if isinstance(cache, PagedKVCache):
            # Native paged decode: one token per row written to its
            # physical page, attention walks the block table (no dense
            # gather/scatter around the step).  Sharded decode does not
            # apply — the arena is replicated, rows are block-table rows.
            new_cache = _paged_write_decode(cache, k[:, 0], v[:, 0], pos)
            if jax.default_backend() == "tpu":
                from repro.kernels.decode_attention.ops import (
                    paged_decode_attention,
                )
                out = paged_decode_attention(
                    q, new_cache.k, new_cache.v, new_cache.slot_pos,
                    new_cache.block_table, pos + 1, new_cache.layer,
                    k_scale=new_cache.k_scale, v_scale=new_cache.v_scale,
                )
            else:
                k_d, v_d, sp_d = paged_gather(new_cache)
                out = decode_attention_ref(
                    q, k_d, v_d, pos + 1, window=window, slot_pos=sp_d
                )
            y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
            return y, new_cache
        S_c = cache.k.shape[1]
        use_sharded = (
            cfg.sharded_decode and ctx is not None and cfg.decode_kv_shard_seq
            # batch must shard over the data axes, else the manual path
            # replicates per-rank work that pjit-auto handles better (B=1
            # long-context cells)
            and B % max(ctx.dp_size(), 1) == 0
        )
        if use_sharded:
            from repro.models.sharded_decode import sharded_decode_attention
            try:
                out, new_cache = sharded_decode_attention(
                    ctx, q, cache, k, v, pos, window=window
                )
            except ValueError:       # cache seq not actually sharded
                use_sharded = False
        if not use_sharded:
            if window is not None and S_c <= window:
                slot = (pos % S_c)                            # rolling buffer
            else:
                slot = jnp.minimum(pos, S_c - 1)
            bidx = jnp.arange(B)
            k_c = cache.k.at[bidx, slot].set(k[:, 0])
            v_c = cache.v.at[bidx, slot].set(v[:, 0])
            sp = cache.slot_pos.at[bidx, slot].set(pos)
            kv_len = pos + 1
            out = decode_attention_ref(
                q, k_c, v_c, kv_len, window=window, slot_pos=sp
            )
            new_cache = KVSlice(k=k_c, v=v_c, slot_pos=sp)
    else:
        raise ValueError(mode)

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def kv_slice_specs(cfg: ArchConfig, batch: int, max_len: int) -> KVSlice:
    """PSpec tree for one layer's KV cache slice.

    The cache sequence dim carries the ``kv_seq`` logical axis (sharded over
    data/model per the rules — distributed decode), or ``kv_heads`` when
    ``cfg.decode_kv_shard_seq`` is off.
    """
    S_c = max_len if cfg.sliding_window is None else min(max_len, cfg.sliding_window)
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.decode_kv_shard_seq:
        axes = ("batch", "kv_seq", None, None)
    else:
        axes = ("batch", None, "kv_heads", None)
    return KVSlice(
        k=PSpec((batch, S_c, hkv, dh), axes, ("const", 0.0)),
        v=PSpec((batch, S_c, hkv, dh), axes, ("const", 0.0)),
        slot_pos=PSpec((batch, S_c), ("batch", axes[1] if axes[1] == "kv_seq" else None),
                       ("const", -1), dtype="int32"),
    )


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def mlp_specs(cfg: ArchConfig, d_ff: Optional[int] = None, d_in: Optional[int] = None) -> dict:
    d, f = d_in or cfg.d_model, d_ff or cfg.d_ff
    if cfg.gated_mlp:
        return {
            "w_gate": PSpec((d, f), ("embed", "ffn"), ("normal", 0)),
            "w_up": PSpec((d, f), ("embed", "ffn"), ("normal", 0)),
            "w_down": PSpec((f, d), ("ffn", "embed"), ("normal", 0)),
        }
    return {
        "w_up": PSpec((d, f), ("embed", "ffn"), ("normal", 0)),
        "w_down": PSpec((f, d), ("ffn", "embed"), ("normal", 0)),
    }


def _act(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "sq_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def mlp_block(p, x, cfg: ArchConfig):
    act = _act(cfg.act)
    if cfg.gated_mlp:
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = act(x @ p["w_up"])
    return h @ p["w_down"]


# --------------------------------------------------------------------------
# embeddings / logits / loss
# --------------------------------------------------------------------------
def pad_vocab(vocab: int, multiple: int) -> int:
    return ((vocab + multiple - 1) // multiple) * multiple


def embed_spec(vocab_padded: int, d: int) -> PSpec:
    return PSpec((vocab_padded, d), ("vocab", "embed"), ("normal", 1))


def out_spec(d: int, vocab_padded: int) -> PSpec:
    return PSpec((d, vocab_padded), ("embed", "vocab"), ("normal", 0))


def logits_fn(x, out_w, real_vocab: int):
    """x: (B,S,D) -> fp32 logits with padded-vocab tail masked."""
    logits = jnp.einsum("bsd,dv->bsv", x, out_w).astype(F32)
    V = logits.shape[-1]
    if V != real_vocab:
        mask = jnp.arange(V) < real_vocab
        logits = jnp.where(mask, logits, NEG_INF)
    return logits


def softmax_xent(logits, labels, z_loss: float = 0.0):
    """fp32 cross entropy; labels (B,S) int32; returns scalar mean.

    The label logit is picked with a one-hot einsum (a vocab-dim reduction)
    rather than ``take_along_axis`` — GSPMD keeps the vocab dimension
    sharded for reductions, while a sharded-dim gather forces a full
    rematerialization of the (B, S, V) logits on every device.
    """
    lse = jax.nn.logsumexp(logits, axis=-1)
    oh = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.bfloat16)
    ll = jnp.einsum("bsv,bsv->bs", logits, oh.astype(logits.dtype))
    loss = (lse - ll).mean()
    if z_loss:
        loss = loss + z_loss * jnp.square(lse).mean()
    return loss
