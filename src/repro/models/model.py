"""Model assembly: ArchConfig -> init / loss / prefill / decode programs.

One :class:`Model` per (arch, shard-ctx).  All families share the same
public surface so Cells, the dry-run, and the benchmarks treat every
architecture uniformly:

  param_specs / init / abstract_params / params_pspecs
  loss(params, batch)                                    (train shapes)
  prefill(params, batch)            -> (logits, cache)   (prefill shapes)
  decode(params, cache, batch)      -> (logits, cache)   (decode shapes)
  cache_specs(batch, max_len) / batch_specs(shape)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm
from repro.models import zamba2 as zmb
from repro.models.layers import (
    PagedKVCache,
    embed_spec,
    kv_slice_specs,
    logits_fn,
    norm_spec,
    out_spec,
    pad_vocab,
    rms_norm,
    softmax_xent,
)
from repro.models.mamba2 import mamba_dims
from repro.models.param import (
    PSpec,
    abstract_params,
    count_params,
    init_params,
    tree_map_pspec,
)
from repro.sharding.rules import ShardCtx

F32 = jnp.float32


def stack_specs(specs, n: int):
    """Stack per-layer PSpecs along a leading 'layers' dim."""
    def bump(s: PSpec) -> PSpec:
        init = s.init
        if init[0] == "normal" and init[1] >= 0:
            init = ("normal", init[1] + 1)
        return PSpec((n,) + s.shape, ("layers",) + s.logical, init, s.dtype)
    return tree_map_pspec(bump, specs)


def _policy(name: str):
    if name == "nothing_saveable":
        return jax.checkpoint_policies.nothing_saveable
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    raise ValueError(name)


def _scan_stack(fn, x, stacked, cache, *, remat: bool, policy: str,
                constrain=None, gather=None):
    """Scan fn(x, layer_params, cache_slice)->(x, new_slice, aux) over layers.

    Megatron-SP residual handling: the scan carry (and the remat-saved
    layer input) is kept sequence-sharded via ``constrain`` at the layer
    exit; ``gather`` all-gathers the sequence at layer ENTRY — *inside*
    the remat body so the gathered copy is recomputed in the backward
    rather than saved.  Without the entry gather, GSPMD sees seq-sharded
    activations against model-sharded weights in the dW einsums and
    replicates full weight gradients per layer step.
    """
    def wrapped(h, lp, csl):
        if gather is not None:
            h = gather(h)
        return fn(h, lp, csl)

    body_fn = jax.checkpoint(wrapped, policy=_policy(policy)) if remat else wrapped

    is_paged = lambda n: isinstance(n, PagedKVCache)
    paged_nodes = (
        [n for n in jax.tree.leaves(cache, is_leaf=is_paged) if is_paged(n)]
        if cache is not None else []
    )
    if paged_nodes:
        # Paged KV rides the scan CARRY, not the xs: arena leaves have no
        # layer-stacked leading dim (the whole (N, P, L, ...) arena flows
        # through every step), so slicing them per layer is impossible.
        # Instead the per-step xs carry only the layer index; the body
        # rebinds each PagedKVCache's ``layer`` field and threads the
        # updated arena through the carry.  Output ys for paged positions
        # are dummies; the real arenas are spliced back after the scan.
        L = jax.tree.leaves(stacked)[0].shape[0]
        idx = jnp.arange(L, dtype=jnp.int32)
        cache_x = jax.tree.map(lambda n: idx if is_paged(n) else n, cache,
                               is_leaf=is_paged)

        def body(carry, xs):
            h, aux, pnodes = carry
            lp, csl_x = xs
            it = iter(pnodes)
            csl = jax.tree.map(
                lambda t, sx: next(it)._replace(layer=sx) if is_paged(t) else sx,
                cache, csl_x, is_leaf=is_paged,
            )
            h, ncsl, a = body_fn(h, lp, csl)
            if constrain is not None:
                h = constrain(h)
            new_p = [n for n in jax.tree.leaves(ncsl, is_leaf=is_paged)
                     if is_paged(n)]
            ys = jax.tree.map(
                lambda n: jnp.zeros((), jnp.int32) if is_paged(n) else n,
                ncsl, is_leaf=is_paged,
            )
            return (h, aux + a, new_p), ys

        (x, aux, pnodes), ys = jax.lax.scan(
            body, (x, jnp.float32(0.0), paged_nodes), (stacked, cache_x))
        it = iter(pnodes)
        new_cache = jax.tree.map(
            lambda t, y: (next(it)._replace(layer=jnp.zeros((), jnp.int32))
                          if is_paged(t) else y),
            cache, ys, is_leaf=is_paged,
        )
        return x, new_cache, aux

    def body(carry, xs):
        h, aux = carry
        lp, csl = xs
        h, ncsl, a = body_fn(h, lp, csl)
        if constrain is not None:
            h = constrain(h)
        return (h, aux + a), ncsl

    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.float32(0.0)), (stacked, cache))
    return x, new_cache, aux


class Model:
    def __init__(self, cfg: ArchConfig, ctx: ShardCtx):
        self.cfg = cfg
        self.ctx = ctx
        self.vocab_padded = pad_vocab(cfg.vocab, cfg.vocab_pad_multiple)
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------------
    # parameter specs
    # ------------------------------------------------------------------
    def param_specs(self) -> Dict[str, Any]:
        cfg, ctx = self.cfg, self.ctx
        d, L = cfg.d_model, cfg.num_layers
        specs: Dict[str, Any] = {
            "embed": embed_spec(self.vocab_padded, d),
            "final_norm": norm_spec(d),
        }
        if not cfg.tie_embeddings:
            specs["out"] = out_spec(d, self.vocab_padded)

        fam = cfg.family
        if fam in ("dense", "vlm"):
            specs["layers"] = stack_specs(tfm.dense_layer_specs(cfg), L)
        elif fam == "moe":
            fd = cfg.moe.first_dense_layers
            if fd:
                specs["dense_layers"] = stack_specs(
                    tfm.dense_layer_specs(cfg, d_ff=cfg.moe.dense_d_ff), fd
                )
            specs["moe_layers"] = stack_specs(tfm.moe_layer_specs(cfg, ctx), L - fd)
        elif fam == "ssm":
            specs["mamba_layers"] = stack_specs(zmb.mamba_layer_specs(cfg), L)
        elif fam == "hybrid":
            every = cfg.hybrid_attn_every
            ngroups = L // every
            inner = stack_specs(zmb.mamba_layer_specs(cfg), every)
            specs["groups"] = stack_specs(inner, ngroups)
            specs["shared"] = zmb.shared_block_specs(cfg)
        elif fam == "encdec":
            specs["src_proj"] = PSpec((d, d), ("embed", None), ("normal", 0))
            specs["enc_layers"] = stack_specs(
                encdec_mod.enc_layer_specs(cfg), cfg.encoder_layers
            )
            specs["enc_norm"] = norm_spec(d)
            specs["dec_layers"] = stack_specs(encdec_mod.dec_layer_specs(cfg), L)
        else:
            raise ValueError(fam)
        return specs

    def init(self, rng):
        return init_params(self.param_specs(), rng, self.cfg.dtype)

    def abstract_params(self):
        return abstract_params(self.param_specs(), self.cfg.dtype)

    def params_pspecs(self):
        return self.ctx.params_pspecs(self.param_specs())

    def n_params(self) -> int:
        return count_params(self.param_specs())

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def cache_specs(self, batch: int, max_len: int):
        cfg = self.cfg
        fam = cfg.family
        L = cfg.num_layers
        if fam in ("dense", "vlm"):
            return {"layers": stack_specs(kv_slice_specs(cfg, batch, max_len), L)}
        if fam == "moe":
            fd = cfg.moe.first_dense_layers
            out = {"moe_layers": stack_specs(kv_slice_specs(cfg, batch, max_len), L - fd)}
            if fd:
                out["dense_layers"] = stack_specs(kv_slice_specs(cfg, batch, max_len), fd)
            return out
        if fam == "ssm":
            return {"mamba_layers": stack_specs(self._mamba_state_specs(batch), L)}
        if fam == "hybrid":
            every = cfg.hybrid_attn_every
            ngroups = L // every
            return {
                "groups": zmb.ZambaGroupCache(
                    mamba=stack_specs(
                        stack_specs(self._mamba_state_specs(batch), every), ngroups
                    ),
                    shared=stack_specs(
                        kv_slice_specs(cfg, batch, max_len), ngroups
                    ),
                )
            }
        if fam == "encdec":
            s_src = self.source_len(max_len)
            hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
            cross_axes = ("batch", "kv_seq", None, None)
            return {
                "dec_layers": encdec_mod.DecCache(
                    self_kv=stack_specs(kv_slice_specs(cfg, batch, max_len), L),
                    cross_k=PSpec((L, batch, s_src, hkv, dh),
                                  ("layers",) + cross_axes, ("const", 0.0)),
                    cross_v=PSpec((L, batch, s_src, hkv, dh),
                                  ("layers",) + cross_axes, ("const", 0.0)),
                    # valid source prefix per row; 0 (init) = no memory yet
                    src_len=PSpec((L, batch), ("layers", "batch"),
                                  ("const", 0), dtype="int32"),
                )
            }
        raise ValueError(fam)

    def _mamba_state_specs(self, batch: int):
        cfg = self.cfg
        d_inner, H, G, N, K = mamba_dims(cfg)
        P_ = cfg.ssm.head_dim
        from repro.models.mamba2 import MambaState
        return MambaState(
            conv=PSpec((batch, K - 1, d_inner + 2 * G * N),
                       ("batch", None, "inner"), ("const", 0.0)),
            ssm=PSpec((batch, H, P_, N),
                      ("batch", "ssm_heads", None, None), ("const", 0.0),
                      dtype="float32"),
        )

    def init_cache(self, batch: int, max_len: int):
        return init_params(self.cache_specs(batch, max_len), jax.random.PRNGKey(0), self.cfg.dtype)

    def abstract_cache(self, batch: int, max_len: int):
        return abstract_params(self.cache_specs(batch, max_len), self.cfg.dtype)

    def cache_pspecs(self, batch: int, max_len: int):
        return self.ctx.params_pspecs(self.cache_specs(batch, max_len))

    def source_len(self, seq_len: int) -> int:
        """Encoder source length for encdec shapes (audio capped at 4k frames)."""
        return int(min(seq_len, 4096) * self.cfg.source_len_ratio)

    # ------------------------------------------------------------------
    # batches
    # ------------------------------------------------------------------
    def batch_specs(self, shape: ShapeConfig):
        """(ShapeDtypeStruct tree, PartitionSpec tree) for a workload shape."""
        cfg, ctx = self.cfg, self.ctx
        B, S = shape.global_batch, shape.seq_len
        tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)
        out: Dict[str, Any] = {}
        pspecs: Dict[str, Any] = {}

        def add(name, sds, logical):
            out[name] = sds
            pspecs[name] = ctx.pspec(logical, sds.shape)

        if shape.kind == "train":
            add("tokens", tok(B, S), ("batch", None))
            add("labels", tok(B, S), ("batch", None))
        elif shape.kind == "prefill":
            add("tokens", tok(B, S), ("batch", None))
        else:  # decode
            add("tokens", tok(B, 1), ("batch", None))
            add("pos", tok(B), ("batch",))
        if cfg.family == "encdec" and shape.kind != "decode":
            s_src = self.source_len(S)
            add("src", jax.ShapeDtypeStruct((B, s_src, cfg.d_model), self.dtype),
                ("batch", None, None))
        return out, pspecs

    # ------------------------------------------------------------------
    # forward
    # ------------------------------------------------------------------
    def _embed_tokens(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        x = jax.lax.with_sharding_constraint(
            x, self.ctx.sharding(("batch", None, None), x.shape)
        )
        return x

    def _logits(self, params, x):
        w = params["embed"].T if self.cfg.tie_embeddings else params["out"]
        logits = logits_fn(x, w, self.cfg.vocab)
        # vocab-parallel logits (Megatron): never materialize the full vocab
        # dim on one device — the xent reductions then psum over the model
        # axis instead of all-gathering (B, S, V).
        return jax.lax.with_sharding_constraint(
            logits, self.ctx.sharding(("batch", None, "vocab"), logits.shape)
        )


    def _act_constrain(self):
        mode = self.cfg.activation_shard
        if mode is None:
            return None
        logical = (
            ("batch", "act_seq", None) if mode == "seq"
            else ("batch", None, "act_embed")
        )

        def f(h):
            return jax.lax.with_sharding_constraint(
                h, self.ctx.sharding(logical, h.shape)
            )
        return f

    def _act_gather(self):
        """Layer-entry resharding: batch-sharded only (full seq/embed)."""
        if self.cfg.activation_shard is None:
            return None

        def f(h):
            return jax.lax.with_sharding_constraint(
                h, self.ctx.sharding(("batch", None, None), h.shape)
            )
        return f

    def _backbone(self, params, x, *, mode: str, cache=None, pos=None, x0=None,
                  mask=None, ckpt_every=None):
        """Shared decoder trunk for non-encdec families.

        ``mask`` (B, S) bool marks the real tokens of bucket-padded
        prefill rows.  Recurrent families (ssm / hybrid) thread it into
        the SSD scan so pad positions make no state update; KV families
        ignore it (causality + ``mask_pad_slots`` already confine pads).

        ``ckpt_every`` (prefill, ssm/hybrid only): emit recurrent-state
        checkpoints at every interior chunk boundary — the per-layer new
        state becomes ``(state, checkpoints)`` and rides the scan ys; the
        caller splits it back apart (``prefill_ranged``).
        """
        cfg, ctx = self.cfg, self.ctx
        remat = mode == "train"
        pol = cfg.remat_policy
        aux_total = jnp.float32(0.0)
        new_cache: Dict[str, Any] = {}
        constrain = self._act_constrain()
        gather = None  # entry-gather measured WORSE (see EXPERIMENTS.md §Perf)

        fam = cfg.family
        if fam in ("dense", "vlm"):
            fn = lambda h, lp, csl: tfm.dense_layer(lp, h, cfg, ctx, mode=mode, cache=csl, pos=pos)
            x, nc, aux = _scan_stack(fn, x, params["layers"],
                                     None if cache is None else cache["layers"],
                                     remat=remat, policy=pol, constrain=constrain, gather=gather)
            new_cache["layers"] = nc
            aux_total += aux
        elif fam == "moe":
            fd = cfg.moe.first_dense_layers
            if fd:
                fn = lambda h, lp, csl: tfm.dense_layer(lp, h, cfg, ctx, mode=mode, cache=csl, pos=pos)
                x, nc, aux = _scan_stack(fn, x, params["dense_layers"],
                                         None if cache is None else cache.get("dense_layers"),
                                         remat=remat, policy=pol, constrain=constrain, gather=gather)
                new_cache["dense_layers"] = nc
                aux_total += aux
            fn = lambda h, lp, csl: tfm.moe_layer(lp, h, cfg, ctx, mode=mode, cache=csl, pos=pos)
            x, nc, aux = _scan_stack(fn, x, params["moe_layers"],
                                     None if cache is None else cache["moe_layers"],
                                     remat=remat, policy=pol, constrain=constrain, gather=gather)
            new_cache["moe_layers"] = nc
            aux_total += aux
        elif fam == "ssm":
            fn = lambda h, lp, csl: zmb.mamba_layer(lp, h, cfg, mode=mode, state=csl, mask=mask, ckpt_every=ckpt_every)
            x, nc, aux = _scan_stack(fn, x, params["mamba_layers"],
                                     None if cache is None else cache["mamba_layers"],
                                     remat=remat, policy=pol, constrain=constrain, gather=gather)
            new_cache["mamba_layers"] = nc
            aux_total += aux
        elif fam == "hybrid":
            shared = params["shared"]

            def group_fn(h, gp, gcsl):
                m_cache = None if gcsl is None else gcsl.mamba
                inner = lambda hh, lp, csl: zmb.mamba_layer(lp, hh, cfg, mode=mode, state=csl, mask=mask, ckpt_every=ckpt_every)
                h, n_m, aux = _scan_stack(inner, h, gp, m_cache, remat=False, policy=pol)
                h, n_s = zmb.shared_block(
                    shared, h, x0, cfg, self.ctx, mode=mode,
                    cache=None if gcsl is None else gcsl.shared, pos=pos,
                )
                ncache = zmb.ZambaGroupCache(mamba=n_m, shared=n_s) if gcsl is not None else None
                return h, ncache, aux

            x, nc, aux = _scan_stack(group_fn, x, params["groups"],
                                     None if cache is None else cache["groups"],
                                     remat=remat, policy=pol, constrain=constrain, gather=gather)
            new_cache["groups"] = nc
            aux_total += aux
        else:
            raise ValueError(fam)
        return x, new_cache, aux_total

    def _encode(self, params, src, *, remat: bool = False, src_len=None):
        """src (B, S_src, d_model) -> memory; ``src_len`` (B,) masks pad
        frames out of the bidirectional self-attention so each row's
        encoding is independent of the batch's common padded length."""
        cfg = self.cfg
        x = (src.astype(self.dtype) @ params["src_proj"])
        fn = lambda h, lp, _csl: encdec_mod.enc_layer(lp, h, cfg, self.ctx,
                                                      src_len=src_len)
        x, _, _ = _scan_stack(fn, x, params["enc_layers"], None,
                              remat=remat, policy=cfg.remat_policy,
                              constrain=self._act_constrain(),
                              gather=self._act_gather())
        return rms_norm(x, params["enc_norm"], cfg.rms_eps)

    def _decode_stack(self, params, x, *, mode, memory=None, cache=None, pos=None,
                      src_len=None):
        cfg = self.cfg
        fn = lambda h, lp, csl: encdec_mod.dec_layer(
            lp, h, cfg, self.ctx, mode=mode, memory=memory, cache=csl, pos=pos,
            src_len=src_len,
        )
        remat = mode == "train"
        x, nc, aux = _scan_stack(fn, x, params["dec_layers"],
                                 None if cache is None else cache["dec_layers"],
                                 remat=remat, policy=cfg.remat_policy,
                                 constrain=self._act_constrain(),
                                 gather=self._act_gather())
        return x, ({"dec_layers": nc} if cache is not None else {}), aux

    # ------------------------------------------------------------------
    # public programs
    # ------------------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        if cfg.family == "encdec":
            src_len = batch.get("src_len")
            memory = self._encode(params, batch["src"], remat=True,
                                  src_len=src_len)
            x = self._embed_tokens(params, batch["tokens"])
            x, _, aux = self._decode_stack(params, x, mode="train",
                                           memory=memory, src_len=src_len)
        else:
            x = self._embed_tokens(params, batch["tokens"])
            x0 = x
            x, _, aux = self._backbone(params, x, mode="train", x0=x0)
        x = rms_norm(x, params["final_norm"], cfg.rms_eps)
        if self.ctx.dp_over_model and x.shape[1] >= 1024:
            # ZeRO-3 layout: the vocab dim can't shard (the model axis backs
            # the batch), so never materialize full-seq logits — scan the
            # head over sequence chunks with remat
            xent = self._chunked_xent(params, x, batch["labels"])
        else:
            logits = self._logits(params, x)
            xent = softmax_xent(logits, batch["labels"])
        loss = xent + 0.01 * aux
        return loss, {"loss": loss, "xent": xent, "aux": aux}

    def _chunked_xent(self, params, x, labels, chunk: int = 512):
        B, S, D = x.shape
        n = S // chunk
        assert S % chunk == 0
        xs = (
            x.reshape(B, n, chunk, D).swapaxes(0, 1),
            labels.reshape(B, n, chunk).swapaxes(0, 1),
        )

        def body(tot, xs_c):
            xc, lc = xs_c
            logits = self._logits(params, xc)
            lse = jax.nn.logsumexp(logits, axis=-1)
            oh = jax.nn.one_hot(lc, logits.shape[-1], dtype=jnp.bfloat16)
            ll = jnp.einsum("bsv,bsv->bs", logits, oh.astype(logits.dtype))
            return tot + (lse - ll).sum(), None

        body = jax.checkpoint(body, policy=_policy(self.cfg.remat_policy))
        total, _ = jax.lax.scan(body, jnp.float32(0.0), xs)
        return total / (B * S)

    def prefill(self, params, batch, cache):
        cfg = self.cfg
        if cfg.family == "encdec":
            src_len = batch.get("src_len")
            memory = self._encode(params, batch["src"], src_len=src_len)
            x = self._embed_tokens(params, batch["tokens"])
            x, new_cache, _ = self._decode_stack(
                params, x, mode="prefill", memory=memory, cache=cache,
                src_len=src_len,
            )
        else:
            x = self._embed_tokens(params, batch["tokens"])
            x0 = x
            x, new_cache, _ = self._backbone(
                params, x, mode="prefill", cache=cache, x0=x0
            )
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
        logits = self._logits(params, x)[:, 0]
        return logits, new_cache

    @property
    def chunked_prefill_exact(self) -> bool:
        """True when :meth:`prefill_ranged` is EXACT for this family on
        bucket-padded prompt batches.

        The single source of truth for the serving capability:
        ``serve_step.supports_chunked_prefill`` consults this (plus the
        cache-layout condition on ``sliding_window``) and
        :meth:`prefill_ranged` raises ``NotImplementedError`` exactly when
        this is False — the two can never drift.

        Every registered family qualifies: KV families (dense/vlm/moe) via
        causal attention + ``mask_pad_slots``; recurrent families
        (ssm/hybrid) via the pad-token validity mask threaded into the SSD
        scan (zero ``dt`` at pads, conv state snapshotted at the last real
        token); encdec via per-request source features with ``src_len``
        masked encoder/cross attention.
        """
        return self.cfg.family in ("dense", "vlm", "moe", "ssm", "hybrid",
                                   "encdec")

    @property
    def supports_paged_kv(self) -> bool:
        """True when this family's serve cache can live in a paged KV
        arena with radix-tree prefix sharing (``repro.serve.kvpool``).

        Requires every *positional* cache leaf to be a ``KVSlice`` whose
        per-position values depend only on the token prefix up to that
        position (causal KV) — then interned prefix pages written by one
        request are bit-identical to what any other request with the
        same prompt prefix (and, for encdec, the same source features)
        would compute, so they can be mapped read-only.  Recurrent state
        (ssm / hybrid) folds the whole history into one non-positional
        state and cannot be page-shared — those families share state
        SNAPSHOTS at chunk boundaries instead
        (:attr:`supports_snapshot_state`); the pool-level three-way
        capability is ``repro.serve.kvpool.KVPool.capability``.
        encdec qualifies: its decoder self-KV pages, while the cross
        memory rides the dense *resident* remainder of the cache.
        """
        return self.cfg.family in ("dense", "vlm", "moe", "encdec")

    @property
    def supports_snapshot_state(self) -> bool:
        """True when this family's serve cache is a recurrent state that
        can be SNAPSHOTTED at token-chunk boundaries and restored to seed
        a suffix-only prefill (``repro.serve.kvpool`` snapshot pools).

        Requires the state after token ``i`` to depend only on tokens
        ``<= i`` (plus, for hybrid, the shared-attention KV up to ``i``,
        which is causal and travels with the snapshot as page stacks), so
        an interned checkpoint written by one request is bit-identical to
        what any request with the same prefix would compute —
        :meth:`prefill_ranged` with ``checkpoint_every`` emits the
        checkpoints, :meth:`restore_state_row` +
        :meth:`prefill_extend` replay from the deepest one.
        """
        return self.cfg.family in ("ssm", "hybrid")

    def prefill_extend(self, params, batch, cache):
        """Suffix-only prefill behind a resident prefix (prefix sharing).

        ``batch`` = {tokens (B, S_ext) int32, pos (B,) int32, length (B,)
        int32}: row b's suffix ``tokens[b, :length[b]]`` continues a
        prompt whose first ``pos[b]`` positions are ALREADY present in
        ``cache`` (gathered from interned pool pages).  Positions are
        absolute (``pos[b] + i``), attention masks purely by the cache's
        ``slot_pos``, and K/V for the suffix is written in place — the
        per-layer work is ``attention_block(mode="extend")``.  encdec
        reads its cross memory from the cache (installed by the caller),
        exactly like decode.  Recurrent families (ssm/hybrid) continue
        from the cache's restored snapshot state instead of resident
        pages: the suffix validity mask keeps pad tokens out of the SSD
        scan (a ``length`` 0 row is a pure no-op: identity state update,
        and its attention writes land out of range when the caller sets
        ``pos`` past the cache length).  Returns (logits at each row's
        LAST REAL suffix token, updated cache).
        """
        cfg = self.cfg
        if not (self.supports_paged_kv or self.supports_snapshot_state):
            raise NotImplementedError(
                f"no suffix prefill for family {cfg.family!r}"
            )
        tokens, pos, length = batch["tokens"], batch["pos"], batch["length"]
        mask = jnp.arange(tokens.shape[1])[None, :] < length[:, None]
        x = self._embed_tokens(params, tokens)
        if cfg.family == "encdec":
            x, new_cache, _ = self._decode_stack(
                params, x, mode="extend", cache=cache, pos=pos
            )
        else:
            x, new_cache, _ = self._backbone(
                params, x, mode="extend", cache=cache, pos=pos, x0=x,
                mask=mask,
            )
        last = jnp.clip(length - 1, 0, x.shape[1] - 1)
        x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)
        x_last = rms_norm(x_last, params["final_norm"], cfg.rms_eps)
        logits = self._logits(params, x_last)[:, 0]
        return logits, new_cache

    @property
    def decode_state_positional(self) -> bool:
        """True when every per-slot serve-cache leaf is position-masked
        (pure KV with ``slot_pos``), so stale rows left by a slot's
        previous occupant are invisible to decode attention.  Recurrent
        state (ssm/hybrid) and encdec cross memory are NOT positional —
        a reused slot must be reset to init values before a
        token-at-a-time admit (the batcher consults this)."""
        return self.cfg.family in ("dense", "vlm", "moe")

    def prefill_ranged(self, params, batch, cache, *, checkpoint_every=None):
        """Chunked prefill: whole padded prompts in a single invocation.

        ``batch`` = {tokens (B, S_pad) int32, length (B,) int32} where row b
        holds a real prompt in ``tokens[b, :length[b]]`` and padding after
        (``length`` 0 marks a dummy batch-padding row).  encdec batches add
        {src (B, S_src, d_model), src_len (B,)} — see
        :meth:`ranged_batch_extras`.

        ``checkpoint_every`` (ssm/hybrid only; must divide S_pad): also
        return the stacked per-boundary recurrent-state checkpoints the
        snapshot cache plane interns — return becomes ``(logits, cache,
        ckpts)`` with ``ckpts`` sliceable via :meth:`slice_checkpoint`.
        Checkpoints at boundaries past a row's true length are garbage
        (identity updates over pad conv windows) and must not be read —
        consumers only intern full-chunk boundaries ``<= length - 1``.

        Returns (logits (B, V) taken at each
        row's LAST REAL token, cache exact at each row's true length:

        * KV families: pad slots' ``slot_pos`` masked to -1 so decode
          attention never sees the padding K/V;
        * ssm / hybrid: pad tokens contribute ZERO state update (``dt``
          masked inside the SSD scan) and the causal-conv state is
          snapshotted at each row's last real token;
        * encdec: cross-attention memory encoded under a ``src_len`` mask
          and carried in the cache (with the mask) for decode).
        """
        cfg = self.cfg
        if not self.chunked_prefill_exact:
            raise NotImplementedError(
                f"no exact chunked prefill for family {cfg.family!r}"
            )
        tokens, length = batch["tokens"], batch["length"]
        if checkpoint_every is not None:
            if not self.supports_snapshot_state:
                raise NotImplementedError(
                    f"no state checkpoints for family {cfg.family!r}")
            if tokens.shape[1] % checkpoint_every:
                raise ValueError(
                    f"S_pad={tokens.shape[1]} not a multiple of "
                    f"checkpoint_every={checkpoint_every}")
        mask = jnp.arange(tokens.shape[1])[None, :] < length[:, None]
        if cfg.family == "encdec":
            src_len = batch.get("src_len")
            if src_len is None:
                src_len = jnp.full((tokens.shape[0],), batch["src"].shape[1],
                                   jnp.int32)
            memory = self._encode(params, batch["src"], src_len=src_len)
            x = self._embed_tokens(params, tokens)
            x, new_cache, _ = self._decode_stack(
                params, x, mode="prefill", memory=memory, cache=cache,
                src_len=src_len,
            )
        else:
            x = self._embed_tokens(params, tokens)
            x, new_cache, _ = self._backbone(
                params, x, mode="prefill", cache=cache, x0=x, mask=mask,
                ckpt_every=checkpoint_every,
            )
        ckpts = None
        if checkpoint_every is not None:
            new_cache, ckpts = self._split_checkpoints(new_cache)
        last = jnp.clip(length - 1, 0, x.shape[1] - 1)
        x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)  # (B,1,D)
        x_last = rms_norm(x_last, params["final_norm"], cfg.rms_eps)
        logits = self._logits(params, x_last)[:, 0]
        from repro.models.cache_utils import mask_pad_slots
        new_cache = mask_pad_slots(new_cache, length)
        if checkpoint_every is not None:
            return logits, new_cache, ckpts
        return logits, new_cache

    # ------------------------------------------------------------------
    # recurrent-state snapshots (the ssm/hybrid cache-plane payload)
    # ------------------------------------------------------------------
    def _split_checkpoints(self, new_cache):
        """Split the ``(state, checkpoints)`` tuples the checkpointing
        backbone threads through the layer scan back into (cache, ckpts).
        ``ckpts`` leaves carry the chunk axis right after batch: ssm
        (L, B, nb, ...), hybrid (G, E, B, nb, ...)."""
        if self.cfg.family == "ssm":
            states, ck = new_cache["mamba_layers"]
            return {**new_cache, "mamba_layers": states}, ck
        g = new_cache["groups"]
        states, ck = g.mamba
        return {**new_cache, "groups": g._replace(mamba=states)}, ck

    @property
    def _state_batch_axis(self) -> int:
        """Batch-axis index of the stacked recurrent-state leaves: ssm
        stacks (L,) in front, hybrid (G, E)."""
        return 1 if self.cfg.family == "ssm" else 2

    def slice_checkpoint(self, ckpts, row: int, chunk_idx: int):
        """One row's recurrent state at interior chunk boundary
        ``chunk_idx`` (state AFTER chunk ``chunk_idx``), as a 1-row state
        tree shaped exactly like the recurrent part of a dense cache row
        — the snapshot payload :meth:`restore_state_row` writes back."""
        ax = self._state_batch_axis
        idx = (slice(None),) * ax + (slice(row, row + 1), chunk_idx)
        return jax.tree.map(lambda a: a[idx], ckpts)

    def restore_state_row(self, cache, state, row: int):
        """Write a 1-row snapshot ``state`` (from :meth:`slice_checkpoint`
        or a final prefill state row) over slot ``row``'s recurrent cache
        leaves; KV leaves (hybrid shared attention) are untouched — the
        caller restores those from the snapshot's page stacks."""
        ax = self._state_batch_axis
        idx = (slice(None),) * ax + (slice(row, row + 1),)

        def put(c, s):
            return c.at[idx].set(s.astype(c.dtype))

        if self.cfg.family == "ssm":
            return {**cache,
                    "mamba_layers": jax.tree.map(put, cache["mamba_layers"],
                                                 state)}
        g = cache["groups"]
        return {**cache, "groups": g._replace(
            mamba=jax.tree.map(put, g.mamba, state))}

    # ------------------------------------------------------------------
    # chunked-prefill batch helpers (family-specific knowledge lives HERE
    # so the serve layer stays free of family branches)
    # ------------------------------------------------------------------
    def ranged_batch_extras(self, srcs, max_len: int):
        """Extra ``prefill_ranged`` batch keys for ``len(srcs)`` rows.

        ``srcs``: per-row source feature arrays (S_src_i, d_model) or None
        (no source -> zero features, ``src_len`` 0).  Families without
        side inputs return {}; encdec returns {src, src_len} padded to the
        cache's source length so every bucket compiles one program shape.
        """
        if self.cfg.family != "encdec":
            return {}
        import numpy as np
        B = len(srcs)
        s_src = self.source_len(max_len)
        src = np.zeros((B, s_src, self.cfg.d_model), np.float32)
        src_len = np.zeros((B,), np.int32)
        for i, s in enumerate(srcs):
            if s is None:
                continue
            s = np.asarray(s, np.float32)
            L = min(len(s), s_src)
            src[i, :L] = s[:L]
            src_len[i] = L
        return {"src": jnp.asarray(src, self.dtype),
                "src_len": jnp.asarray(src_len)}

    def encode_cross_rows(self, params, srcs, max_len: int):
        """Cross-attention memory rows for token-at-a-time prompt paths.

        Returns (cross_k (L,B,S_src,Hkv,Dh), cross_v, src_len (B,)) ready
        for :func:`repro.models.cache_utils.install_cross_memory`, or None
        when this family has no cross memory (or no row carries source
        features) — callers need no family branch.
        """
        if self.cfg.family != "encdec" or all(s is None for s in srcs):
            return None
        extras = self.ranged_batch_extras(srcs, max_len)
        if not hasattr(self, "_encode_cross_jit"):
            def _encode_cross(params, src, src_len):
                memory = self._encode(params, src, src_len=src_len)
                # the SAME projection dec_layer uses in prefill, vmapped
                # over the stacked layer dim — one definition, two paths
                return jax.vmap(encdec_mod.cross_kv, in_axes=(0, None))(
                    params["dec_layers"]["cross"], memory)
            self._encode_cross_jit = jax.jit(_encode_cross)
        ck, cv = self._encode_cross_jit(params, extras["src"], extras["src_len"])
        return ck, cv, extras["src_len"]

    def decode(self, params, cache, batch):
        cfg = self.cfg
        x = self._embed_tokens(params, batch["tokens"])     # (B,1,D)
        pos = batch["pos"]
        if cfg.family == "encdec":
            x, new_cache, _ = self._decode_stack(
                params, x, mode="decode", cache=cache, pos=pos
            )
        else:
            x0 = x
            x, new_cache, _ = self._backbone(
                params, x, mode="decode", cache=cache, pos=pos, x0=x0
            )
        x = rms_norm(x, params["final_norm"], cfg.rms_eps)
        logits = self._logits(params, x)[:, 0]
        return logits, new_cache


def build_model(cfg: ArchConfig, ctx: ShardCtx) -> Model:
    return Model(cfg, ctx)
