"""Mixture-of-experts FFN block.

Fully-manual ``jax.shard_map`` implementation so the parallel layout is
explicit and differentiable:

* **EP** (expert parallelism): when ``E % model_axis == 0`` each model rank
  owns ``E_local`` experts; activations are replicated over the model axis,
  each rank dispatches only tokens routed to its experts, and the final
  ``psum`` over the model axis sums disjoint expert contributions
  (DeepSeekMoE: 64 experts over 16 ranks).
* **TP-in-expert**: otherwise every rank holds all experts with the ffn dim
  sharded; the same ``psum`` combines partial products (Mixtral: 8 experts).
* **FSDP**: expert weights are additionally sharded over the data axis and
  explicitly ``all_gather``-ed before use; AD transposes that into the ZeRO
  gradient reduce-scatter.

Dispatch is scatter-based (capacity-bounded, GShard-style slots computed
with a cumsum over one-hots) — no O(T·E·C·D) dispatch einsum.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.param import PSpec
from repro.sharding.rules import ShardCtx

F32 = jnp.float32


def use_ep(cfg: ArchConfig, ctx: ShardCtx) -> bool:
    return cfg.moe.num_experts % max(ctx.model_size(), 1) == 0


def moe_specs(cfg: ArchConfig, ctx: ShardCtx) -> dict:
    m = cfg.moe
    d, fe = cfg.d_model, m.d_expert
    e = m.num_experts
    if use_ep(cfg, ctx):
        w_axes = {
            "w_gate": ("expert", "embed", None),
            "w_up": ("expert", "embed", None),
            "w_down": ("expert", None, "embed"),
        }
    else:
        w_axes = {
            "w_gate": (None, "embed", "expert_ffn"),
            "w_up": (None, "embed", "expert_ffn"),
            "w_down": (None, "expert_ffn", "embed"),
        }
    specs = {
        "router": PSpec((d, e), (None, None), ("normal", 0), dtype="float32"),
        "w_gate": PSpec((e, d, fe), w_axes["w_gate"], ("normal", 1)),
        "w_up": PSpec((e, d, fe), w_axes["w_up"], ("normal", 1)),
        "w_down": PSpec((e, fe, d), w_axes["w_down"], ("normal", 1)),
    }
    if m.num_shared:
        fs = m.num_shared * m.d_shared
        specs["ws_gate"] = PSpec((d, fs), ("embed", "ffn"), ("normal", 0))
        specs["ws_up"] = PSpec((d, fs), ("embed", "ffn"), ("normal", 0))
        specs["ws_down"] = PSpec((fs, d), ("ffn", "embed"), ("normal", 0))
    return specs


def _capacity(cfg: ArchConfig, t_local: int, train: bool) -> int:
    m = cfg.moe
    if not train and t_local <= 64:
        # decode / tiny prefill shards: dropless (worst case: every token
        # routes one of its k choices to the same expert).
        return t_local
    cf = m.capacity_factor if train else max(m.capacity_factor, 2.0)
    c = int(math.ceil(m.top_k * t_local * cf / m.num_experts))
    return max(min(c, t_local), 1)


def _moe_local(xf, router, w_gate, w_up, w_down, *, cfg: ArchConfig,
               ctx: ShardCtx, train: bool):
    """Per-shard MoE body (runs under fully-manual shard_map).

    xf: (T_local, D) tokens, replicated over the model axis.
    EP:  w_*: (E_local, D_local, Fe)  ->  all_gather(data) -> (E_local, D, Fe)
    TP:  w_*: (E, D_local, Fe_local)  ->  all_gather(data) -> (E, D, Fe_local)
    """
    m = cfg.moe
    ep = use_ep(cfg, ctx)
    model_ax = ctx.model_axis
    T, D = xf.shape
    E, K = m.num_experts, m.top_k

    # ---- FSDP gather of expert weights (transpose = grad reduce-scatter).
    # Weights may be sharded over ("pod","data") on the embed dim; gather
    # minor-to-major so tiles reassemble in order.
    if ctx.fsdp:
        for ax in ("data", "pod"):
            if ctx.axis_sizes.get(ax, 1) > 1 and w_gate.shape[1] < D:
                w_gate = jax.lax.all_gather(w_gate, ax, axis=1, tiled=True)
                w_up = jax.lax.all_gather(w_up, ax, axis=1, tiled=True)
                w_down = jax.lax.all_gather(w_down, ax, axis=2, tiled=True)

    # ---- routing (fp32)
    logits = xf.astype(F32) @ router.astype(F32)              # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)                      # (T, K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e.  f_e and P_e are
    # *global* means — average them across data shards BEFORE the product
    # (the product of local means is not linear in the sharding).
    oh_full = jax.nn.one_hot(topi, E, dtype=F32).sum(1)       # (T, E)
    f_e = jax.lax.pmean(oh_full.mean(0), ctx.batch_axes)
    p_e = jax.lax.pmean(probs.mean(0), ctx.batch_axes)
    aux = E * jnp.sum(f_e * p_e)

    # ---- capacity slots
    C = _capacity(cfg, T, train)
    flat_e = topi.reshape(-1)                                 # (T*K,)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    slot = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1          # slot within expert
    keep = slot < C
    tok = jnp.repeat(jnp.arange(T), K)
    gate = jnp.where(keep, topv.reshape(-1), 0.0)

    # ---- EP filter: this rank owns experts [r*E_local, (r+1)*E_local)
    if ep and model_ax is not None:
        e_local_n = E // ctx.model_size()
        r = jax.lax.axis_index(model_ax)
        mine = (flat_e // e_local_n) == r
        keep = keep & mine
        local_e = jnp.clip(flat_e - r * e_local_n, 0, e_local_n - 1)
    else:
        e_local_n = E
        local_e = flat_e

    safe_slot = jnp.where(keep, slot, C - 1)
    contrib = jnp.where(keep[:, None], xf[tok], 0).astype(xf.dtype)
    buf = jnp.zeros((e_local_n, C, D), xf.dtype)
    buf = buf.at[local_e, safe_slot].add(contrib, mode="drop")

    # ---- expert FFN (SwiGLU)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", buf, w_up
    )
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down)           # (E_local, C, D)

    # ---- combine.  The cross-rank sum runs as a bf16 REDUCE-SCATTER over
    # the embed dim: half the ring traffic of an all-reduce, and the output
    # lands embed-sharded — exactly the residual-stream layout, so no
    # downstream reshard.
    gathered = out_buf[local_e, safe_slot] * jnp.where(keep, gate, 0.0)[:, None].astype(xf.dtype)
    y = jax.ops.segment_sum(gathered, tok, num_segments=T)
    if model_ax is not None:
        msz = ctx.model_size()
        if msz > 1 and D % msz == 0:
            y = jax.lax.psum_scatter(
                y.astype(xf.dtype), model_ax, scatter_dimension=1, tiled=True
            )
        else:
            y = jax.lax.psum(y, model_ax)
    return y.astype(xf.dtype), aux


def moe_block(p, x, cfg: ArchConfig, ctx: ShardCtx, *, train: bool) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) global.  Returns (y, aux_loss scalar)."""
    B, S, D = x.shape
    # divisibility-aware: decode with tiny batches replicates tokens over
    # the data axes (each instance computes identically; psum over the
    # model axis still combines expert/ffn shards correctly)
    batch_spec = ctx.pspec(("batch", None), (B * S, D))

    def wrapped(xf, router, w_gate, w_up, w_down):
        return _moe_local(
            xf, router, w_gate, w_up, w_down, cfg=cfg, ctx=ctx, train=train
        )

    wspec = lambda name, shape: ctx.pspec(moe_specs(cfg, ctx)[name].logical, shape)
    msz = ctx.model_size()
    scattered = msz > 1 and D % msz == 0 and ctx.model_axis is not None
    y_spec = (
        P(batch_spec[0], ctx.model_axis) if scattered
        else P(batch_spec[0], None)
    )
    from repro.sharding.rules import shard_map_compat
    fn = shard_map_compat(
        wrapped,
        mesh=ctx.mesh,
        in_specs=(
            batch_spec,
            P(None, None),
            wspec("w_gate", p["w_gate"].shape),
            wspec("w_up", p["w_up"].shape),
            wspec("w_down", p["w_down"].shape),
        ),
        out_specs=(y_spec, P()),
        axis_names=ctx.manual_axes,
        check_vma=False,
    )
    xf = x.reshape(B * S, D)
    y, aux = fn(xf, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    y = y.reshape(B, S, D)

    # shared experts (dense, pjit-auto part)
    if cfg.moe.num_shared:
        h = jax.nn.silu(x @ p["ws_gate"]) * (x @ p["ws_up"])
        y = y + h @ p["ws_down"]
    return y, aux
