"""Distributed flash-decode: attention over a sequence-sharded KV cache.

With ``decode_kv_shard_seq`` the cache's sequence dim is sharded over the
model (and, for batch=1 long-context cells, also the data) axis.  Under
pjit autosharding, XLA resolves the softmax over the sharded dim by
ALL-GATHERING the per-layer KV cache every step — ~KV_bytes/chip of ICI
traffic per layer per token, which makes decode collective-bound.

This module is the beyond-paper replacement: a fully-manual ``shard_map``
where each shard computes a *partial* softmax (m, l, acc) over its local
KV rows and the shards merge with an LSE combine — ``pmax`` of the max and
``psum`` of (l, acc), i.e. O(B*H*Dh) bytes instead of O(B*S*H*Dh).  The
cache-slot write is also local (only the owning shard writes).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import KVSlice

F32 = jnp.float32
NEG_INF = -1e30


def _seq_axes_of(pspec: P) -> Tuple[str, ...]:
    """Mesh axes the cache's seq dim (dim 1) is sharded over."""
    if len(pspec) < 2 or pspec[1] is None:
        return ()
    e = pspec[1]
    return e if isinstance(e, tuple) else (e,)


def sharded_decode_attention(
    ctx, q, cache: KVSlice, new_k, new_v, pos, *,
    window: Optional[int] = None,
) -> Tuple[jnp.ndarray, KVSlice]:
    """q: (B,1,Hq,Dh); cache k/v: (B,S_c,Hkv,Dh); new_k/v: (B,1,Hkv,Dh);
    pos: (B,) absolute positions.  Returns (out (B,1,Hq,Dh), new cache)."""
    B, S_c, Hkv, Dh = cache.k.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(Dh)

    kv_spec = ctx.pspec(
        ("batch", "kv_seq", None, None), cache.k.shape
    )
    sp_spec = ctx.pspec(
        ("batch", "kv_seq" if kv_spec[1] is not None else None),
        cache.slot_pos.shape,
    )
    seq_axes = _seq_axes_of(kv_spec)
    if not seq_axes:
        raise ValueError("cache seq dim is not sharded; use the ref path")
    mesh_sizes = ctx.axis_sizes
    n_seq_shards = 1
    for a in seq_axes:
        n_seq_shards *= mesh_sizes[a]
    S_loc = S_c // n_seq_shards

    def local_fn(q, k_c, v_c, sp, nk, nv, pos):
        # shard rank along the seq sharding (major-to-minor order)
        r = jnp.int32(0)
        for a in seq_axes:
            r = r * mesh_sizes[a] + jax.lax.axis_index(a)
        B_l = q.shape[0]
        bidx = jnp.arange(B_l)

        # --- local cache-slot write -----------------------------------
        if window is not None and S_c <= window:
            slot = pos % S_c
        else:
            slot = jnp.minimum(pos, S_c - 1)
        idx = slot - r * S_loc
        mine = (idx >= 0) & (idx < S_loc)
        safe = jnp.clip(idx, 0, S_loc - 1)
        old_k = k_c[bidx, safe]
        old_v = v_c[bidx, safe]
        old_sp = sp[bidx, safe]
        k_c = k_c.at[bidx, safe].set(
            jnp.where(mine[:, None, None], nk[:, 0], old_k))
        v_c = v_c.at[bidx, safe].set(
            jnp.where(mine[:, None, None], nv[:, 0], old_v))
        sp = sp.at[bidx, safe].set(jnp.where(mine, pos, old_sp))

        # --- local partial softmax -------------------------------------
        qg = q[:, 0].reshape(B_l, Hkv, G, Dh).astype(F32)
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_c.astype(F32)) * scale
        kv_len = pos + 1
        valid = (sp >= 0) & (sp < kv_len[:, None])
        if window is not None:
            valid &= sp > (kv_len[:, None] - 1 - window)
        s = jnp.where(valid[:, None, None], s, NEG_INF)
        m_l = s.max(axis=-1)                                  # (B,Hkv,G)
        p_ = jnp.exp(s - m_l[..., None])
        p_ = jnp.where(valid[:, None, None], p_, 0.0)
        l_l = p_.sum(axis=-1)
        acc = jnp.einsum("bhgk,bkhd->bhgd", p_, v_c.astype(F32))

        # --- LSE combine across seq shards ------------------------------
        m_g = jax.lax.pmax(m_l, seq_axes)
        corr = jnp.exp(m_l - m_g)
        l_g = jax.lax.psum(l_l * corr, seq_axes)
        acc_g = jax.lax.psum(acc * corr[..., None], seq_axes)
        out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
        out = out.reshape(B_l, 1, Hq, Dh).astype(q.dtype)
        return out, k_c, v_c, sp

    q_spec = ctx.pspec(("batch", None, None, None), q.shape)
    nk_spec = ctx.pspec(("batch", None, None, None), new_k.shape)
    pos_spec = ctx.pspec(("batch",), pos.shape)
    from repro.sharding.rules import shard_map_compat
    fn = shard_map_compat(
        local_fn,
        mesh=ctx.mesh,
        in_specs=(q_spec, kv_spec, kv_spec, sp_spec, nk_spec, nk_spec, pos_spec),
        out_specs=(q_spec, kv_spec, kv_spec, sp_spec),
        axis_names=ctx.manual_axes,
        check_vma=False,
    )
    out, k_new, v_new, sp_new = fn(
        q, cache.k, cache.v, cache.slot_pos, new_k, new_v,
        pos.astype(jnp.int32),
    )
    return out, KVSlice(k=k_new, v=v_new, slot_pos=sp_new)
