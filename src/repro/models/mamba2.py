"""Mamba-2 (SSD, state-space duality) blocks.

The scan is computed in the **chunked matmul form** of the SSD paper
[arXiv:2405.21060] — intra-chunk dense matmuls (MXU-friendly on TPU) plus a
cheap inter-chunk recurrence over per-chunk states — not a per-step
sequential scan.  Group dims (ngroups) are kept un-broadcast so B/C are
never materialized per-head.

Bucket-padded (chunked) prefill is EXACT: a (B, S_pad) validity mask makes
pad tokens identity state updates (``dt`` zeroed -> zero log-decay, zero
dt-weighted input) and the causal-conv state snapshots at each row's last
real token — see ``ssd_chunked`` / ``mamba_block``.

Layout (per block):
  in projections  wz, wx : (D, d_inner)   wB, wC : (D, G*N)   wdt : (D, H)
  causal conv (k taps) over [x, B, C] segments (separate weights per segment)
  SSD over heads (H = d_inner / head_dim)
  gated RMSNorm (norm(y * silu(z))), out projection d_inner -> D.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.param import PSpec
from repro.models.layers import rms_norm

F32 = jnp.float32


# --------------------------------------------------------------------------
# specs
# --------------------------------------------------------------------------
def mamba_dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return d_inner, H, s.ngroups, s.d_state, s.d_conv


def mamba_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_inner, H, G, N, K = mamba_dims(cfg)
    return {
        "wz": PSpec((d, d_inner), ("embed", "inner"), ("normal", 0)),
        "wx": PSpec((d, d_inner), ("embed", "inner"), ("normal", 0)),
        "wB": PSpec((d, G * N), ("embed", None), ("normal", 0)),
        "wC": PSpec((d, G * N), ("embed", None), ("normal", 0)),
        "wdt": PSpec((d, H), ("embed", "ssm_heads"), ("normal", 0)),
        "dt_bias": PSpec((H,), ("ssm_heads",), ("dt_bias",), dtype="float32"),
        "A_log": PSpec((H,), ("ssm_heads",), ("alog",), dtype="float32"),
        "D_skip": PSpec((H,), ("ssm_heads",), ("const", 1.0), dtype="float32"),
        "conv_x": PSpec((K, d_inner), (None, "inner"), ("normal", 0)),
        "conv_B": PSpec((K, G * N), (None, None), ("normal", 0)),
        "conv_C": PSpec((K, G * N), (None, None), ("normal", 0)),
        "gate_norm": PSpec((d_inner,), ("inner",), ("const", 1.0)),
        "out_proj": PSpec((d_inner, d), ("inner", "embed"), ("normal", 0)),
    }


class MambaState(NamedTuple):
    """Decode-time state for one layer."""
    conv: jnp.ndarray   # (B, K-1, d_inner + 2*G*N) trailing pre-conv inputs
    ssm: jnp.ndarray    # (B, H, P, N) fp32


def init_mamba_state(cfg: ArchConfig, batch: int, dtype) -> MambaState:
    d_inner, H, G, N, K = mamba_dims(cfg)
    P_ = cfg.ssm.head_dim
    return MambaState(
        conv=jnp.zeros((batch, K - 1, d_inner + 2 * G * N), dtype),
        ssm=jnp.zeros((batch, H, P_, N), F32),
    )


# --------------------------------------------------------------------------
# SSD chunked scan (pure jnp oracle; the Pallas kernel mirrors this)
# --------------------------------------------------------------------------
def _segsum(x):
    """x: (..., Q) log-decays -> (..., Q, Q) with [i,j] = sum_{j<k<=i} x_k."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_tiling_chunk(S: int, chunk: int) -> int:
    """Largest usable SSD chunk that tiles ``S`` exactly.

    Serve buckets are multiples of the PREFILL chunk, not necessarily of
    the SSD chunk, so the chunk degrades to ``gcd(S, chunk)`` when it
    doesn't divide ``S``.  ``S`` and ``chunk`` are static, so the warning
    fires at trace time — a degenerate gcd (odd S -> Q=1 = per-step
    recurrence) is loud, not silent.  The single tiling policy shared by
    this oracle and the Pallas wrapper (``kernels.ssd_scan.ops.ssd``).
    """
    Q = min(chunk, S)
    if S % Q:
        import math
        import warnings
        Q = math.gcd(S, Q)
        warnings.warn(
            f"ssd: S={S} is not a multiple of chunk={chunk}; "
            f"degrading to chunk {Q}", stacklevel=3)
    return Q


def ssd_chunked(x, dt, A_log, B_in, C_in, *, chunk: int,
                initial_state: Optional[jnp.ndarray] = None,
                mask: Optional[jnp.ndarray] = None,
                checkpoints: bool = False):
    """SSD in chunked matmul form.

    x: (B, S, H, P)    dt: (B, S, H) (post-softplus, >0)
    A_log: (H,) (A = -exp(A_log))    B_in, C_in: (B, S, G, N)
    mask: optional (B, S) bool validity mask.  A masked step has its ``dt``
    forced to zero, so its log-decay is 0 (state decay = identity) and its
    dt-weighted input is 0 (no state contribution): the recurrence passes
    through pad positions untouched and ``final_state`` equals the state
    at each row's last REAL token.  Outputs at masked positions are
    garbage and must not be read.
    Returns (y (B,S,H,P), final_state (B,H,P,N) fp32); with
    ``checkpoints`` also the state at EVERY interior chunk boundary —
    ``ck[:, c]`` is the state after chunk ``c`` (positions ``< (c+1) *
    chunk``), shape (B, nc, H, P, N) fp32 — the inter-chunk recurrence
    already computes these, so emitting them is free of extra matmuls.
    """
    if mask is not None:
        dt = jnp.where(mask[..., None], dt, jnp.zeros_like(dt))
    Bb, S, H, P_ = x.shape
    G, N = B_in.shape[2], B_in.shape[3]
    HG = H // G
    Q = ssd_tiling_chunk(S, chunk)
    nc = S // Q

    A = -jnp.exp(A_log.astype(F32))                       # (H,)
    dA = dt.astype(F32) * A                               # (B,S,H) log-decay
    xw = (x.astype(F32) * dt.astype(F32)[..., None])      # dt-weighted input

    # chunk views; head dim split into (G, HG)
    xc = xw.reshape(Bb, nc, Q, G, HG, P_)
    dAc = dA.reshape(Bb, nc, Q, H).transpose(0, 3, 1, 2)  # (B,H,nc,Q)
    dAc = dAc.reshape(Bb, G, HG, nc, Q)
    Bc = B_in.astype(F32).reshape(Bb, nc, Q, G, N)
    Cc = C_in.astype(F32).reshape(Bb, nc, Q, G, N)

    A_cs = jnp.cumsum(dAc, axis=-1)                       # (B,G,HG,nc,Q)

    # 1) intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dAc))                             # (B,G,HG,nc,Q,Q)
    scores = jnp.einsum("bcqgn,bckgn->bgcqk", Cc, Bc)     # (B,G,nc,Q,K)
    M = scores[:, :, None] * L                            # (B,G,HG,nc,Q,K)
    y_diag = jnp.einsum("bghcqk,bckghp->bcqghp", M, xc)

    # 2) per-chunk states
    decay_states = jnp.exp(A_cs[..., -1:] - A_cs)         # (B,G,HG,nc,Q)
    states = jnp.einsum(
        "bcqgn,bghcq,bcqghp->bcghpn", Bc, decay_states, xc
    )                                                     # (B,nc,G,HG,P,N)

    # 3) inter-chunk recurrence (associative scan over chunks)
    chunk_decay = jnp.exp(A_cs[..., -1])                  # (B,G,HG,nc)
    if initial_state is None:
        initial_state = jnp.zeros((Bb, H, P_, N), F32)
    init = initial_state.reshape(Bb, G, HG, P_, N)

    a_seq = chunk_decay.transpose(3, 0, 1, 2)[..., None, None]  # (nc,B,G,HG,1,1)
    s_seq = states.transpose(1, 0, 2, 3, 4, 5)                  # (nc,B,G,HG,P,N)

    def combine(c1, c2):
        a1, h1 = c1
        a2, h2 = c2
        return a1 * a2, h1 * a2 + h2

    a_all, h_all = jax.lax.associative_scan(combine, (a_seq, s_seq), axis=0)
    # state entering chunk c = init*prod(a<=c-1) + h_all[c-1]
    prev = jnp.concatenate(
        [jnp.zeros_like(h_all[:1]), h_all[:-1]], axis=0
    ) + jnp.concatenate(
        [jnp.ones_like(a_all[:1]), a_all[:-1]], axis=0
    ) * init[None]
    prev = prev.transpose(1, 0, 2, 3, 4, 5)               # (B,nc,G,HG,P,N)
    final = (h_all[-1] + a_all[-1] * init).reshape(Bb, H, P_, N)

    # 4) state -> output
    out_decay = jnp.exp(A_cs)                             # (B,G,HG,nc,Q)
    y_off = jnp.einsum(
        "bcqgn,bcghpn,bghcq->bcqghp", Cc, prev, out_decay
    )

    y = (y_diag + y_off).reshape(Bb, S, H, P_)
    if checkpoints:
        # state AFTER chunk c = h_all[c] + a_all[c] * init — the same
        # associative-scan outputs the recurrence is built from
        ck = (h_all + a_all * init[None]).transpose(1, 0, 2, 3, 4, 5)
        return y, final, ck.reshape(Bb, nc, H, P_, N)
    return y, final


def ssd_decode_step(state, x, dt, A_log, B_in, C_in):
    """One-token SSD update.  x: (B,1,H,P); state: (B,H,P,N) fp32."""
    Bb, _, H, P_ = x.shape
    G, N = B_in.shape[2], B_in.shape[3]
    HG = H // G
    A = -jnp.exp(A_log.astype(F32))
    dA = jnp.exp(dt[:, 0].astype(F32) * A)                # (B,H)
    xg = (x[:, 0].astype(F32) * dt[:, 0][..., None]).reshape(Bb, G, HG, P_)
    dBx = jnp.einsum("bgn,bghp->bghpn", B_in[:, 0].astype(F32), xg)
    new_state = state * dA[..., None, None] + dBx.reshape(Bb, H, P_, N)
    y = jnp.einsum("bgn,bghpn->bghp", C_in[:, 0].astype(F32),
                   new_state.reshape(Bb, G, HG, P_, N))
    return y.reshape(Bb, 1, H, P_), new_state


# --------------------------------------------------------------------------
# full block
# --------------------------------------------------------------------------
def _causal_conv(seq, w, conv_state=None, length=None, boundary_every=None):
    """Depthwise causal conv.  seq: (B,S,C); w: (K,C).  Returns (y, new_state).

    ``length`` (B,) optional: snapshot the returned conv state at each
    row's last REAL token instead of the end of the (padded) sequence —
    ``new_state[b]`` holds the K-1 inputs preceding position ``length[b]``
    (zero left-padding included for rows shorter than K-1), exactly what a
    decode step at position ``length[b]`` must see.

    ``boundary_every`` (static int R) optional: additionally return the
    conv windows at every interior boundary — ``bstates[:, c]`` holds the
    K-1 inputs preceding position ``(c+1)*R``, shape (B, S//R, K-1, C) —
    what a suffix continuation restored from a chunk-boundary snapshot
    must see.  Boundary positions are static, so these are plain slices.
    """
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((seq.shape[0], K - 1, seq.shape[2]), seq.dtype)
    else:
        pad = conv_state.astype(seq.dtype)
    full = jnp.concatenate([pad, seq], axis=1)            # (B, S+K-1, C)
    y = sum(full[:, i : i + seq.shape[1]] * w[i] for i in range(K))
    if K <= 1:
        new_state = jnp.zeros_like(pad)
    elif length is None:
        new_state = full[:, -(K - 1):]
    else:
        # seq position p lives at full index p + K-1, so the window of the
        # K-1 inputs BEFORE position length[b] is full[b, length[b] : length[b]+K-1]
        idx = length[:, None].astype(jnp.int32) + jnp.arange(K - 1)[None, :]
        new_state = jnp.take_along_axis(full, idx[:, :, None], axis=1)
    if boundary_every is None:
        return y, new_state
    R = boundary_every
    bstates = jnp.stack(
        [full[:, bp : bp + K - 1] for bp in range(R, seq.shape[1] + 1, R)],
        axis=1)                                           # (B, S//R, K-1, C)
    return y, new_state, bstates


def mamba_block(p, x, cfg: ArchConfig, *, mode: str,
                state: Optional[MambaState] = None,
                mask: Optional[jnp.ndarray] = None,
                ckpt_every: Optional[int] = None
                ) -> Tuple[jnp.ndarray, Optional[MambaState]]:
    """x: (B, S, D).  Returns (y (B,S,D), new state or None).

    ``mask`` (B, S) bool (prefill/extend): marks the REAL tokens of each
    bucket-padded row.  Masked (pad) positions make no state update
    (``dt`` zeroed inside :func:`ssd_chunked`) and the conv state is
    snapshotted at each row's last real token, so the returned
    :class:`MambaState` is bit-identical to having prefilled each row at
    its exact length — the contract chunked prefill needs.

    ``mode="extend"`` continues from ``state`` (the deepest restored
    snapshot): conv state seeds the left pad, ssm state seeds the
    recurrence, and the returned state is snapshotted at each row's last
    real SUFFIX token.

    ``ckpt_every`` (prefill only): also emit a :class:`MambaState` of
    per-boundary checkpoints with a chunk axis after batch — ``conv``
    (B, nb, K-1, C), ``ssm`` (B, nb, H, P, N) with ``nb = S //
    ckpt_every`` — the sharable cache payload for this family.  The
    return value becomes ``(final_state, checkpoints)``.
    """
    s = cfg.ssm
    d_inner, H, G, N, K = mamba_dims(cfg)
    P_ = s.head_dim
    Bb, S, _ = x.shape

    z = x @ p["wz"]                                        # (B,S,d_inner)
    xs = x @ p["wx"]
    Bm = x @ p["wB"]                                       # (B,S,G*N)
    Cm = x @ p["wC"]
    dt_raw = x @ p["wdt"]                                  # (B,S,H)
    dt = jax.nn.softplus(dt_raw.astype(F32) + p["dt_bias"].astype(F32))

    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)
    conv_in = (state.conv if (state is not None
                              and mode in ("decode", "extend")) else None)
    length = None
    if mask is not None and mode in ("prefill", "extend"):
        length = jnp.sum(mask.astype(jnp.int32), axis=1)
    conv_ck = None
    if ckpt_every is not None and mode == "prefill":
        xbc_conv, new_conv, conv_ck = _causal_conv(
            xbc, conv_w, conv_in, length=length, boundary_every=ckpt_every)
    else:
        xbc_conv, new_conv = _causal_conv(xbc, conv_w, conv_in, length=length)
    xbc_conv = jax.nn.silu(xbc_conv)
    xs_c = xbc_conv[..., :d_inner]
    Bm_c = xbc_conv[..., d_inner : d_inner + G * N].reshape(Bb, S, G, N)
    Cm_c = xbc_conv[..., d_inner + G * N :].reshape(Bb, S, G, N)
    xh = xs_c.reshape(Bb, S, H, P_)

    if mode == "decode":
        assert state is not None
        y, new_ssm = ssd_decode_step(state.ssm, xh, dt, p["A_log"], Bm_c, Cm_c)
        new_state = MambaState(conv=new_conv, ssm=new_ssm)
    else:
        init = state.ssm if state is not None else None
        if conv_ck is not None:
            # checkpoint chunks must land on SSD chunk boundaries, so the
            # scan runs at the (smaller) checkpoint granularity — exact at
            # any chunk size, only the matmul tiling changes
            y, final, ssm_ck = ssd_chunked(
                xh, dt, p["A_log"], Bm_c, Cm_c, chunk=ckpt_every,
                initial_state=init, mask=mask, checkpoints=True,
            )
            return_ck = MambaState(conv=conv_ck, ssm=ssm_ck)
        else:
            y, final = ssd_chunked(
                xh, dt, p["A_log"], Bm_c, Cm_c, chunk=s.chunk,
                initial_state=init, mask=mask,
            )
        new_state = (
            MambaState(conv=new_conv, ssm=final)
            if mode in ("prefill", "extend") else None
        )
        if conv_ck is not None:
            new_state = (new_state, return_ck)

    y = y + xh.astype(F32) * p["D_skip"][None, None, :, None].astype(F32)
    y = y.reshape(Bb, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype),
                 p["gate_norm"], cfg.rms_eps)
    return y @ p["out_proj"], new_state
