"""Gradient compression for data-parallel reduction (int8 + error feedback).

On real hardware the compressed reduce runs as a manual ``shard_map`` over
the DP axes (``compressed_psum``): each rank quantizes its local shard to
int8 with a per-tensor scale, the all-reduce moves 4x fewer bytes, and the
dequantization error is carried in an error-feedback buffer so the scheme
stays unbiased over steps (1-bit Adam / EF-SGD lineage).

``apply_ef_compression`` is the pjit-composable form used inside the train
step: quantize->dequantize(+EF) of the *global* gradient, which is
numerically identical to compressing before a linear psum.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
INT8_MAX = 127.0


def quantize_int8(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x.astype(F32))) / INT8_MAX
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(F32) * scale


def apply_ef_compression(grads, ef_state):
    """grads, ef_state: matching pytrees.  Returns (compressed grads, new ef)."""
    def one(g, e):
        g32 = g.astype(F32) + e.astype(F32)
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), (g32 - deq).astype(e.dtype)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(tdef, [o[0] for o in out]),
        jax.tree.unflatten(tdef, [o[1] for o in out]),
    )


def init_ef_state(params, dtype: str = "bfloat16"):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.dtype(dtype)), params)


def compressed_psum(x, axis_name, ef):
    """Manual-collective form: quantize local shard, psum int32, dequantize.

    Run under ``shard_map``.  The wire format is int8 (psum accumulates in
    int32); per-rank scales are max-combined so dequantization is shared.
    Returns (reduced array, new error-feedback buffer).
    """
    x32 = x.astype(F32) + ef.astype(F32)
    scale = jnp.max(jnp.abs(x32)) / INT8_MAX
    scale = jnp.maximum(jax.lax.pmax(scale, axis_name), 1e-12)
    q = jnp.clip(jnp.round(x32 / scale), -INT8_MAX, INT8_MAX)
    local_deq = q * scale
    new_ef = (x32 - local_deq).astype(ef.dtype)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return (total.astype(F32) * scale), new_ef
