"""AdamW + schedules, implemented from scratch (optax is not available).

States are plain pytrees so they shard exactly like their parameters
(m/v inherit the param's PartitionSpec) — ZeRO-style optimizer sharding
falls out of the param sharding rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # memory knobs for the big archs
    m_dtype: str = "float32"
    v_dtype: str = "float32"


class AdamState(NamedTuple):
    step: jnp.ndarray       # () int32
    m: Any                  # pytree like params
    v: Any


def cosine_schedule(cfg: OptConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def sched(step):
        step = step.astype(F32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
        return cfg.lr * warm * frac
    return sched


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(F32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype), tree), norm


def init_adam_state(params, cfg: OptConfig) -> AdamState:
    m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.m_dtype)), params)
    v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.v_dtype)), params)
    return AdamState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def abstract_adam_state(abstract_params, cfg: OptConfig) -> AdamState:
    m = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(cfg.m_dtype)), abstract_params
    )
    v = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(cfg.v_dtype)), abstract_params
    )
    return AdamState(step=jax.ShapeDtypeStruct((), jnp.int32), m=m, v=v)


def adam_state_pspecs(param_pspecs) -> AdamState:
    from jax.sharding import PartitionSpec as P
    return AdamState(
        step=P(),
        m=jax.tree.map(lambda s: s, param_pspecs),
        v=jax.tree.map(lambda s: s, param_pspecs),
    )


def adamw_update(
    params, grads, state: AdamState, cfg: OptConfig
) -> Tuple[Any, AdamState, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    sched = cosine_schedule(cfg)
    lr = sched(step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(F32)
    bc2 = 1 - b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g32 = g.astype(F32)
        m_new = b1 * m.astype(F32) + (1 - b1) * g32
        v_new = b2 * v.astype(F32) + (1 - b2) * jnp.square(g32)
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        # weight decay on matrices only (ndim >= 2), standard practice
        if p.ndim >= 2 and cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(F32)
        p_new = (p.astype(F32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr,
    }
