"""Train step builder: loss -> grads (microbatched) -> AdamW update.

Gradient accumulation runs as a ``lax.scan`` over microbatches so activation
memory is bounded by one microbatch; the grad buffers stay sharded like the
params (ZeRO).  Optional int8 error-feedback gradient compression slots in
between accumulation and the optimizer.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train import grad_compress
from repro.train.optimizer import (
    AdamState,
    OptConfig,
    abstract_adam_state,
    adam_state_pspecs,
    adamw_update,
    init_adam_state,
)

F32 = jnp.float32


class TrainState(NamedTuple):
    params: Any
    opt: AdamState
    ef: Optional[Any] = None      # error-feedback buffers (grad compression)


def init_train_state(model: Model, rng, opt_cfg: OptConfig, *, compress: bool = False) -> TrainState:
    params = model.init(rng)
    return TrainState(
        params=params,
        opt=init_adam_state(params, opt_cfg),
        ef=grad_compress.init_ef_state(params) if compress else None,
    )


def abstract_train_state(model: Model, opt_cfg: OptConfig, *, compress: bool = False) -> TrainState:
    params = model.abstract_params()
    return TrainState(
        params=params,
        opt=abstract_adam_state(params, opt_cfg),
        ef=jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.bfloat16), params)
        if compress else None,
    )


def train_state_pspecs(model: Model, *, compress: bool = False) -> TrainState:
    pp = model.params_pspecs()
    return TrainState(
        params=pp,
        opt=adam_state_pspecs(pp),
        ef=jax.tree.map(lambda s: s, pp) if compress else None,
    )


def _split_microbatches(batch, n: int):
    """(B, ...) -> (n, B/n, ...) for every leaf."""
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def resolve_microbatch(want: int, global_batch: int, dp: int) -> int:
    """Largest n <= want with n | B and dp | (B/n) (shardable microbatches)."""
    for n in range(min(want, max(global_batch // max(dp, 1), 1)), 0, -1):
        if global_batch % n == 0 and (global_batch // n) % max(dp, 1) == 0:
            return n
    return 1


def build_train_step(
    model: Model, opt_cfg: OptConfig, *, compress: bool = False
) -> Callable[[TrainState, Any], Tuple[TrainState, dict]]:
    """Returns train_step(state, batch) -> (state, metrics).  jit-ready."""

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, dict]:
        params = state.params
        global_batch = jax.tree.leaves(batch)[0].shape[0]
        n_micro = resolve_microbatch(
            max(model.cfg.microbatch, 1), global_batch, model.ctx.dp_size()
        )

        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs = _split_microbatches(batch, n_micro)

            def body(carry, mb):
                acc = carry
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(F32) / n_micro, acc, g
                )
                return acc, (l, m)

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            grads, (losses, metricses) = jax.lax.scan(body, zeros, mbs)
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), metricses)

        ef = state.ef
        if compress:
            grads, ef = grad_compress.apply_ef_compression(grads, ef)

        new_params, new_opt, opt_metrics = adamw_update(params, grads, state.opt, opt_cfg)
        metrics = dict(metrics, **opt_metrics, loss_step=loss)
        return TrainState(params=new_params, opt=new_opt, ef=ef), metrics

    return train_step
