"""KVPool — payload-polymorphic cache memory with radix-tree prefix sharing.

The paper's memory model applied to the serving cache plane: each subOS
(here: each decode cell) owns an *isolated* arena of physical memory, and
the supervisor-of-the-cache (the pool) grants *shared* read-only mappings
only on demand.  Concretely:

* **Isolate first** — every request's KV lives in page-granular private
  allocations (pages of ``page_size`` positions spanning all layers); a
  block table maps ``(slot, logical_page) -> physical_page``, and a slot
  only ever holds the pages its request actually reached — no more dense
  ``max_len`` slabs committed to 12-token prompts.
* **Then share** — immutable, fully-written prompt pages are *interned*
  into a :class:`PrefixTree` (a radix tree over ``page_size``-token
  chunks) with refcounts.  A new request whose prompt shares a cached
  prefix maps those pages read-only (copy-free), skips their prefill
  compute entirely (only the suffix runs, via ``Model.prefill_extend``),
  and allocates private pages from its divergence point.  The partial
  boundary page is the copy-on-write edge: it is always private, so
  decode writes can never touch a shared page.
* **Revoke on pressure** — admission *blocks* (requests stay queued) when
  the pool is exhausted, and interned pages whose refcount has dropped to
  zero are LRU-evicted to make room, exactly like the paper's
  supervisor-mediated reclamation of granted-but-idle resources (and in
  the spirit of XOS's application-defined memory mapping and OSmosis'
  explicit sharing-set semantics — see PAPERS.md).

Exactness: for causal-KV families the K/V at position ``i`` depends only
on tokens ``<= i`` (plus, for encdec, the request's source features — the
tree roots are keyed by a source digest), so an interned page written by
one request is bit-identical to what any other request with the same
prefix would have computed; chunk-granular matching means partial matches
are misses.

**The payload protocol.**  The unit of sharing is a typed *payload*, not
hard-coded pages — the OSmosis argument (arXiv:2309.09291) that
isolation/sharing policy should be expressed over a uniform resource
abstraction.  A :class:`PrefixTree` node's ``page`` field is an integer
HANDLE whose meaning is the pool's ``payload_kind``:

* ``"page"`` — a physical page id in the KV arena (causal-KV families:
  dense/vlm/moe/encdec), the classic paged plane above;
* ``"snapshot"`` — a key into the pool's snapshot store holding
  ``{"state": <1-row recurrent-state tree at the chunk boundary>,
  "pages": [<this chunk's shared-attention KV page stacks>]}`` for
  recurrent families (ssm/hybrid).  Node ``d-1``'s state is the FULL
  state after the depth-``d`` prefix (Mamba state folds history, so each
  node stores one boundary checkpoint, not a delta); ``"pages"`` carries
  only chunk ``d-1``'s KV positions (empty for pure ssm), so a chain's
  KV grows linearly with depth.  A warm prompt restores the deepest
  node's state (plus the chain's concatenated KV pages) into a dense
  cache row and prefill-extends only the suffix.

Every mechanism above the handle — refcounts, LRU eviction, tenant quota
pockets, COW admission, export/import migration — is payload-agnostic
and identical for both kinds.  The three-way capability predicate is
:meth:`KVPool.capability` (``"paged" | "snapshot" | "none"``): the ONLY
place family reach into the cache plane is decided.  Digest
compatibility: both kinds key tree nodes by the same ``page_size``-token
chunks, so ``serve.cacheplane.chunk_digests`` / ``advertise`` /
``PrefixIndex`` routing and ``migrate_prefixes`` work unchanged over
snapshot pools — the cluster plane never looks inside a payload.

Tenancy applies the same subOS model one level up, to *users* of one
pool.  Each tenant is a little subOS of the cache plane:

* its **page quota** is a physical-resource partition — ``quotas``
  splits the arena into per-tenant pockets (plus a shared commons for
  quota-less tenants), every allocated page is charged to exactly one
  pocket, and a tenant over its pocket can only reclaim its *own*
  refcount-0 cache, so it can exhaust its quota but never the pool;
* its **prefix namespace** is an address space — tree roots are keyed
  per tenant (:func:`request_ctx_key`), so one tenant's prompts never
  match another's pages;
* the **public namespace** is the supervisor-mediated memory grant — a
  prompt marked public interns under the shared ``__public__`` root
  (charged to the commons), and any granted tenant may map those pages
  read-only (:func:`public_ctx_key` fallback in :meth:`KVPool.lease`).
  A foreign (public) hit never lets the tenant intern *into* the public
  namespace: its suffix pages stay private, so the grant is strictly
  read-only — sharing is something the spec grants, never ambient.

The decode/extend hot path is NATIVELY paged — the block table reaches
the kernels instead of being flattened away above them.  The calling
convention (``build_paged_serve_step`` / ``build_paged_extend_step``):
the step function takes ``(params, arena, scales, resident, block_table,
batch, rng)``; ``cache_utils.paged_view`` wraps each positional arena
node in a :class:`~repro.models.layers.PagedKVCache` carrying the whole
``(num_pages, page_size, L, Hkv, Dh)`` arena plus the batch's
``(B, n_logical)`` block table, and ``Model.decode`` /
``Model.prefill_extend`` thread that view into every attention layer
(the arena rides the layer-scan carry; each step rebinds the ``layer``
index).  Attention writes the current token(s) straight into their
physical pages — sentinel entries drop the write — and the paged Pallas
kernels (``kernels/decode_attention``, ``kernels/flash_attention``) walk
each row's pages directly in the arena via scalar-prefetched block-table
index maps; on CPU an equivalent jnp page gather feeds the dense
reference attention, bit-identical to the pre-paged path.  No contiguous
per-slot KV copy is ever materialized in steady state:
``gather_pages``/``scatter_current_pages`` survive only on the
export/import/migration and cold-install paths.  With
``kv_dtype="int8"`` the arena stores int8 pages with per-(page, layer)
scales — quantize on page write, dequantize in-kernel — doubling pool
capacity at documented (small, non-exact) accuracy cost.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cache_utils import (
    clean_arena_pages,
    dequantize_page,
    extract_paged,
    extract_row_pages,
    install_cross_memory,
    kv_node_axes,
    kv_position_bytes,
    page_arena,
    paged_view,
    quantize_page,
    read_arena_pages,
    recurrent_state_bytes,
    strip_kv_nodes,
    write_arena_pages,
)
from repro.models.layers import KVSlice
from repro.serve.serve_step import bucket_len, sample_tokens
from repro.serve.tenancy import COMMONS, DEFAULT_TENANT, PUBLIC


class PoolExhausted(RuntimeError):
    """No free or evictable page is left — the caller must requeue."""


def _src_part(req) -> Optional[tuple]:
    """Source-feature digest component of a ctx key (encdec decoder KV
    depends on the request's source features as well as its tokens, so
    prompts may only share pages when the sources are byte-identical)."""
    src = getattr(req, "src", None)
    if src is None:
        return None
    a = np.ascontiguousarray(np.asarray(src))
    return ("src", a.shape, hashlib.sha1(a.tobytes()).hexdigest())


def request_ctx_key(req) -> Optional[tuple]:
    """Prefix-tree root key for a request: its tenant namespace plus any
    non-token context.

    The default tenant's private namespace keeps the pre-tenancy keys
    (None, or the bare source digest) so a single-tenant deployment is
    byte-identical to the old stack; a request marked ``public`` lives
    under the shared ``__public__`` root; any other tenant gets a
    private ``("tenant", name)`` root no other tenant's lookups can
    reach."""
    src = _src_part(req)
    if getattr(req, "public", False):
        return ("public",) if src is None else ("public", src)
    tenant = getattr(req, "tenant", DEFAULT_TENANT)
    if tenant != DEFAULT_TENANT:
        return (("tenant", tenant) if src is None
                else ("tenant", tenant, src))
    return src


def public_ctx_key(req) -> Optional[tuple]:
    """The public-namespace variant of a request's ctx key — the root a
    granted tenant may additionally match READ-ONLY (the supervisor
    grant).  None when the request already lives there."""
    if getattr(req, "public", False):
        return None
    src = _src_part(req)
    return ("public",) if src is None else ("public", src)


class _Node:
    """One interned page: a ``page_size``-token chunk under its parent."""

    __slots__ = ("parent", "key", "children", "page", "refs", "last_used",
                 "owner")

    def __init__(self, parent, key, page, owner=None):
        self.parent = parent
        self.key = key                  # tuple of page_size token ids
        self.children: Dict[tuple, "_Node"] = {}
        self.page = page                # physical page id (None for roots)
        self.refs = 0
        self.last_used = 0
        self.owner = owner              # tenant / PUBLIC the page bills to


class PrefixTree:
    """Radix tree over ``page_size``-token chunks with refcounted pages.

    Nodes are interned *full* pages only — a prompt's partial tail chunk
    never enters the tree, so every match is exact by construction.
    Refcounts track live users (slots holding the page mapped, or
    in-flight leases); refcount-0 nodes are cache, reclaimable LRU."""

    def __init__(self, page_size: int):
        self.page_size = page_size
        self._roots: Dict[Optional[tuple], _Node] = {}
        self._clock = 0
        self.interned = 0               # live interned (non-root) nodes

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def root(self, ctx_key) -> _Node:
        if ctx_key not in self._roots:
            self._roots[ctx_key] = _Node(None, None, None)
        return self._roots[ctx_key]

    def match(self, prompt, ctx_key) -> List[_Node]:
        """Longest chain of interned full-chunk nodes matching ``prompt``
        — capped so at least one suffix token is left to compute (the
        extend invocation must produce the first output token)."""
        P = self.page_size
        L = len(prompt)
        node = self._roots.get(ctx_key)
        out: List[_Node] = []
        if node is None:
            return out
        for lp in range(max(L - 1, 0) // P):
            child = node.children.get(tuple(int(t) for t in
                                            prompt[lp * P:(lp + 1) * P]))
            if child is None:
                break
            out.append(child)
            node = child
        return out

    def acquire(self, nodes: List[_Node]):
        now = self._tick()
        for n in nodes:
            n.refs += 1
            n.last_used = now

    def release(self, nodes: List[_Node]):
        now = self._tick()
        for n in nodes:
            assert n.refs > 0, "refcount underflow on an interned page"
            n.refs -= 1
            n.last_used = now

    def insert(self, parent: _Node, key: tuple, page: int,
               owner=None) -> _Node:
        assert key not in parent.children
        node = _Node(parent, key, page, owner)
        node.last_used = self._tick()
        parent.children[key] = node
        self.interned += 1
        return node

    def _walk(self):
        stack = list(self._roots.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n.page is not None:
                yield n

    def evictable_pages(self, visible=None) -> int:
        """Pages reclaimable right now: interned nodes whose whole
        subtree is refcount-0 (evicting leaf-upward never strands a
        live descendant's prefix).  One ITERATIVE bottom-up pass — each
        node's pinned flag is computed once, children before parents;
        no recursion, so page chains as deep as max_len/page_size (long
        shared prompts) can never blow the interpreter stack.  With
        ``visible`` (a node predicate), count only nodes the caller may
        reclaim — the per-tenant quota view."""
        total = 0
        pinned: Dict[int, bool] = {}
        for root in self._roots.values():
            stack = [(root, False)]
            while stack:
                n, seen = stack.pop()
                if not seen:
                    stack.append((n, True))
                    stack.extend((c, False) for c in n.children.values())
                    continue
                p = n.refs > 0 or any(pinned[id(c)]
                                      for c in n.children.values())
                pinned[id(n)] = p
                if (n.page is not None and not p
                        and (visible is None or visible(n))):
                    total += 1
        return total

    def evict_lru(self, visible=None) -> Optional[Tuple[_Node, int]]:
        """Detach the least-recently-used evictable LEAF node; returns
        (node, freed page id) or None when nothing is evictable.  A
        childless node's subtree is itself, so evictability is just its
        own refcount.  ``visible`` restricts candidates to nodes the
        requester may reclaim (its own pocket's cache)."""
        best: Optional[_Node] = None
        for n in self._walk():
            if (n.refs == 0 and not n.children
                    and (visible is None or visible(n))
                    and (best is None or n.last_used < best.last_used)):
                best = n
        if best is None:
            return None
        del best.parent.children[best.key]
        self.interned -= 1
        return best, best.page


@dataclasses.dataclass
class PrefixLease:
    """An acquired (incref'd) chain of shared prefix nodes.

    Held from lookup until the pages are mapped into a slot (ownership
    transfers to the slot) or the request is abandoned (release).
    ``foreign`` marks a chain matched in a namespace the request does
    not own (the public grant): its pages map read-only and the slot's
    suffix never interns under them."""

    nodes: List[_Node]
    page_size: int
    released: bool = False
    foreign: bool = False

    @property
    def pages(self) -> int:
        return len(self.nodes)

    @property
    def tokens(self) -> int:
        return len(self.nodes) * self.page_size


def _write_pages_q(arena: list, scales: list, page_ids, stacks: list):
    """``write_arena_pages`` for an int8 arena: quantize each float page
    stack per (page, layer) and update the scale tables alongside."""
    idx = jnp.asarray(page_ids, jnp.int32)
    new_arena, new_scales = [], []
    for a, (ks, vs), s in zip(arena, scales, stacks):
        kq, ksc = quantize_page(s.k, keep_axes=(0, 2))
        vq, vsc = quantize_page(s.v, keep_axes=(0, 2))
        new_arena.append(KVSlice(
            k=a.k.at[idx].set(kq), v=a.v.at[idx].set(vq),
            slot_pos=a.slot_pos.at[idx].set(s.slot_pos)))
        new_scales.append((ks.at[idx].set(ksc), vs.at[idx].set(vsc)))
    return new_arena, new_scales


def _clean_pages_q(arena: list, scales: list, page_ids):
    """``clean_arena_pages`` for an int8 arena: also zero the recycled
    pages' scales so the lazy in-place scale init sees them untouched."""
    idx = jnp.asarray(page_ids, jnp.int32)
    arena = clean_arena_pages(arena, idx)
    scales = [(ks.at[idx].set(0.0), vs.at[idx].set(0.0))
              for ks, vs in scales]
    return arena, scales


class KVPool:
    """Page-granular KV arena + block table + prefix tree for one cell.

    Two deployment shapes share this class:

    * a *decode* pool (``slots`` > 0) backs a ``ContinuousBatcher``: the
      block table is the storage plane its jitted decode step reads
      through, and slot admission reserves a private-page *pocket* up
      front (worst case ``ceil((prompt + max_new) / page_size)`` minus
      the shared prefix) so mid-decode page-boundary growth can never
      fail — admission is the single choke point that blocks on
      exhaustion;
    * a *prefill* pool (``slots`` == 0) backs a ``PrefillWorker``: no
      block table traffic, just the tree + arena as a prefix cache that
      lets warm prompts skip their shared chunks' prefill compute.
    """

    def __init__(self, model, *, max_len: int, page_size: int = 16,
                 slots: int = 0, num_pages: Optional[int] = None,
                 accounting=None, quotas: Any = None,
                 kv_dtype: Optional[str] = None):
        if model.supports_paged_kv:
            self.payload_kind = "page"
        elif getattr(model, "supports_snapshot_state", False):
            self.payload_kind = "snapshot"
        else:
            raise ValueError(
                f"family {model.cfg.family!r} has no shareable cache "
                f"payload (neither paged KV nor state snapshots)")
        if max_len % page_size:
            raise ValueError(f"max_len={max_len} not a multiple of "
                             f"page_size={page_size}")
        self.model = model
        self.max_len = max_len
        self.page_size = page_size
        self.slots = slots
        self.n_logical = max_len // page_size
        self.num_pages = int(num_pages if num_pages is not None else
                             (slots + 2) * self.n_logical if slots
                             else 8 * self.n_logical)
        if self.num_pages < self.n_logical:
            raise ValueError("pool smaller than one request's worst case")
        self.template = model.cache_specs(1, max_len)
        self.axes = kv_node_axes(model, 1, max_len)
        # a warm hit skips BOTH the prefix KV bytes (hybrid shared
        # attention; zero for pure ssm) and, amortized per position, the
        # boundary state checkpoints the handoff no longer ships
        self.position_bytes = kv_position_bytes(model, max_len)
        if self.payload_kind == "snapshot":
            self.position_bytes += (
                recurrent_state_bytes(model, max_len) // page_size)
        # snapshot store: handle -> interned payload pytree.  Handles are
        # drawn from the same free list / quota / eviction machinery as
        # physical page ids — only the backing storage differs.
        self._snaps: Dict[int, Any] = {}
        if self.payload_kind == "snapshot":
            if kv_dtype is not None:
                raise ValueError(
                    "snapshot pools hold float state payloads; kv_dtype "
                    "quantization applies to page arenas only")
            self.arena = []
            self.kv_scales = None
        else:
            self.arena = page_arena(model, self.num_pages, page_size)
            if kv_dtype is None:
                self.kv_scales = None
            elif kv_dtype == "int8":
                # int8 page scaffolding: k/v store int8 with one f32 scale
                # per (page, layer) per tensor — quantized on page write,
                # dequantized in-kernel on the paged hot path (and on
                # read_pages / export, so migration round-trips via floats)
                self.arena = [KVSlice(k=jnp.zeros(a.k.shape, jnp.int8),
                                      v=jnp.zeros(a.v.shape, jnp.int8),
                                      slot_pos=a.slot_pos)
                              for a in self.arena]
                self.kv_scales = [
                    (jnp.zeros((self.num_pages, a.k.shape[2]), jnp.float32),
                     jnp.zeros((self.num_pages, a.k.shape[2]), jnp.float32))
                    for a in self.arena]
            else:
                raise ValueError(f"unsupported kv_dtype {kv_dtype!r}")
        self.kv_dtype = kv_dtype
        self.sentinel = self.num_pages          # unmapped block-table entry
        self.block_table = np.full((max(slots, 1), self.n_logical),
                                   self.sentinel, np.int32)
        self.tree = PrefixTree(page_size)
        self.free: deque = deque(range(self.num_pages))
        self.accounting = accounting
        # per-slot ownership: shared tree nodes (refcounted), private
        # pages (this request's divergent/boundary/decode pages), and the
        # pre-reserved pocket future boundary crossings draw from
        self._shared: List[List[_Node]] = [[] for _ in range(max(slots, 1))]
        self._private: List[List[int]] = [[] for _ in range(max(slots, 1))]
        self._pocket: List[List[int]] = [[] for _ in range(max(slots, 1))]
        # tenant bulkheads: quotas maps pocket name -> page budget (the
        # COMMONS pocket is the unreserved remainder); every allocated
        # page is charged to exactly one pocket in ``used``.  A callable
        # gets the resolved page count (TenantRegistry.page_quotas)
        if callable(quotas):
            quotas = quotas(self.num_pages)
        if quotas is not None:
            if sum(quotas.values()) > self.num_pages:
                raise ValueError(
                    f"quota pockets sum to {sum(quotas.values())}, "
                    f"pool has only {self.num_pages} pages")
            if any(q < 0 for q in quotas.values()):
                raise ValueError("negative page quota pocket")
        self.quotas = dict(quotas) if quotas is not None else None
        self.used: Dict[str, int] = ({p: 0 for p in quotas}
                                     if quotas is not None else {})
        self._slot_tenant: List[Optional[str]] = [None] * max(slots, 1)
        self._slot_foreign: List[bool] = [False] * max(slots, 1)
        self.pages_evicted = 0
        self.prefix_hit_tokens = 0
        self.prefix_miss_tokens = 0
        self.kv_bytes_saved = 0
        # snapshot-payload counters — present (zero) on page pools too so
        # aggregators can fold stats() dicts without key checks
        self.snapshots_interned = 0
        self.snapshot_hit_tokens = 0
        self.snapshot_bytes_saved = 0
        # arena mutators run jitted with the arena DONATED so updates are
        # in-place buffer writes, not whole-arena functional copies — the
        # admission path must not pay O(arena) per request (compiled
        # variants are bounded by the <= n_logical distinct page counts)
        if self.payload_kind == "snapshot":
            self._clean_fn = self._write_fn = None
        elif self.kv_scales is None:
            self._clean_fn = jax.jit(clean_arena_pages, donate_argnums=(0,))
            self._write_fn = jax.jit(write_arena_pages, donate_argnums=(0,))
        else:
            self._clean_fn = jax.jit(_clean_pages_q, donate_argnums=(0, 1))
            self._write_fn = jax.jit(_write_pages_q, donate_argnums=(0, 1))

    def _clean_pages(self, page_ids):
        """In-place (donated) page clean; also resets int8 scales."""
        if self.kv_scales is None:
            self.arena = self._clean_fn(self.arena, page_ids)
        else:
            self.arena, self.kv_scales = self._clean_fn(
                self.arena, self.kv_scales, page_ids)

    def _write_pages(self, page_ids, stacks):
        """In-place (donated) page write from FLOAT canonical stacks;
        quantizes into an int8 arena (updating the scale tables)."""
        if self.kv_scales is None:
            self.arena = self._write_fn(self.arena, page_ids, stacks)
        else:
            self.arena, self.kv_scales = self._write_fn(
                self.arena, self.kv_scales, page_ids, stacks)

    # -- capability ----------------------------------------------------
    @staticmethod
    def capability(model, max_len: int, page_size: int) -> str:
        """Pool gate, three-way: what cache payload can this config share?

        * ``"paged"`` — attention KV lives in a pageable absolute-position
          layout: full page-granular prefix sharing.
        * ``"snapshot"`` — no paged KV, but the family carries compact
          recurrent state (ssm/hybrid): prefix sharing via interned
          boundary-state checkpoints.
        * ``"none"`` — neither (page-misaligned cache, or a rolling SWA
          buffer that keeps only a window of *slots*, so neither page ids
          nor chunk-boundary states are stable).

        This predicate is the ONLY place payload capability is decided;
        callers branch on its result, never on ``supports_paged_kv``."""
        w = model.cfg.sliding_window
        if max_len % page_size or not (w is None or w >= max_len):
            return "none"
        if model.supports_paged_kv:
            return "paged"
        if getattr(model, "supports_snapshot_state", False):
            return "snapshot"
        return "none"

    # -- occupancy -----------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        """Allocated pages (slot-held, pocketed, or interned cache)."""
        return self.num_pages - len(self.free)

    def evictable_pages(self) -> int:
        return self.tree.evictable_pages()

    def _pocket_of(self, tenant: Optional[str]) -> Optional[str]:
        """Charge pocket for a tenant / namespace owner: an explicitly
        quota'd tenant bills its own pocket; everyone else (quota-less
        tenants, unknown tenants, the public namespace) shares the
        commons remainder."""
        if self.quotas is None:
            return None
        if tenant is not None and tenant in self.quotas:
            return tenant
        return COMMONS

    def _pocket_visible(self, pocket: str):
        """Eviction-candidate predicate for a requester charged to
        ``pocket``: only refcount-0 cache chargeable to the same pocket
        may be reclaimed — a tenant reclaims its own idle cache (or, in
        the commons, anyone's commons cache incl. public pages), never a
        bulkheaded co-tenant's."""
        return lambda n: self._pocket_of(n.owner) == pocket

    def available_pages(self, tenant: Optional[str] = None) -> int:
        """Pages an admission could obtain right now (free + reclaimable
        refcount-0 interned cache).

        With quotas, the answer is scoped to the pocket the admission
        would charge (``_pocket_of``: the tenant's own, or the commons
        for untagged/unknown tenants): quota headroom plus that pocket's
        evictable cache.  The bulkhead invariant (pockets sum <= pool,
        every page charged) guarantees headroom is always physically
        backed by free pages, so this never overstates — which is the
        whole point: a True pre-check here means ``admit`` succeeds."""
        if self.quotas is None:
            return len(self.free) + self.evictable_pages()
        pocket = self._pocket_of(tenant)
        headroom = self.quotas[pocket] - self.used.get(pocket, 0)
        return headroom + self.tree.evictable_pages(
            self._pocket_visible(pocket))

    def occupancy(self) -> float:
        """Committed (non-reclaimable) fraction of the arena — the
        autoscale pressure signal: 1.0 means even evicting every cached
        prefix frees nothing.  Always the GLOBAL view — quota pockets
        partition who may allocate, not how full the arena is."""
        free = len(self.free) + self.evictable_pages()
        return 1.0 - free / self.num_pages

    def stats(self) -> dict:
        out = {
            "num_pages": self.num_pages,
            "pages_in_use": self.pages_in_use,
            "pages_evicted": self.pages_evicted,
            "interned_pages": self.tree.interned,
            "occupancy": self.occupancy(),
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_miss_tokens": self.prefix_miss_tokens,
            "kv_bytes_saved": self.kv_bytes_saved,
            "snapshots_interned": self.snapshots_interned,
            "snapshot_hit_tokens": self.snapshot_hit_tokens,
            "snapshot_bytes_saved": self.snapshot_bytes_saved,
        }
        if self.quotas is not None:
            out["quota_pages"] = dict(self.quotas)
            out["tenant_pages"] = dict(self.used)
        return out

    def _gauge(self):
        if self.accounting is not None:
            self.accounting.record_gauge("pages_in_use", self.pages_in_use)

    def _reap(self, handle: int):
        """Drop the payload behind an evicted/freed handle.  Physical
        pages have nothing to drop (the arena slab is recycled in place);
        snapshot handles release their interned state pytree."""
        self._snaps.pop(handle, None)

    # -- page supply ---------------------------------------------------
    def _alloc_raw(self, tenant: Optional[str] = None) -> Optional[int]:
        """One page, charged to ``tenant``'s pocket (when quotas are on).

        Postcondition on success: the returned page is charged to
        ``_pocket_of(tenant)``.  Quota path: a full pocket may only
        reclaim refcount-0 cache chargeable to the SAME pocket (charge
        unchanged — the page moves from tree cache to slot use), so a
        tenant can exhaust its quota but never another tenant's; an
        under-quota pocket always finds a free page because pockets sum
        to at most the pool and every allocated page is charged."""
        if self.quotas is None:
            if self.free:
                return self.free.popleft()
            evicted = self.tree.evict_lru()
            if evicted is None:
                return None
            _, page = evicted
            self._reap(page)
            self.pages_evicted += 1
            if self.accounting is not None:
                self.accounting.record_counter("pages_evicted")
            return page
        pocket = self._pocket_of(tenant)
        if self.used[pocket] >= self.quotas[pocket]:
            evicted = self.tree.evict_lru(self._pocket_visible(pocket))
            if evicted is None:
                return None             # quota exhausted, pool untouched
            _, page = evicted
            self._reap(page)
            self.pages_evicted += 1
            if self.accounting is not None:
                self.accounting.record_counter("pages_evicted",
                                               tenant=tenant)
            return page
        assert self.free, "bulkhead invariant broken: headroom w/o free"
        self.used[pocket] += 1
        return self.free.popleft()

    def _uncharge(self, tenant: Optional[str], n: int):
        """Return ``n`` pages' worth of charge from ``tenant``'s pocket
        (the pages themselves go back on ``self.free`` at the caller)."""
        if self.quotas is None or n == 0:
            return
        pocket = self._pocket_of(tenant)
        self.used[pocket] -= n
        assert self.used[pocket] >= 0, f"pocket {pocket} charge underflow"

    def _take_pocket(self, slot: int) -> int:
        assert self._pocket[slot], (
            "pocket underflow: admission reserved too few pages")
        return self._pocket[slot].pop()

    # -- prefix lookup -------------------------------------------------
    def lease(self, prompt, ctx_key=None, alt_key=None) -> PrefixLease:
        """Match + acquire the longest interned prefix for ``prompt``.

        The acquired nodes are pinned (non-evictable) until the lease is
        released or its ownership transfers to a slot via ``admit``.

        ``alt_key`` is the read-only fallback namespace (the public
        grant): both roots are matched and the longer chain wins, the
        request's own namespace on ties.  A winning ``alt_key`` chain is
        marked ``foreign`` — its pages map read-only and the suffix will
        not intern under them."""
        nodes = self.tree.match(prompt, ctx_key)
        foreign = False
        if alt_key is not None:
            alt = self.tree.match(prompt, alt_key)
            if len(alt) > len(nodes):
                nodes, foreign = alt, True
        self.tree.acquire(nodes)
        return PrefixLease(nodes=nodes, page_size=self.page_size,
                           foreign=foreign)

    def empty_lease(self) -> PrefixLease:
        """A zero-page lease (cold request / token-at-a-time admit)."""
        return PrefixLease(nodes=[], page_size=self.page_size)

    def release_lease(self, lease: PrefixLease):
        if lease is None or lease.released:
            return
        self.tree.release(lease.nodes)
        lease.released = True

    def note_lookup(self, prompt_len: int, hit_tokens: int,
                    accounting=None, saved_bytes: bool = True):
        """Record a prefix lookup's hit/miss token split (and the KV
        bytes the hit avoided recomputing/duplicating).

        Counted per ADMISSION ATTEMPT, matching the rest of the serving
        ledger (``kv_transfers`` also counts a requeued request's
        re-send): a request re-admitted after a replica detach really
        did skip its prefix work twice."""
        acc = accounting if accounting is not None else self.accounting
        self.prefix_hit_tokens += hit_tokens
        self.prefix_miss_tokens += prompt_len - hit_tokens
        saved = hit_tokens * self.position_bytes if saved_bytes else 0
        self.kv_bytes_saved += saved
        if self.payload_kind == "snapshot":
            self.snapshot_hit_tokens += hit_tokens
            self.snapshot_bytes_saved += saved
        if acc is not None:
            acc.record_counter("prefix_hit_tokens", hit_tokens)
            acc.record_counter("prefix_miss_tokens", prompt_len - hit_tokens)
            if saved:
                acc.record_counter("kv_bytes_saved", saved)

    # -- slot lifecycle ------------------------------------------------
    def required_pages(self, prompt_len: int, max_new: int,
                       shared_pages: int = 0) -> int:
        """Worst-case private pages a request can touch: every page up to
        its last writable position, minus the shared prefix.  At least
        one post-prompt position is counted — install always maps the
        page holding position ``prompt_len`` for the first decode write.

        Snapshot pools reserve nothing per slot: the request's state
        lives in its dense cache row, and handle supply is consumed only
        when a finished prefix interns new checkpoints."""
        if self.payload_kind == "snapshot":
            return 0
        last = min(prompt_len + max(max_new, 1), self.max_len)
        return -(-last // self.page_size) - shared_pages

    def admit(self, slot: int, lease: PrefixLease, prompt_len: int,
              max_new: int, tenant: Optional[str] = None):
        """Commit a slot to a request: map the lease's shared pages into
        the block table (ownership of the lease transfers to the slot)
        and materialize the full private-page pocket, evicting LRU
        refcount-0 prefixes as needed — all charged to ``tenant``'s
        quota pocket.  Raises :class:`PoolExhausted` (with the lease
        still held by the CALLER to release) when the arena — or the
        tenant's pocket — cannot cover the worst case: the admission
        choke point that makes exhaustion a queueing event, not an OOM,
        and the bulkhead that keeps one tenant's exhaustion out of
        everyone else's admission."""
        assert not self._shared[slot] and not self._private[slot] \
            and not self._pocket[slot], f"slot {slot} not released"
        need = self.required_pages(prompt_len, max_new, lease.pages)
        got: List[int] = []
        for _ in range(need):
            page = self._alloc_raw(tenant)
            if page is None:
                self._uncharge(tenant, len(got))
                self.free.extend(got)
                if self.accounting is not None and self.quotas is not None:
                    self.accounting.record_counter("quota_blocked",
                                                   tenant=tenant)
                raise PoolExhausted(
                    f"need {need} pages, got {len(got)} "
                    f"(free={len(self.free)}, "
                    f"evictable={self.evictable_pages()}, "
                    f"tenant={tenant!r})")
            got.append(page)
        self._slot_tenant[slot] = tenant
        self._slot_foreign[slot] = lease.foreign
        if got:
            self._clean_pages(jnp.asarray(got, jnp.int32))
        self._pocket[slot] = got
        if self.payload_kind == "page":
            for lp, node in enumerate(lease.nodes):
                self.block_table[slot, lp] = node.page
        self._shared[slot] = list(lease.nodes)
        lease.released = True            # ownership moved to the slot
        self.note_lookup(prompt_len, lease.tokens)
        self._gauge()

    def _transfer_charge(self, tenant: Optional[str], owner) -> bool:
        """Move one page's charge from ``tenant``'s pocket to
        ``owner``'s — interning a slot-billed page into a namespace
        billed elsewhere (a public prompt's pages move to the commons).
        Returns False (leave the page private) when the destination
        pocket cannot absorb the charge even after reclaiming its own
        idle cache."""
        if self.quotas is None:
            return True
        src = self._pocket_of(tenant)
        dst = self._pocket_of(owner)
        if src == dst:
            return True
        if self.used[dst] >= self.quotas[dst]:
            evicted = self.tree.evict_lru(self._pocket_visible(dst))
            if evicted is None:
                return False
            _, page = evicted
            self._reap(page)
            self.pages_evicted += 1
            self.free.append(page)
            self.used[dst] -= 1
        self.used[src] -= 1
        self.used[dst] += 1
        return True

    def map_private(self, slot: int, logical_page: int) -> int:
        """Map a pocket page at ``logical_page`` (decode growth / the
        copy-on-write boundary page)."""
        page = self._take_pocket(slot)
        self.block_table[slot, logical_page] = page
        self._private[slot].append(page)
        return page

    def ensure_decode_page(self, slot: int, pos: int):
        """Called before a decode step: make sure the page holding
        ``pos`` is mapped (drawn from the slot's reserved pocket, so it
        cannot fail)."""
        lp = pos // self.page_size
        if self.block_table[slot, lp] == self.sentinel:
            self.map_private(slot, lp)

    def map_suffix_pages(self, slot: int, prompt_len: int):
        """Map pocket pages under every logical page a suffix extend
        will write (lease depth through the prompt's last page).  The
        native paged extend writes K/V straight into the slot's arena
        pages, so they must be mapped BEFORE the kernel runs — a
        sentinel block-table entry silently drops the write.  Pocket-
        backed, so it cannot fail; decode growth past the prompt keeps
        drawing pages per step via ``ensure_decode_page``."""
        for lp in range(-(-prompt_len // self.page_size)):
            if self.block_table[slot, lp] == self.sentinel:
                self.map_private(slot, lp)

    def promote_slot_pages(self, slot: int, prompt, ctx_key):
        """Intern a warm-extended slot's full prompt pages by OWNERSHIP
        TRANSFER — the paged extend already wrote the suffix KV in place,
        so no page data moves: each full-page chunk either joins the
        tree as-is (the slot's private page becomes the interned node,
        refcount 1 held by this slot) or, when the chunk is already
        interned, the slot remaps to the existing node and frees its
        now-redundant private copy (bit-identical by the exactness
        invariant).  The partial boundary page stays private (the
        copy-on-write edge); a foreign-prefix slot never interns
        (read-only public grant)."""
        if self._slot_foreign[slot]:
            return
        P = self.page_size
        L = len(prompt)
        tenant = self._slot_tenant[slot]
        owner = (PUBLIC if (ctx_key is not None and ctx_key
                            and ctx_key[0] == "public")
                 else (tenant if tenant is not None else DEFAULT_TENANT))
        parent = (self._shared[slot][-1] if self._shared[slot]
                  else self.tree.root(ctx_key))
        for lp in range(len(self._shared[slot]), L // P):
            page = int(self.block_table[slot, lp])
            key = tuple(int(t) for t in prompt[lp * P:(lp + 1) * P])
            node = parent.children.get(key)
            if node is not None:
                # chunk already interned: share it, free our copy
                self.block_table[slot, lp] = node.page
                self._private[slot].remove(page)
                self.free.append(page)
                self._uncharge(tenant, 1)
            elif self._transfer_charge(tenant, owner):
                node = self.tree.insert(parent, key, page, owner)
                self._private[slot].remove(page)
            else:
                break                   # owner pocket full: stay private
            node.refs += 1
            node.last_used = self.tree._tick()
            self._shared[slot].append(node)
            parent = node
        self._gauge()

    def install_stacks(self, slot: int, prompt, ctx_key,
                       stacks: List[KVSlice], start_page: int):
        """Map a request's computed suffix pages into ``slot``.

        ``stacks``: canonical page stacks covering logical pages
        ``start_page ..`` up to the prompt's last page.  Full prompt
        pages are INTERNED (copied into pool pages owned by the tree,
        refcount 1 held by this slot) so the next request with this
        prefix shares them; the partial boundary page stays private
        (copy-on-write edge).  Finally the page holding position
        ``len(prompt)`` is mapped so the first decode write lands."""
        P = self.page_size
        L = len(prompt)
        n = stacks[0].k.shape[0] if stacks else 0
        tenant = self._slot_tenant[slot]
        owner = (PUBLIC if (ctx_key is not None and ctx_key
                            and ctx_key[0] == "public")
                 else (tenant if tenant is not None else DEFAULT_TENANT))
        # a foreign (public-grant) prefix is read-only: the suffix may
        # never intern under it, so every suffix page stays private —
        # one tenant's data can't leak into a namespace it doesn't own
        can_intern = not self._slot_foreign[slot]
        parent = (self._shared[slot][-1] if self._shared[slot]
                  else self.tree.root(ctx_key))
        new_ids: List[int] = []         # pages needing a data write,
        new_rows: List[int] = []        # batched into ONE arena scatter
        for j in range(n):
            lp = start_page + j
            node = None
            if can_intern and (lp + 1) * P <= L:
                key = tuple(int(t) for t in prompt[lp * P:(lp + 1) * P])
                node = parent.children.get(key)
                if node is None:
                    if self._transfer_charge(tenant, owner):
                        page = self._take_pocket(slot)
                        node = self.tree.insert(parent, key, page, owner)
                        new_ids.append(page)
                        new_rows.append(j)
                    else:
                        # owner pocket full: the rest of the chain stays
                        # private (a child without its parent interned
                        # would be unreachable anyway)
                        can_intern = False
            if node is not None:
                node.refs += 1
                node.last_used = self.tree._tick()
                self._shared[slot].append(node)
                self.block_table[slot, lp] = node.page
                parent = node
            else:
                page = self._take_pocket(slot)
                new_ids.append(page)
                new_rows.append(j)
                self._private[slot].append(page)
                self.block_table[slot, lp] = page
        if new_ids:
            rows = jnp.asarray(new_rows, jnp.int32)
            sub = [KVSlice(k=s.k[rows], v=s.v[rows],
                           slot_pos=s.slot_pos[rows]) for s in stacks]
            self._write_pages(jnp.asarray(new_ids, jnp.int32), sub)
        self.ensure_decode_page(slot, L)
        self._gauge()

    def install_rows(self, slot: int, prompt, ctx_key, rows_cache,
                     row: int, start_page: int):
        """``install_stacks`` fed straight from a dense prefill/extend
        rows cache (the colocated batcher path)."""
        P = self.page_size
        n_total = -(-len(prompt) // P)
        stacks = extract_row_pages(rows_cache, self.axes, row, start_page,
                                   n_total - start_page, P)
        self.install_stacks(slot, prompt, ctx_key, stacks, start_page)

    def release_slot(self, slot: int):
        """Free a slot's pages: decref shared prefixes (they stay
        interned as reclaimable cache, still charged to their owner's
        pocket), return private + pocket pages to the free list
        (uncharging the slot tenant's pocket), unmap the block-table
        row."""
        self.tree.release(self._shared[slot])
        self._shared[slot] = []
        self._uncharge(self._slot_tenant[slot],
                       len(self._private[slot]) + len(self._pocket[slot]))
        self.free.extend(self._private[slot])
        self._private[slot] = []
        self.free.extend(self._pocket[slot])
        self._pocket[slot] = []
        self._slot_tenant[slot] = None
        self._slot_foreign[slot] = False
        self.block_table[slot, :] = self.sentinel
        self._gauge()

    def release_all(self):
        for slot in range(len(self._shared)):
            self.release_slot(slot)

    # -- prefill-side prefix cache (slot-less) -------------------------
    def intern_rows(self, prompt, ctx_key, rows_cache, row: int,
                    tenant: Optional[str] = None):
        """Best-effort intern of a prompt's full pages from a dense rows
        cache (the PrefillWorker's cache-fill path — refcounts stay 0,
        pages are pure reclaimable cache).  Stops silently when no page
        can be obtained.  Pages bill the namespace they land in: the
        public root charges the commons, a tenant root charges that
        tenant's pocket."""
        P = self.page_size
        L = len(prompt)
        owner = (PUBLIC if (ctx_key is not None and ctx_key
                            and ctx_key[0] == "public")
                 else (tenant if tenant is not None else DEFAULT_TENANT))
        parent = self.tree.root(ctx_key)
        path: List[_Node] = []          # pinned so eviction inside
        new_ids: List[int] = []         # _alloc_raw can't detach our walk
        new_lps: List[int] = []
        try:
            for lp in range(L // P):
                key = tuple(int(t) for t in prompt[lp * P:(lp + 1) * P])
                node = parent.children.get(key)
                if node is None:
                    # a fresh node's children can't pre-exist, so from
                    # the first miss on every page is new — the data
                    # writes batch into one scatter below
                    page = self._alloc_raw(owner)
                    if page is None:
                        break
                    node = self.tree.insert(parent, key, page, owner)
                    new_ids.append(page)
                    new_lps.append(lp)
                self.tree.acquire([node])
                path.append(node)
                parent = node
            if new_ids:
                stacks = extract_row_pages(rows_cache, self.axes, row,
                                           new_lps[0], len(new_lps), P)
                self._write_pages(jnp.asarray(new_ids, jnp.int32), stacks)
        finally:
            self.tree.release(path)
            self._gauge()

    def intern_snapshots(self, prompt, ctx_key, payloads,
                         tenant: Optional[str] = None):
        """Best-effort intern of a prompt's per-chunk state snapshots —
        the snapshot-pool twin of ``intern_rows`` (refcounts stay 0, the
        chain is pure reclaimable cache).  ``payloads[lp]`` is chunk
        ``lp``'s payload dict: ``{"state": the 1-row recurrent state
        AFTER position ``(lp+1)*page_size``, "pages": per-KV-node 1-page
        canonical stacks for the chunk's shared-attention positions
        ([] for pure ssm)}``.  Handles bill the landing namespace's
        pocket exactly like pages; the walked chain is pinned so an
        eviction inside ``_alloc_raw`` can't detach it mid-walk."""
        assert self.payload_kind == "snapshot", "page pools intern rows"
        P = self.page_size
        L = len(prompt)
        owner = (PUBLIC if (ctx_key is not None and ctx_key
                            and ctx_key[0] == "public")
                 else (tenant if tenant is not None else DEFAULT_TENANT))
        parent = self.tree.root(ctx_key)
        path: List[_Node] = []
        try:
            for lp in range(min(L // P, len(payloads))):
                key = tuple(int(t) for t in prompt[lp * P:(lp + 1) * P])
                node = parent.children.get(key)
                if node is None:
                    handle = self._alloc_raw(owner)
                    if handle is None:
                        break
                    node = self.tree.insert(parent, key, handle, owner)
                    self._snaps[handle] = payloads[lp]
                    self.snapshots_interned += 1
                    if self.accounting is not None:
                        self.accounting.record_counter("snapshots_interned")
                self.tree.acquire([node])
                path.append(node)
                parent = node
        finally:
            self.tree.release(path)
            self._gauge()

    def snapshot_chain(self, lease: PrefixLease) -> tuple:
        """Materialize a warm lease's restore payload.

        Returns ``(state, page_stacks)``: ``state`` is the DEEPEST
        node's boundary recurrent state (the scan state after
        ``lease.tokens`` positions — restoring it replays the whole
        prefix in O(1)); ``page_stacks`` is, per KV node, the
        concatenation of every chain chunk's shared-attention pages
        (logical pages ``[0, lease.pages)``, [] for pure ssm).
        ``(None, [])`` for an empty lease.  Read-only — the lease keeps
        its pins."""
        if not lease.nodes:
            return None, []
        payloads = [self._snaps[n.page] for n in lease.nodes]
        state = payloads[-1]["state"]
        per_chunk = [p["pages"] for p in payloads]
        if not per_chunk[0]:
            return state, []
        stacks = [
            jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                         *(pc[i] for pc in per_chunk))
            for i in range(len(per_chunk[0]))
        ]
        return state, stacks

    def alloc_temp_pages(self, n: int,
                         tenant: Optional[str] = None) -> List[int]:
        """``n`` cleaned scratch pages for a slot-less paged extend (the
        prefill worker's warm path writes suffix KV straight into them).
        Charged to ``tenant``'s pocket; raises :class:`PoolExhausted`
        (holding nothing) when the pocket/pool cannot cover them — the
        caller falls back to the cold dense-prefill path."""
        got: List[int] = []
        for _ in range(n):
            page = self._alloc_raw(tenant)
            if page is None:
                self._uncharge(tenant, len(got))
                self.free.extend(got)
                raise PoolExhausted(
                    f"need {n} temp pages, got {len(got)} "
                    f"(free={len(self.free)}, "
                    f"evictable={self.evictable_pages()})")
            got.append(page)
        if got:
            self._clean_pages(jnp.asarray(got, jnp.int32))
        return got

    def free_temp_pages(self, pages: List[int],
                        tenant: Optional[str] = None):
        """Return temp pages that did not transfer into the tree."""
        self._uncharge(tenant, len(pages))
        self.free.extend(pages)

    def intern_arena_pages(self, prompt, ctx_key, lease: PrefixLease,
                           temp_pages: List[int],
                           tenant: Optional[str] = None):
        """Ownership-transfer intern for the slot-less warm path:
        ``temp_pages[i]`` holds logical page ``lease.pages + i`` of
        ``prompt``, already written IN PLACE by the paged extend — the
        native-paged twin of ``intern_rows`` with zero data movement.
        Full pages enter the tree as refs-0 reclaimable cache (or are
        freed when the chunk is already interned); the partial tail
        page is always freed.  A foreign lease never interns (read-only
        public grant): every temp page is freed.  The walked chain is
        pinned so an eviction inside ``_transfer_charge`` can't reap a
        just-inserted leaf mid-walk."""
        P = self.page_size
        L = len(prompt)
        owner = (PUBLIC if (ctx_key is not None and ctx_key
                            and ctx_key[0] == "public")
                 else (tenant if tenant is not None else DEFAULT_TENANT))
        can_intern = not lease.foreign
        parent = (lease.nodes[-1] if lease.nodes
                  else self.tree.root(ctx_key))
        path: List[_Node] = []
        leftover: List[int] = []
        try:
            for i, page in enumerate(temp_pages):
                lp = lease.pages + i
                node = None
                if can_intern and (lp + 1) * P <= L:
                    key = tuple(int(t) for t in prompt[lp * P:(lp + 1) * P])
                    node = parent.children.get(key)
                    if node is None:
                        if self._transfer_charge(tenant, owner):
                            node = self.tree.insert(parent, key, page, owner)
                            page = None     # consumed: the tree owns it
                        else:
                            can_intern = False
                if page is not None:
                    leftover.append(page)
                if node is not None:
                    self.tree.acquire([node])
                    path.append(node)
                    parent = node
        finally:
            self.tree.release(path)
            if leftover:
                self._uncharge(tenant, len(leftover))
                self.free.extend(leftover)
            self._gauge()

    def read_pages(self, page_ids) -> list:
        """Canonical page stacks for ``page_ids`` (test / audit surface:
        the copy-on-write suite snapshots interned pages through this).
        An int8 arena dequantizes to f32 — export/migration round-trips
        through floats, so int8 pools make no bit-exactness claims."""
        stacks = read_arena_pages(self.arena, page_ids)
        if self.kv_scales is None:
            return stacks
        idx = jnp.asarray(page_ids, jnp.int32)
        return [KVSlice(k=dequantize_page(s.k, ks[idx], keep_axes=(0, 2)),
                        v=dequantize_page(s.v, vs[idx], keep_axes=(0, 2)),
                        slot_pos=s.slot_pos)
                for s, (ks, vs) in zip(stacks, self.kv_scales)]

    # -- replica-to-replica migration (the cluster cache plane) --------
    def export_subtree(self, ctx_key=None,
                       max_pages: Optional[int] = None) -> tuple:
        """Serialize one namespace's interned prefix tree for migration.

        Returns ``(records, stacks)``: ``records[i]`` is ``{"key":
        chunk-token tuple, "owner": billing owner, "parent": j}`` with
        ``j`` the index of the node's parent record (``-1`` = root), in
        pre-order so every parent precedes its children; ``stacks`` is
        the canonical page data aligned row-for-row with ``records``
        (``read_pages`` over the nodes' arena pages).  ``max_pages``
        caps the export — children of an unexported node are dropped
        with it (a child without its parent would be unreachable).
        Read-only: refcounts and the tree are untouched."""
        root = self.tree._roots.get(ctx_key)
        records: List[dict] = []
        pages: List[int] = []
        if root is None:
            return records, []
        stack: List[tuple] = [(root, -1)]
        while stack and (max_pages is None or len(records) < max_pages):
            node, pidx = stack.pop()
            if node.page is not None:
                idx = len(records)
                records.append({"key": node.key, "owner": node.owner,
                                "parent": pidx})
                pages.append(node.page)
            else:
                idx = pidx
            stack.extend((c, idx) for c in node.children.values())
        if self.payload_kind == "snapshot":
            # stacks row i is record i's interned payload dict verbatim
            # (ArrayChannel._transfer device-puts any pytree)
            return records, [self._snaps[p] for p in pages]
        stacks = (self.read_pages(jnp.asarray(pages, jnp.int32))
                  if pages else [])
        return records, stacks

    def import_subtree(self, ctx_key, records, stacks) -> int:
        """Best-effort re-intern of an exported subtree into this pool.

        Refcount-correct: imported nodes arrive as refs-0 reclaimable
        cache (no phantom pins survive the migration), each page is
        charged to its record's ORIGINAL owner's pocket, and nodes this
        tree already holds are skipped (the interned page is
        bit-identical by the exactness invariant).  A record whose page
        cannot be allocated — or whose parent was skipped — is dropped
        with its descendants, never partially linked.  The walked chain
        is pinned during the import so an eviction triggered by
        ``_alloc_raw`` can never reap a just-imported leaf mid-walk.
        Returns the number of NEW pages interned."""
        root = self.tree.root(ctx_key)
        nodes: List[Optional[_Node]] = [None] * len(records)
        pinned: List[_Node] = []
        new_ids: List[int] = []
        new_rows: List[int] = []
        try:
            for i, rec in enumerate(records):
                parent = (root if rec["parent"] < 0
                          else nodes[rec["parent"]])
                if parent is None:      # parent dropped -> drop subtree
                    continue
                key = tuple(rec["key"])
                node = parent.children.get(key)
                if node is None:
                    page = self._alloc_raw(rec["owner"])
                    if page is None:
                        continue        # exhausted: siblings may still fit
                    node = self.tree.insert(parent, key, page, rec["owner"])
                    if self.payload_kind == "snapshot":
                        self._snaps[page] = stacks[i]
                        self.snapshots_interned += 1
                    new_ids.append(page)
                    new_rows.append(i)
                self.tree.acquire([node])
                pinned.append(node)
                nodes[i] = node
            if new_ids and self.payload_kind == "page":
                rows = jnp.asarray(new_rows, jnp.int32)
                sub = [KVSlice(k=s.k[rows], v=s.v[rows],
                               slot_pos=s.slot_pos[rows]) for s in stacks]
                self._write_pages(jnp.asarray(new_ids, jnp.int32), sub)
        finally:
            self.tree.release(pinned)
            self._gauge()
        return len(new_ids)


# --------------------------------------------------------------------------
# jitted programs over the paged cache
# --------------------------------------------------------------------------
def build_paged_serve_step(model, temperature, *, template):
    """paged_step(params, arena, scales, resident, block_table, batch,
    rng) -> (next_tokens, arena, scales, resident).

    NATIVE paged decode: ``paged_view`` hands ``Model.decode`` the arena
    itself behind each row's block table — attention writes the new
    token's K/V straight into its physical page (sentinel entries drop
    the write) and the paged decode kernel walks the row's pages in
    place.  No gather, no scatter, no dense per-slot KV is ever
    materialized.  ``resident`` carries the non-positional cache
    remainder (encdec cross memory) dense per slot; ``scales`` is the
    per-(page, layer) int8 scale list (None for float arenas).  Callers
    jit with the arena/scales/resident donated and may width-trim the
    block table to the live page bucket — paged cost then scales with
    occupancy, not ``max_len``."""
    def paged_step(params, arena, scales, resident, block_table, batch, rng):
        cache = paged_view(template, resident, arena, block_table, scales)
        logits, new_cache = model.decode(params, cache, batch)
        arena, scales, resident = extract_paged(new_cache)
        toks = sample_tokens(logits, rng, temperature)
        return toks, arena, scales, resident
    return paged_step


def build_paged_extend_step(model, temperature, *, template):
    """paged_extend(params, arena, scales, resident, block_table, batch,
    rng) -> (first_tokens, arena, scales, resident).

    The suffix-extend twin of ``build_paged_serve_step``:
    ``Model.prefill_extend`` runs over the paged view, writing each
    row's suffix K/V directly into its mapped arena pages — no dense
    prefix gather in front, no page scatter behind.  Each row's block
    table must already map every page its suffix touches
    (``KVPool.map_suffix_pages`` / ``alloc_temp_pages``); unmapped rows
    and pages drop their writes and read fully masked."""
    def paged_extend(params, arena, scales, resident, block_table, batch,
                     rng):
        cache = paged_view(template, resident, arena, block_table, scales)
        logits, new_cache = model.prefill_extend(params, batch, cache)
        arena, scales, resident = extract_paged(new_cache)
        toks = sample_tokens(logits, rng, temperature)
        return toks, arena, scales, resident
    return paged_extend


def build_snapshot_payloads(model, axes, page_size: int, prompt,
                            rows_cache, ckpts, row: int) -> list:
    """Per-chunk snapshot payload dicts for one cold-prefilled row — the
    intern/handoff artifact of the snapshot cache plane.

    ``payloads[lp]`` covers prompt chunk ``lp``: ``state`` is the 1-row
    recurrent state AFTER position ``(lp+1)*page_size`` (sliced from the
    checkpoint-emitting prefill's stacked ``ckpts``) and ``pages`` holds
    the chunk's shared-attention KV as per-node 1-page canonical stacks
    ([] for pure ssm — ``axes`` empty).  Only ``len(prompt) //
    page_size`` chunks are built: checkpoints at boundaries past a row's
    true length are bucket-pad garbage and must never be read."""
    from repro.models.cache_utils import extract_row_pages
    n_chunks = len(prompt) // page_size
    if n_chunks == 0:
        return []
    all_stacks = (extract_row_pages(rows_cache, axes, row, 0, n_chunks,
                                    page_size)
                  if axes else None)
    payloads = []
    for lp in range(n_chunks):
        pages = ([jax.tree.map(lambda a, lp=lp: a[lp:lp + 1], s)
                  for s in all_stacks] if all_stacks else [])
        payloads.append({
            "state": model.slice_checkpoint(ckpts, row, lp),
            "pages": pages,
        })
    return payloads


def run_extend_group(extend_fn, params, scratch, pool: KVPool, reqs,
                     leases: List[PrefixLease], bt_rows, *, chunk: int,
                     max_len: int, rng, model, accounting=None):
    """ONE native-paged suffix-extend invocation over prefix-hit rows.

    Mirrors ``run_prefill_group``: the batch dim pads to the next power
    of two with dummy rows and all suffixes share one pad bucket, but
    each row carries its own prefix offset (``pos``), so requests with
    DIFFERENT hit depths batch together.  ``bt_rows`` (B, n_logical)
    gives each row's block table — slot rows in the batcher, lease +
    temp-page rows in the prefill worker — with every page the suffix
    writes already mapped; pad rows are all-sentinel (writes drop,
    reads mask, outputs are discarded).  The table is width-trimmed to
    the pow2 page bucket covering the longest prompt, so extend cost
    scales with occupancy, not ``max_len``.  The suffix K/V lands
    directly in the arena pages (``extend_fn`` is a — typically
    jitted — ``build_paged_extend_step`` step; the pool's arena/scales
    are updated in place here).  ``scratch`` is a ``batch -> cache``
    factory (callers memoize theirs; only its resident structure is
    used).  Returns (first_tokens, b_pad-row resident tree, advanced
    rng, b_pad)."""
    B = len(reqs)
    b_pad = 1 << (B - 1).bit_length()
    P = pool.page_size
    prefix = [lease.tokens for lease in leases] + [0] * (b_pad - B)
    suffixes = [np.asarray(r.prompt[h:], np.int32)
                for r, h in zip(reqs, prefix)]
    s_pad = bucket_len(max(len(s) for s in suffixes), chunk, max_len)
    tokens = np.zeros((b_pad, s_pad), np.int32)
    lengths = np.zeros((b_pad,), np.int32)
    for i, s in enumerate(suffixes):
        tokens[i, :len(s)] = s
        lengths[i] = len(s)
    width = max(-(-len(r.prompt) // P) for r in reqs)
    width = min(1 << (width - 1).bit_length(), pool.n_logical)
    bt = np.full((b_pad, width), pool.sentinel, np.int32)
    bt[:B] = np.asarray(bt_rows, np.int32)[:, :width]
    resident = jax.tree.map(jnp.zeros_like, strip_kv_nodes(scratch(b_pad)))
    srcs = [getattr(r, "src", None) for r in reqs] + [None] * (b_pad - B)
    mem = model.encode_cross_rows(params, srcs, max_len)
    if mem is not None:
        resident = install_cross_memory(resident, mem, list(range(b_pad)))
    batch = {
        "tokens": jnp.asarray(tokens),
        "pos": jnp.asarray(prefix, jnp.int32),
        "length": jnp.asarray(lengths),
    }
    rng, sub = jax.random.split(rng)
    toks, arena, scales, rows = extend_fn(
        params, pool.arena, pool.kv_scales, resident, jnp.asarray(bt),
        batch, sub)
    pool.arena = arena
    pool.kv_scales = scales
    if accounting is not None and b_pad != B:
        accounting.record_counter("prefill_dummy_rows", b_pad - B)
    return [int(t) for t in np.asarray(toks)], rows, rng, b_pad
