"""Serving programs: chunked prefill + decode with sampling.

Two programs back the serving stack:

* ``prefill_step`` — consumes a whole (bucket-padded) prompt in ONE program
  invocation, writes the KV cache directly, and samples the first output
  token from the logits at the last real prompt position.  This is the
  TTFT-critical path: O(prompt_len / chunk) invocations instead of the
  O(prompt_len) decode calls of token-at-a-time prompt consumption.
* ``serve_step`` — one decode step over all busy batcher slots.

Prompts are padded to *chunk buckets* (multiples of the batcher's
``prefill_chunk``) so the number of distinct compiled prefill programs is
bounded by ``max_len / chunk`` rather than one per prompt length.

Chunked prefill is exact for EVERY registered family — the padding is
neutralized per family inside ``Model.prefill_ranged`` (KV slot masking /
SSD validity mask / ``src_len``-masked cross memory), not here: this layer
only buckets, pads and batches, and consults ``supports_chunked_prefill``
(backed by ``Model.chunked_prefill_exact``) for the one remaining layout
exception (rolling sliding-window caches).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model

F32 = jnp.float32


def sample_tokens(logits, rng, temperature: float = 0.0):
    """logits (B, V) -> token ids (B,).  temperature 0 = greedy."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits.astype(F32) / temperature).astype(jnp.int32)


def bucket_len(prompt_len: int, chunk: int, max_len: int) -> int:
    """Pad a prompt length up to the next chunk multiple (capped at the
    cache length) so prefill programs compile once per bucket.

    The cap binds LAST: a bucket longer than the cache would push the
    prefill attention into its rolling-cache branch and silently discard
    the real prompt KV."""
    b = -(-prompt_len // chunk) * chunk
    return min(max(b, chunk), max_len)


def supports_chunked_prefill(model: Model, max_len: int) -> bool:
    """Is ``Model.prefill_ranged`` exact for this model at ``max_len``?

    Consults the model's own capability (``Model.chunked_prefill_exact`` —
    every registered family qualifies; see its docstring for the per-family
    mask semantics) plus one cache-LAYOUT condition: a rolling SWA buffer
    (``sliding_window < max_len``) keeps only the last ``window`` slots of
    the PADDED sequence, so a short row's real tokens would be shifted out
    by its pad tail — those configs stay on the token-at-a-time path.
    """
    cfg = model.cfg
    return model.chunked_prefill_exact and (
        cfg.sliding_window is None or cfg.sliding_window >= max_len
    )


def build_prefill_step(model: Model, temperature: float = 0.0,
                       checkpoint_every: Optional[int] = None) -> Callable:
    """prefill_step(params, cache, batch, rng) -> (first_tokens, logits, cache).

    ``batch`` = {tokens (B, S_pad), length (B,)}; ``cache`` is a fresh
    (B-row) cache whose buffers are NOT donated — callers reuse a scratch
    cache across requests since prefill rebuilds every KV leaf.

    ``checkpoint_every`` (ssm/hybrid snapshot pools): the third output
    becomes ``(cache, ckpts)`` with ``ckpts`` the stacked per-boundary
    recurrent-state checkpoints from ``Model.prefill_ranged`` — the rest
    of the batching protocol (``run_prefill_prompts`` / ``_group``) passes
    it through untouched, so checkpointing callers unpack the pair.
    """
    def prefill_step(params, cache, batch, rng):
        if checkpoint_every is None:
            logits, cache = model.prefill_ranged(params, batch, cache)
        else:
            logits, cache, ckpts = model.prefill_ranged(
                params, batch, cache, checkpoint_every=checkpoint_every)
            cache = (cache, ckpts)
        toks = sample_tokens(logits, rng, temperature)
        return toks, logits, cache
    return prefill_step


def build_extend_step(model: Model, temperature: float = 0.0) -> Callable:
    """extend_step(params, cache, batch, rng) -> (first_tokens, logits, cache).

    The suffix-only sibling of ``prefill_step`` (paged prefix sharing):
    ``batch`` = {tokens (B, S_ext), pos (B,) prefix offsets, length (B,)
    true suffix lengths}; ``cache`` is a dense scratch cache whose rows
    already hold each request's shared prefix (gathered from pool pages)
    with everything beyond it position-masked.  Buffers are not donated —
    callers reuse the scratch across invocations.
    """
    def extend_step(params, cache, batch, rng):
        logits, cache = model.prefill_extend(params, batch, cache)
        toks = sample_tokens(logits, rng, temperature)
        return toks, logits, cache
    return extend_step


def run_prefill_prompts(step_fn: Callable, params, scratch_cache, prompts,
                        *, chunk: int, max_len: int, rng,
                        model: Optional[Model] = None,
                        srcs: Optional[Sequence] = None):
    """Bucket-pad B same-bucket prompts and run ONE jitted ``prefill_step``.

    All NON-EMPTY prompts must share a bucket (``bucket_len`` of each
    equals the group bucket) so a batch compiles to one (B, S_pad)
    program; zero-length rows are normalized to dummy batch padding
    (``length`` 0, every slot masked) rather than bucketed — asserted
    here so a future bucket check can never reject its own padding.
    ``scratch_cache`` is a B-row cache reused across invocations.  Rows
    are independent under prefill attention/scan, so the batched
    invocation is bit-equivalent to B single-row invocations.  ``model``
    + ``srcs`` (per-row source features or None) add the family-specific
    batch extras via ``Model.ranged_batch_extras`` (encdec source
    features; {} for every other family).  Returns
    (first_tokens list, B-row KV cache, advanced rng).
    """
    B = len(prompts)
    s_pad = bucket_len(max(len(p) for p in prompts), chunk, max_len)
    tokens = np.zeros((B, s_pad), np.int32)
    lengths = np.zeros((B,), np.int32)
    for i, p in enumerate(prompts):
        if len(p):
            assert bucket_len(len(p), chunk, max_len) == s_pad, (
                f"prompt {i} (len {len(p)}) belongs to bucket "
                f"{bucket_len(len(p), chunk, max_len)}, not {s_pad}"
            )
        tokens[i, :len(p)] = p
        lengths[i] = len(p)
    batch = {
        "tokens": jnp.asarray(tokens),
        "length": jnp.asarray(lengths),
    }
    if model is not None:
        batch.update(model.ranged_batch_extras(
            list(srcs) if srcs is not None else [None] * B, max_len))
    rng, sub = jax.random.split(rng)
    toks, _logits, cache = step_fn(params, scratch_cache, batch, sub)
    return [int(t) for t in np.asarray(toks)], cache, rng


def run_prefill_group(step_fn: Callable, params, scratch: Callable, reqs,
                      *, chunk: int, max_len: int, rng, model: Model,
                      accounting=None):
    """ONE prefill invocation over a same-bucket request group.

    The batch dim is padded to the next power of two with dummy
    zero-length rows (normalized/masked by :func:`run_prefill_prompts`,
    discarded by callers) so compiled prefill variants stay O(log
    capacity) per bucket; the dummy-row waste — real prefill compute — is
    recorded as ``prefill_dummy_rows`` in ``accounting``.  ``scratch`` is
    a ``batch -> cache`` factory (callers memoize theirs).  The single
    definition both the colocated batcher and the disaggregated
    PrefillWorker use, so the batching protocol cannot drift between
    them.  Returns (first_tokens, b_pad-row cache, advanced rng, b_pad).
    """
    B = len(reqs)
    b_pad = 1 << (B - 1).bit_length()
    prompts = [r.prompt for r in reqs]
    prompts += [np.zeros(0, np.int32)] * (b_pad - B)
    srcs = [getattr(r, "src", None) for r in reqs] + [None] * (b_pad - B)
    toks, cache, rng = run_prefill_prompts(
        step_fn, params, scratch(b_pad), prompts,
        chunk=chunk, max_len=max_len, rng=rng, model=model, srcs=srcs,
    )
    if accounting is not None and b_pad != B:
        accounting.record_counter("prefill_dummy_rows", b_pad - B)
    return toks, cache, rng, b_pad


def run_prefill_prompt(step_fn: Callable, params, scratch_cache, prompt,
                       *, chunk: int, max_len: int, rng,
                       model: Optional[Model] = None, src=None):
    """Single-prompt wrapper over :func:`run_prefill_prompts`.

    Returns (first_token, 1-row KV cache, advanced rng)."""
    toks, row_cache, rng = run_prefill_prompts(
        step_fn, params, scratch_cache, [prompt],
        chunk=chunk, max_len=max_len, rng=rng, model=model, srcs=[src],
    )
    return toks[0], row_cache, rng


def build_serve_step(model: Model, temperature: float = 0.0) -> Callable:
    """serve_step(params, cache, batch) -> (next_tokens, logits, cache).

    ``batch`` = {tokens (B,1), pos (B,)}; the KV cache is donated by callers.
    """
    def serve_step(params, cache, batch, rng):
        logits, cache = model.decode(params, cache, batch)
        toks = sample_tokens(logits, rng, temperature)
        return toks, logits, cache
    return serve_step
