"""Serving programs: chunked prefill + decode with sampling.

Two programs back the serving stack:

* ``prefill_step`` — consumes a whole (bucket-padded) prompt in ONE program
  invocation, writes the KV cache directly, and samples the first output
  token from the logits at the last real prompt position.  This is the
  TTFT-critical path: O(prompt_len / chunk) invocations instead of the
  O(prompt_len) decode calls of token-at-a-time prompt consumption.
* ``serve_step`` — one decode step over all busy batcher slots.

Prompts are padded to *chunk buckets* (multiples of the batcher's
``prefill_chunk``) so the number of distinct compiled prefill programs is
bounded by ``max_len / chunk`` rather than one per prompt length.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.model import Model

F32 = jnp.float32


def sample_tokens(logits, rng, temperature: float = 0.0):
    """logits (B, V) -> token ids (B,).  temperature 0 = greedy."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits.astype(F32) / temperature).astype(jnp.int32)


def bucket_len(prompt_len: int, chunk: int, max_len: int) -> int:
    """Pad a prompt length up to the next chunk multiple (capped at the
    cache length) so prefill programs compile once per bucket.

    The cap binds LAST: a bucket longer than the cache would push the
    prefill attention into its rolling-cache branch and silently discard
    the real prompt KV."""
    b = -(-prompt_len // chunk) * chunk
    return min(max(b, chunk), max_len)


def supports_chunked_prefill(cfg: ArchConfig, max_len: int) -> bool:
    """Chunked prefill is exact only for pure-KV-cache families with a
    non-rolling cache (a rolling SWA buffer would retain the pad tail)."""
    return cfg.family in ("dense", "vlm", "moe") and (
        cfg.sliding_window is None or cfg.sliding_window >= max_len
    )


def build_prefill_step(model: Model, temperature: float = 0.0) -> Callable:
    """prefill_step(params, cache, batch, rng) -> (first_tokens, logits, cache).

    ``batch`` = {tokens (B, S_pad), length (B,)}; ``cache`` is a fresh
    (B-row) cache whose buffers are NOT donated — callers reuse a scratch
    cache across requests since prefill rebuilds every KV leaf.
    """
    def prefill_step(params, cache, batch, rng):
        logits, cache = model.prefill_ranged(params, batch, cache)
        toks = sample_tokens(logits, rng, temperature)
        return toks, logits, cache
    return prefill_step


def run_prefill_prompts(step_fn: Callable, params, scratch_cache, prompts,
                        *, chunk: int, max_len: int, rng):
    """Bucket-pad B same-bucket prompts and run ONE jitted ``prefill_step``.

    All prompts must share a bucket (``bucket_len`` of each equals the
    bucket of the longest) so a batch compiles to one (B, S_pad) program;
    ``scratch_cache`` is a B-row cache reused across invocations.  Rows
    are independent under prefill attention, so the batched invocation is
    bit-equivalent to B single-row invocations.  Returns
    (first_tokens list, B-row KV cache, advanced rng).
    """
    B = len(prompts)
    s_pad = bucket_len(max(len(p) for p in prompts), chunk, max_len)
    tokens = np.zeros((B, s_pad), np.int32)
    lengths = np.zeros((B,), np.int32)
    for i, p in enumerate(prompts):
        tokens[i, :len(p)] = p
        lengths[i] = len(p)
    batch = {
        "tokens": jnp.asarray(tokens),
        "length": jnp.asarray(lengths),
    }
    rng, sub = jax.random.split(rng)
    toks, _logits, cache = step_fn(params, scratch_cache, batch, sub)
    return [int(t) for t in np.asarray(toks)], cache, rng


def run_prefill_prompt(step_fn: Callable, params, scratch_cache, prompt,
                       *, chunk: int, max_len: int, rng):
    """Single-prompt wrapper over :func:`run_prefill_prompts`.

    Returns (first_token, 1-row KV cache, advanced rng)."""
    toks, row_cache, rng = run_prefill_prompts(
        step_fn, params, scratch_cache, [prompt],
        chunk=chunk, max_len=max_len, rng=rng,
    )
    return toks[0], row_cache, rng


def build_serve_step(model: Model, temperature: float = 0.0) -> Callable:
    """serve_step(params, cache, batch) -> (next_tokens, logits, cache).

    ``batch`` = {tokens (B,1), pos (B,)}; the KV cache is donated by callers.
    """
    def serve_step(params, cache, batch, rng):
        logits, cache = model.decode(params, cache, batch)
        toks = sample_tokens(logits, rng, temperature)
        return toks, logits, cache
    return serve_step
