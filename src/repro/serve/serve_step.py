"""Serving programs: prefill + decode with sampling."""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model

F32 = jnp.float32


def sample_tokens(logits, rng, temperature: float = 0.0):
    """logits (B, V) -> token ids (B,).  temperature 0 = greedy."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits.astype(F32) / temperature).astype(jnp.int32)


def build_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        return logits, cache
    return prefill_step


def build_serve_step(model: Model, temperature: float = 0.0) -> Callable:
    """serve_step(params, cache, batch) -> (next_tokens, logits, cache).

    ``batch`` = {tokens (B,1), pos (B,)}; the KV cache is donated by callers.
    """
    def serve_step(params, cache, batch, rng):
        logits, cache = model.decode(params, cache, batch)
        toks = sample_tokens(logits, rng, temperature)
        return toks, logits, cache
    return serve_step
