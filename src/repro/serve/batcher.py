"""Continuous batcher: slot-based request scheduling for decode.

A fixed-width decode batch (B slots) over a shared-shape KV cache; requests
join free slots, run until EOS/max_tokens, and free their slot.  Per-slot
positions (``pos`` is a vector) let slots be at different depths — the
model's decode path masks per-slot.  This is the serving front used by the
serving cells and the tail-latency benchmarks.

Prompt consumption is CHUNKED-PREFILL by default: an admitted request's
whole prompt runs through one bucket-padded prefill program invocation that
writes its KV rows straight into the slot (O(1) invocations per prompt),
and the first output token is sampled from the same invocation.  Requests
admitted in the same scheduler tick whose prompts land in the SAME pad
bucket share one (B, S_pad) prefill invocation — under bursty arrivals the
prompt phase costs O(buckets) invocations per tick, not O(requests).
Every registered family chunks exactly (``Model.chunked_prefill_exact``);
only rolling-SWA cache layouts (``sliding_window < max_len``) fall back to
the token-at-a-time decode loop (see ``supports_chunked_prefill``).

Slots can also be filled from OUTSIDE via :meth:`install_prefilled` — the
disaggregated serving path (``repro.serve.disagg``) prefills on a separate
cell and streams the KV rows over an ArrayChannel into a free slot here.

KV STORAGE IS PAGED by default for the families that support it
(``Model.supports_paged_kv`` + an absolute-position cache layout): the
batcher owns a :class:`~repro.serve.kvpool.KVPool` — a page-granular
arena + block table + radix-tree prefix cache — instead of a dense
per-slot cache.  Admission consults the tree first: a request whose
prompt shares an interned prefix maps those pages read-only, skips their
prefill chunks entirely (only the suffix runs, one ``prefill_extend``
invocation per pad bucket), and admission BLOCKS (requests stay queued)
when the pool is exhausted instead of over-committing memory.

RECURRENT FAMILIES (ssm/hybrid) get the SAME prefix-cache plane through
snapshot payloads (``KVPool.capability`` == "snapshot"): decode stays on
the dense per-slot cache (recurrent state is O(1) per slot), but cold
prefills emit per-chunk boundary-state checkpoints that intern into the
pool's radix tree, and a warm prompt restores the deepest checkpoint
into its slot row and suffix-extends only the divergence tail
(:meth:`ContinuousBatcher._restore_group`).  Only rolling-SWA layouts
keep the plain dense cache with no prefix sharing.

ADMISSION IS TENANT-AWARE (``repro.serve.tenancy``): requests carry a
``tenant`` tag, a persistent deficit-round-robin scheduler shares free
slots by tenant weight, token buckets rate-limit each tenant's own FIFO,
and a request blocked on pool pages is scanned PAST instead of stalling
the whole queue.  With no tenants configured all of this degenerates to
the old FIFO behavior (minus the head-of-line block).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.telemetry import (
    finish_request,
    mark_admitted,
    migrate_decode,
    open_decode,
    open_request,
    recorder_of,
    span_group,
)
from repro.serve.tenancy import (
    DEFAULT_TENANT,
    TenantRegistry,
    TenantScheduler,
)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    submitted_at: float = 0.0
    started_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    output: List[int] = dataclasses.field(default_factory=list)
    # per-request source features (S_src, d_model) for encdec models;
    # None = no source (zero cross memory).  Ignored by other families.
    src: Optional[np.ndarray] = None
    # QoS attribution: which tenant's bucket/weight/page-pocket this
    # request bills.  ``public=True`` puts its prompt in the shared
    # prefix namespace any granted tenant may hit read-only.
    tenant: str = DEFAULT_TENANT
    public: bool = False

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (submission -> first output token)."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at

    @property
    def tpot(self) -> Optional[float]:
        """Time per output token over the decode phase; None when fewer
        than two tokens were produced (there was no decode phase to
        measure — a 0.0 would drag the percentiles toward zero)."""
        if self.finished_at is None or self.first_token_at is None:
            return None
        n = len(self.output) - 1
        if n < 1:
            return None
        return (self.finished_at - self.first_token_at) / n


class ContinuousBatcher:
    """Slot-based continuous batching over prefill + decode programs."""

    def __init__(self, model, params, *, batch_slots: int, max_len: int,
                 temperature: float = 0.0, eos_token: Optional[int] = None,
                 prefill_chunk: Optional[int] = 32, accounting=None,
                 kv_pool: Any = "auto", page_size: int = 16,
                 pool_pages: Optional[int] = None, tenants: Any = None,
                 tenant_buckets: bool = True, quantum: int = 256,
                 kv_dtype: Optional[str] = None):
        from repro.models.cache_utils import cache_batch_axes, strip_kv_nodes
        from repro.serve.kvpool import KVPool, build_paged_serve_step
        from repro.serve.serve_step import (
            build_prefill_step,
            build_serve_step,
            supports_chunked_prefill,
        )
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.eos = eos_token
        self.temperature = temperature
        self.accounting = accounting
        # the owning cell's flight recorder (a shared no-op when the
        # batcher runs standalone with accounting=None)
        self.rec = recorder_of(accounting)
        self.pos = np.zeros(batch_slots, np.int32)
        self.cur_tok = np.zeros(batch_slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.queue: deque = deque()
        self.done: List[Request] = []
        # tenant QoS plane: weights + token buckets drive _admit through
        # a persistent DRR scheduler; page quotas (if any tenant declares
        # one) partition the pool's arena into bulkheaded pockets.  With
        # no tenants declared the scheduler degenerates to FIFO-with-
        # scan-past and the pool stays unpartitioned — the single-tenant
        # cold path is byte-identical to the pre-tenancy batcher.
        self.tenants: TenantRegistry = (
            tenants if isinstance(tenants, TenantRegistry)
            else TenantRegistry(tenants or (), buckets=tenant_buckets))
        self.scheduler = TenantScheduler(self.tenants, quantum=quantum)
        quota_fn = (self.tenants.page_quotas
                    if any(t.page_quota is not None
                           for t in self.tenants.specs.values()) else None)
        # cache payload plane: "auto" -> pool iff the family/cache layout
        # supports one (``KVPool.capability``: "paged" arenas for KV
        # families, "snapshot" state checkpoints for ssm/hybrid); None ->
        # legacy dense per-slot cache; or inject a prebuilt KVPool
        if kv_pool == "auto":
            kv_pool = (KVPool(model, max_len=max_len, page_size=page_size,
                              slots=batch_slots, num_pages=pool_pages,
                              accounting=accounting, quotas=quota_fn,
                              kv_dtype=kv_dtype)
                       if KVPool.capability(model, max_len, page_size)
                       != "none" else None)
        self.pool: Optional[KVPool] = kv_pool
        self._paged = (self.pool is not None
                       and self.pool.payload_kind == "page")
        self._snapshot = (self.pool is not None
                          and self.pool.payload_kind == "snapshot")
        if self._paged:
            self.cache = None
            self.resident = strip_kv_nodes(model.init_cache(batch_slots, max_len))
            # native paged decode: the arena + block table flow straight
            # into Model.decode (no gather/scatter); arena, scales and
            # resident are donated so the jitted step mutates in place
            self._step = jax.jit(
                build_paged_serve_step(
                    model, temperature, template=self.pool.template,
                ),
                donate_argnums=(1, 2, 3),
            )
        else:
            # dense per-slot cache — also the decode plane for snapshot
            # pools (recurrent state is O(1) per slot; the pool only
            # holds the shareable checkpoint chains, not the hot state)
            self.cache = model.init_cache(batch_slots, max_len)
            self.resident = None
            self._step = jax.jit(build_serve_step(model, temperature),
                                 donate_argnums=(1,))
        self._rng = jax.random.PRNGKey(0)
        self._cache_axes = cache_batch_axes(model, batch_slots, max_len)
        self._resident_axes = strip_kv_nodes(self._cache_axes)
        self.prefill_chunk = prefill_chunk
        self.chunked = (
            prefill_chunk is not None
            and supports_chunked_prefill(model, max_len)
        )
        if self.chunked and self._snapshot:
            # checkpoint boundaries live at page_size multiples, so every
            # prefill bucket must be page-aligned: coarsen the bucket
            # quantum to lcm(chunk, page_size) (the max_len cap stays
            # aligned — snapshot pools require page-divisible max_len)
            self.prefill_chunk = int(np.lcm(prefill_chunk, page_size))
        self._prefill = (
            jax.jit(build_prefill_step(
                model, temperature,
                checkpoint_every=page_size if self._snapshot else None))
            if self.chunked else None
        )
        self._extend = None                        # lazy; first prefix hit
        self._scratch_caches: Dict[int, Any] = {}  # B -> B-row prefill cache
        self._slot_init_cache = None               # lazy; see _slot_init()
        self.prefill_invocations = 0
        self.prefill_batch_sizes: List[int] = []   # prompts per invocation
        self.decode_invocations = 0

    # -- request management --------------------------------------------
    def submit(self, req: Request):
        req.submitted_at = req.submitted_at or time.monotonic()
        # colocated front door: the root "request" span opens here (the
        # disagg server opens it earlier, on the prefill cell — then
        # this is a no-op returning the existing root)
        open_request(self.rec, req)
        self.queue.append(req)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.B) if self.slot_req[s] is None]

    def _finish(self, req: Request, now: float, slot: Optional[int] = None):
        req.finished_at = now
        finish_request(req, ts=now)
        self.done.append(req)
        if slot is not None:
            self.slot_req[slot] = None
            if self.pool is not None:
                # private + pocket pages return to the free list; shared
                # prefix pages decref (and stay interned as reclaimable
                # cache for the next request with this prefix)
                self.pool.release_slot(slot)
        if self.accounting is not None:
            self.accounting.record_request(
                req.rid, ttft=req.ttft, tpot=req.tpot,
                prompt_len=len(req.prompt), new_tokens=len(req.output),
                tenant=getattr(req, "tenant", None),
            )

    # -- chunked prefill ------------------------------------------------
    def _scratch(self, batch: int):
        """B-row prefill scratch cache, reused across invocations."""
        if batch not in self._scratch_caches:
            self._scratch_caches[batch] = self.model.init_cache(batch, self.max_len)
        return self._scratch_caches[batch]

    def _slot_init(self):
        """Pristine 1-row cache for resetting a slot at token-at-a-time
        admit: KV families mask stale rows by position, but recurrent
        state (ssm/hybrid) is NOT positional — a request admitted into a
        reused slot would integrate its predecessor's state.  Allocated
        on first fallback admit; purely-chunked batchers never pay for it."""
        if self._slot_init_cache is None:
            self._slot_init_cache = self.model.init_cache(1, self.max_len)
        return self._slot_init_cache

    def _prefill_group(self, group):
        """ONE prefill invocation over same-bucket (slot, request, lease)
        triples (cold path — empty leases).

        Power-of-two batch padding (dummy rows discarded) keeps compiled
        prefill variants O(log slots) per bucket and scratch caches O(2B)
        rows total — see ``run_prefill_group``.
        """
        from repro.models.cache_utils import slice_cache_slots
        from repro.serve.serve_step import run_prefill_group
        B = len(group)
        reqs = [r for _, r, _ in group]
        t0 = self.rec.clock()
        toks, rows_cache, self._rng, b_pad = run_prefill_group(
            self._prefill, self.params, self._scratch, reqs,
            chunk=self.prefill_chunk, max_len=self.max_len, rng=self._rng,
            model=self.model, accounting=self.accounting,
        )
        t1 = self.rec.clock()
        span_group(self.rec, "prefill", reqs, t0, t1, kind="cold", batch=B)
        self.rec.record("prefill_s", t1 - t0)
        ckpts = None
        if self._snapshot:
            rows_cache, ckpts = rows_cache
        self.prefill_invocations += 1
        self.prefill_batch_sizes.append(B)
        slots = [s for s, _, _ in group]
        if self._paged:
            self._install_pool_rows(group, rows_cache, toks[:B])
        else:
            if ckpts is not None:
                self._intern_snapshot_chains(group, rows_cache, ckpts)
            if b_pad != B:
                rows_cache = slice_cache_slots(rows_cache, self._cache_axes,
                                               list(range(B)))
            self._install_rows(slots, reqs, rows_cache, toks[:B])

    def _extend_group(self, group):
        """ONE suffix-extend invocation over prefix-hit (slot, request,
        lease) triples whose suffixes share a pad bucket — the shared
        prefix pages are already mapped, so only the divergence tail is
        computed (mixed hit depths batch fine: each row carries its own
        offset).

        NATIVE paged: each row's block-table row IS its slot's row, so
        the suffix K/V lands directly in the slot's arena pages — no
        dense rows cache, no post-install page copy.  Afterwards the
        freshly written full prompt pages are interned by ownership
        transfer (``promote_slot_pages``)."""
        from repro.serve.kvpool import (
            build_paged_extend_step,
            request_ctx_key,
            run_extend_group,
        )
        if self._extend is None:
            self._extend = jax.jit(
                build_paged_extend_step(self.model, self.temperature,
                                        template=self.pool.template),
                donate_argnums=(1, 2, 3),
            )
        slots = [s for s, _, _ in group]
        reqs = [r for _, r, _ in group]
        leases = [le for _, _, le in group]
        for slot, req in zip(slots, reqs):
            self.pool.map_suffix_pages(slot, len(req.prompt))
        bt_rows = np.asarray(self.pool.block_table[slots], np.int32)
        t0 = self.rec.clock()
        toks, resident_rows, self._rng, _b_pad = run_extend_group(
            self._extend, self.params, self._scratch, self.pool, reqs,
            leases, bt_rows, chunk=self.prefill_chunk,
            max_len=self.max_len, rng=self._rng, model=self.model,
            accounting=self.accounting,
        )
        t1 = self.rec.clock()
        span_group(self.rec, "prefill", reqs, t0, t1, kind="warm",
                   batch=len(group),
                   hit_tokens=sum(le.tokens for le in leases))
        self.rec.record("prefill_s", t1 - t0)
        self.prefill_invocations += 1
        self.prefill_batch_sizes.append(len(group))
        for slot, req in zip(slots, reqs):
            self.pool.promote_slot_pages(slot, req.prompt,
                                         request_ctx_key(req))
            self.pool.ensure_decode_page(slot, len(req.prompt))
        self._merge_resident_rows(resident_rows, list(range(len(group))),
                                  slots)
        self._post_install(slots, reqs, toks[:len(group)])

    def _intern_snapshot_chains(self, group, rows_cache, ckpts):
        """Intern each cold request's per-chunk snapshot chain (snapshot
        pools): chunk ``lp``'s payload is the boundary recurrent state
        AFTER position ``(lp+1)*P`` (sliced from the prefill's stacked
        checkpoints) plus, for hybrid, the chunk's shared-attention KV
        page.  Checkpoints at boundaries past a row's true length are
        never read — only ``len(prompt) // P`` chunks intern."""
        from repro.serve.kvpool import (
            build_snapshot_payloads,
            request_ctx_key,
        )
        for i, (_slot, req, _lease) in enumerate(group):
            payloads = build_snapshot_payloads(
                self.model, self.pool.axes, self.pool.page_size,
                req.prompt, rows_cache, ckpts, i)
            if payloads:
                self.pool.intern_snapshots(
                    req.prompt, request_ctx_key(req), payloads,
                    tenant=getattr(req, "tenant", None))

    def _restore_group(self, group):
        """Warm-path twin of ``_extend_group`` for SNAPSHOT pools: seed
        each slot's dense cache row from its leased chain (deepest
        boundary state + the chain's shared-attention pages), then run
        ONE dense suffix-extend over the full slot cache — only the
        divergence tail is computed; the shared prefix is replayed in
        O(1) by the state restore.

        Rows outside the group ride along untouched: their batch rows
        carry ``length`` 0 (every SSD step dt-masked to identity, so
        recurrent state is preserved bit-exactly) and ``pos`` = max_len
        (every KV write lands out of bounds and drops)."""
        from repro.models.cache_utils import clear_kv_row, load_pages_into_row
        from repro.serve.serve_step import bucket_len, build_extend_step
        if self._extend is None:
            self._extend = jax.jit(
                build_extend_step(self.model, self.temperature))
        P = self.pool.page_size
        for slot, _req, lease in group:
            state, stacks = self.pool.snapshot_chain(lease)
            if self.pool.axes:
                self.cache = clear_kv_row(self.cache, self.pool.axes, slot)
            if state is not None:
                self.cache = self.model.restore_state_row(self.cache, state,
                                                          slot)
            if stacks:
                self.cache = load_pages_into_row(
                    self.cache, self.cache, self.pool.axes, slot, stacks,
                    0, P)
        s_pad = bucket_len(
            max(len(r.prompt) - le.tokens for _, r, le in group),
            self.prefill_chunk, self.max_len)
        tokens = np.zeros((self.B, s_pad), np.int32)
        length = np.zeros((self.B,), np.int32)
        pos = np.full((self.B,), self.max_len, np.int32)
        for slot, req, lease in group:
            suf = req.prompt[lease.tokens:]
            tokens[slot, :len(suf)] = suf
            length[slot] = len(suf)
            pos[slot] = lease.tokens
        batch = {
            "tokens": jnp.asarray(tokens),
            "pos": jnp.asarray(pos),
            "length": jnp.asarray(length),
        }
        self._rng, sub = jax.random.split(self._rng)
        t0 = self.rec.clock()
        toks, _logits, self.cache = self._extend(self.params, self.cache,
                                                 batch, sub)
        toks = np.asarray(toks)
        t1 = self.rec.clock()
        span_group(self.rec, "prefill", [r for _, r, _ in group], t0, t1,
                   kind="warm_snapshot", batch=len(group),
                   hit_tokens=sum(le.tokens for _, _, le in group))
        self.rec.record("prefill_s", t1 - t0)
        self.prefill_invocations += 1
        self.prefill_batch_sizes.append(len(group))
        self._post_install([s for s, _, _ in group],
                           [r for _, r, _ in group],
                           [int(toks[s]) for s, _, _ in group])

    def _install_pool_rows(self, group, rows_cache, first_tokens):
        """Map each request's computed pages out of a dense rows cache
        into its slot (interning full prompt pages for future sharing),
        copy the resident remainder, then run the shared bookkeeping."""
        from repro.serve.kvpool import request_ctx_key
        rows = list(range(len(group)))
        for i, (slot, req, lease) in enumerate(group):
            self.pool.install_rows(slot, req.prompt, request_ctx_key(req),
                                   rows_cache, i, lease.pages)
        self._merge_resident_rows(rows_cache, rows,
                                  [s for s, _, _ in group])
        self._post_install([s for s, _, _ in group],
                           [r for _, r, _ in group], first_tokens)

    def _install_rows(self, slots, reqs, rows_cache, first_tokens):
        """Write prefilled KV rows + first tokens into free slots.

        ``rows_cache`` has batch dim == len(slots); one scatter merges all
        rows, then per-request bookkeeping runs on the host."""
        from repro.models.cache_utils import merge_cache_slots
        self.cache = merge_cache_slots(self.cache, rows_cache,
                                       self._cache_axes, slots)
        self._post_install(slots, reqs, first_tokens)

    def _merge_resident_rows(self, rows_cache, rows, slots):
        """Copy the non-paged cache remainder (encdec cross memory) of
        the given prefill rows into the batcher's resident tree."""
        from repro.models.cache_utils import (
            merge_cache_slots,
            slice_cache_slots,
            strip_kv_nodes,
        )
        res = strip_kv_nodes(rows_cache)
        if not jax.tree.leaves(res):
            return
        res = slice_cache_slots(res, self._resident_axes, rows)
        self.resident = merge_cache_slots(self.resident, res,
                                          self._resident_axes, slots)

    def _post_install(self, slots, reqs, first_tokens):
        """Per-request bookkeeping after KV rows landed in slots."""
        now = time.monotonic()
        for slot, req, tok in zip(slots, reqs, first_tokens):
            req.started_at = req.started_at or now
            req.first_token_at = req.first_token_at or now
            L = len(req.prompt)
            self.pos[slot] = L
            self.cur_tok[slot] = tok
            req.output.append(tok)
            finished = (
                len(req.output) >= req.max_new_tokens
                or (self.eos is not None and tok == self.eos)
                or L >= self.max_len - 1
            )
            if finished:
                self._finish(req, now, slot=slot)
            else:
                self.slot_req[slot] = req
                open_decode(self.rec, req, ts=now)

    def install_prefilled(self, req: Request, row_cache, first_token: int) -> bool:
        """Adopt an EXTERNALLY prefilled request (disaggregated serving):
        ``row_cache`` is a 1-row cache already on this batcher's devices.
        Returns False when no slot is free — or, under a paged pool, when
        page admission would exhaust the arena (caller retries later)."""
        from repro.serve.kvpool import (
            PoolExhausted,
            public_ctx_key,
            request_ctx_key,
        )
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        if self.pool is None:
            self._install_rows([slot], [req], row_cache, [first_token])
            return True
        if not self._paged:
            return self.install_snapshot(req, row_cache, first_token)
        ctx = request_ctx_key(req)
        alt = (public_ctx_key(req) if self.tenants.share_public(
            getattr(req, "tenant", DEFAULT_TENANT)) else None)
        lease = self.pool.lease(req.prompt, ctx, alt)
        try:
            self.pool.admit(slot, lease, len(req.prompt), req.max_new_tokens,
                            tenant=getattr(req, "tenant", None))
        except PoolExhausted:
            self.pool.release_lease(lease)
            return False
        self.pool.install_rows(slot, req.prompt, ctx, row_cache, 0,
                               lease.pages)
        self._merge_resident_rows(row_cache, [0], [slot])
        self._post_install([slot], [req], [first_token])
        return True

    def install_snapshot(self, req: Request, row_cache, first_token: int,
                         lease=None, chain=None) -> bool:
        """Adopt an externally prefilled request on a SNAPSHOT pool: the
        dense 1-row install of :meth:`install_prefilled` plus the prefix
        bookkeeping — the lease (router-acquired, or taken fresh here)
        transfers to the slot via ``admit`` (recording the hit/saved
        counters), and a cold handoff's snapshot chain (per-chunk payload
        dicts) interns so the NEXT request with this prefix stays warm.
        Returns False (lease released) when no slot is free."""
        from repro.serve.kvpool import (
            PoolExhausted,
            public_ctx_key,
            request_ctx_key,
        )
        free = self.free_slots()
        if not free:
            if lease is not None:
                self.pool.release_lease(lease)
            return False
        slot = free[0]
        ctx = request_ctx_key(req)
        if lease is None:
            alt = (public_ctx_key(req) if self.tenants.share_public(
                getattr(req, "tenant", DEFAULT_TENANT)) else None)
            lease = self.pool.lease(req.prompt, ctx, alt)
        try:
            self.pool.admit(slot, lease, len(req.prompt),
                            req.max_new_tokens,
                            tenant=getattr(req, "tenant", None))
        except PoolExhausted:            # snapshot admit reserves nothing,
            self.pool.release_lease(lease)   # but keep the contract
            return False
        if chain:
            self.pool.intern_snapshots(req.prompt, ctx, chain,
                                       tenant=getattr(req, "tenant", None))
        self._install_rows([slot], [req], row_cache, [first_token])
        return True

    def install_paged(self, req: Request, stacks, resident_row,
                      start_page: int, first_token: int, lease) -> bool:
        """Adopt an externally prefilled request from PAGE STACKS — the
        disaggregated handoff when both sides run the paged cache plane:
        only the non-shared page suffix crossed the channel; pages
        ``[0, start_page)`` map read-only from this pool's own interned
        prefix (held by ``lease``, whose ownership transfers to the slot
        on success).  Returns False (lease untouched) when no slot is
        free or the pool is exhausted — the caller requeues."""
        from repro.serve.kvpool import PoolExhausted, request_ctx_key
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        try:
            self.pool.admit(slot, lease, len(req.prompt), req.max_new_tokens,
                            tenant=getattr(req, "tenant", None))
        except PoolExhausted:
            return False
        self.pool.install_stacks(slot, req.prompt, request_ctx_key(req),
                                 stacks, start_page)
        if resident_row is not None and jax.tree.leaves(resident_row):
            from repro.models.cache_utils import merge_cache_slots
            self.resident = merge_cache_slots(
                self.resident, resident_row, self._resident_axes, [slot])
        self._post_install([slot], [req], [first_token])
        return True

    def export_slot(self, slot: int) -> dict:
        """Snapshot a busy slot's full mid-decode state for migration
        (the cluster cache plane's drain-before-detach): the request, its
        decode cursor (``pos``/``cur_tok``), every WRITTEN page's data
        (positions ``[0, pos)`` — all mapped by the decode invariant) and
        the slot's resident cache row.  Read-only: the caller drops the
        slot only after a successful adopt on the destination."""
        from repro.models.cache_utils import slice_cache_slots
        req = self.slot_req[slot]
        assert req is not None and self._paged, \
            "slot export is page-granular (snapshot/dense slots requeue)"
        pos = int(self.pos[slot])
        P = self.pool.page_size
        n_pages = -(-pos // P)
        page_ids = np.asarray(self.pool.block_table[slot, :n_pages],
                              np.int32)
        assert not (page_ids == self.pool.sentinel).any(), \
            "written page unmapped — block-table invariant broken"
        resident_row = None
        if jax.tree.leaves(self.resident):
            resident_row = slice_cache_slots(self.resident,
                                             self._resident_axes, [slot])
        return {
            "req": req, "pos": pos, "cur_tok": int(self.cur_tok[slot]),
            "stacks": self.pool.read_pages(jnp.asarray(page_ids)),
            "resident": resident_row,
        }

    def adopt_slot(self, req: Request, stacks, resident_row, pos: int,
                   cur_tok: int) -> bool:
        """Adopt a MIGRATED in-flight request mid-decode (the other half
        of :meth:`export_slot`): admit it into a free slot, map its
        written pages (interning full prompt pages — the migrated prefix
        becomes shareable cache here too) and resume the decode cursor
        exactly where the source replica left it.  The request's token
        bookkeeping (``output``, TTFT stamps) is NOT re-run — decode
        continues, it does not restart.  Returns False (nothing changed)
        when no slot is free or page admission would exhaust the pool —
        the caller requeues for an ordinary cold restart instead."""
        from repro.serve.kvpool import (
            PoolExhausted,
            public_ctx_key,
            request_ctx_key,
        )
        free = self.free_slots()
        if not free or not self._paged:
            return False
        slot = free[0]
        ctx = request_ctx_key(req)
        alt = (public_ctx_key(req) if self.tenants.share_public(
            getattr(req, "tenant", DEFAULT_TENANT)) else None)
        lease = self.pool.lease(req.prompt, ctx, alt)
        try:
            self.pool.admit(slot, lease, len(req.prompt),
                            req.max_new_tokens,
                            tenant=getattr(req, "tenant", None))
        except PoolExhausted:
            self.pool.release_lease(lease)
            return False
        # the locally shared prefix maps from this pool's own interned
        # pages; only the remainder of the migrated stacks installs (and
        # its full prompt pages re-intern here — prefix migration rides
        # along with the slot)
        start = lease.pages
        if start:
            rows = jnp.arange(start, stacks[0].k.shape[0])
            stacks = [type(s)(k=s.k[rows], v=s.v[rows],
                              slot_pos=s.slot_pos[rows]) for s in stacks]
        self.pool.install_stacks(slot, req.prompt, ctx, stacks, start)
        if resident_row is not None and jax.tree.leaves(resident_row):
            from repro.models.cache_utils import merge_cache_slots
            self.resident = merge_cache_slots(
                self.resident, resident_row, self._resident_axes, [slot])
        self.slot_req[slot] = req
        self.pos[slot] = pos
        self.cur_tok[slot] = cur_tok
        migrate_decode(req, self.rec)
        return True

    def _admit_fallback(self, slot: int, req: Request):
        """Token-at-a-time admission: the prompt is consumed through the
        decode path (shared cache keeps slot shapes uniform).
        Non-positional slot state (recurrent ssm/hybrid state, encdec
        cross memory) must go back to init values first — unlike stale
        KV it is not masked by position."""
        if not self.model.decode_state_positional:
            from repro.models.cache_utils import (
                merge_cache_slots,
                strip_kv_nodes,
            )
            if self._paged:
                self.resident = merge_cache_slots(
                    self.resident, strip_kv_nodes(self._slot_init()),
                    self._resident_axes, [slot])
            else:
                self.cache = merge_cache_slots(
                    self.cache, self._slot_init(),
                    self._cache_axes, [slot])
        # request-scoped side state (encdec cross memory) still has to
        # land in the slot up front — the model says what, if anything
        mem = self.model.encode_cross_rows(
            self.params, [getattr(req, "src", None)], self.max_len)
        if mem is not None:
            from repro.models.cache_utils import install_cross_memory
            if self._paged:
                self.resident = install_cross_memory(self.resident, mem,
                                                     [slot])
            else:
                self.cache = install_cross_memory(self.cache, mem, [slot])
        self.slot_req[slot] = req
        self.pos[slot] = 0
        self.cur_tok[slot] = int(req.prompt[0]) if len(req.prompt) else 0
        req._prompt_cursor = 1  # type: ignore[attr-defined]

    def _admit(self):
        from repro.serve.kvpool import (
            PoolExhausted,
            public_ctx_key,
            request_ctx_key,
        )
        from repro.serve.serve_step import bucket_len
        free = self.free_slots()
        staged: List[tuple] = []        # chunked-eligible (slot, req, lease)
        taken = [0]                     # free-slot cursor

        def try_admit(req: Request) -> bool:
            # the scheduler's resource gate: bind the next free slot and
            # reserve pool pages.  False = blocked (pool/quota) — the
            # scheduler scans PAST this request, so a huge blocked prompt
            # no longer head-of-line-blocks a small one that would fit
            slot = free[taken[0]]
            chunkable = (self.chunked
                         and 0 < len(req.prompt) <= self.max_len - 1)
            lease = None
            if self.pool is not None:
                ctx = request_ctx_key(req)
                alt = (public_ctx_key(req)
                       if chunkable and self.tenants.share_public(
                           getattr(req, "tenant", DEFAULT_TENANT))
                       else None)
                lease = (self.pool.lease(req.prompt, ctx, alt) if chunkable
                         else self.pool.empty_lease())
                try:
                    self.pool.admit(slot, lease, len(req.prompt),
                                    req.max_new_tokens,
                                    tenant=getattr(req, "tenant", None))
                except PoolExhausted:
                    self.pool.release_lease(lease)
                    return False
            taken[0] += 1
            req.started_at = req.started_at or time.monotonic()
            mark_admitted(req, slot=slot,
                          prefix_hit=lease.tokens if lease else 0)
            if chunkable:
                staged.append((slot, req, lease))
            else:
                self._admit_fallback(slot, req)
                open_decode(self.rec, req)
            return True

        if free and self.queue:
            self.scheduler.select(self.queue, try_admit, budget=len(free))
        # same-bucket prompts admitted this tick share one invocation;
        # prefix hits group by their SUFFIX bucket (their shared pages are
        # already mapped — only the divergent tail runs), cold prompts by
        # their full bucket through the ordinary prefill program
        cold: Dict[int, List[tuple]] = {}
        warm: Dict[int, List[tuple]] = {}
        for slot, req, lease in staged:
            hit = lease.tokens if lease is not None else 0
            if hit:
                b = bucket_len(len(req.prompt) - hit, self.prefill_chunk,
                               self.max_len)
                warm.setdefault(b, []).append((slot, req, lease))
            else:
                b = bucket_len(len(req.prompt), self.prefill_chunk,
                               self.max_len)
                cold.setdefault(b, []).append((slot, req, lease))
        for _, group in sorted(cold.items()):
            self._prefill_group(group)
        for _, group in sorted(warm.items()):
            if self._paged:
                self._extend_group(group)
            else:
                self._restore_group(group)

    # -- one decode step over all busy slots -----------------------------
    def step(self) -> int:
        self._admit()
        busy = [s for s in range(self.B) if self.slot_req[s] is not None]
        if not busy:
            return 0
        t0 = self.rec.clock() if self.rec.enabled else 0.0
        batch = {
            "tokens": jnp.asarray(self.cur_tok[:, None]),
            "pos": jnp.asarray(self.pos),
        }
        self._rng, sub = jax.random.split(self._rng)
        if self._paged:
            # map the page each busy slot is about to write (drawn from
            # the pocket its admission reserved — cannot fail mid-decode)
            for s in busy:
                self.pool.ensure_decode_page(s, int(self.pos[s]))
            # width-trim the block table to the pow2 page bucket covering
            # the deepest busy slot: the paged kernel's page walk then
            # scales with occupancy, not max_len (compiled variants stay
            # O(log n_logical))
            n_act = max(int(self.pos[s]) // self.pool.page_size + 1
                        for s in busy)
            width = min(1 << (n_act - 1).bit_length(), self.pool.n_logical)
            toks, self.pool.arena, self.pool.kv_scales, self.resident = \
                self._step(
                    self.params, self.pool.arena, self.pool.kv_scales,
                    self.resident,
                    jnp.asarray(self.pool.block_table[:, :width]),
                    batch, sub,
                )
        else:
            toks, _logits, self.cache = self._step(self.params, self.cache,
                                                   batch, sub)
        self.decode_invocations += 1
        toks = np.asarray(toks)       # sync point: device step complete
        if self.rec.enabled:
            t1 = self.rec.clock()
            self.rec.add_complete("decode_step", t0, t1 - t0,
                                  busy=len(busy))
            self.rec.record("decode_step_s", t1 - t0)
        now = time.monotonic()
        for s in busy:
            req = self.slot_req[s]
            self.pos[s] += 1
            cursor = getattr(req, "_prompt_cursor", len(req.prompt))
            if cursor < len(req.prompt):
                if self.pos[s] >= self.max_len - 1:
                    # prompt overran the cache: fail fast instead of
                    # spinning forever past the last writable slot
                    self._finish(req, now, slot=s)
                    continue
                # still consuming the prompt: feed next prompt token
                self.cur_tok[s] = int(req.prompt[cursor])
                req._prompt_cursor = cursor + 1  # type: ignore[attr-defined]
                continue
            tok = int(toks[s])
            if not req.output:
                req.first_token_at = now
            req.output.append(tok)
            self.cur_tok[s] = tok
            finished = (
                len(req.output) >= req.max_new_tokens
                or (self.eos is not None and tok == self.eos)
                or self.pos[s] >= self.max_len - 1
            )
            if finished:
                self._finish(req, now, slot=s)
        return len(busy)

    def drop_slot(self, slot: int) -> Optional[Request]:
        """Evict a slot's request WITHOUT finishing it (detach/requeue
        path): clears the slot and releases its pool pages; the caller
        owns the request's re-homing."""
        req = self.slot_req[slot]
        self.slot_req[slot] = None
        if self.pool is not None:
            self.pool.release_slot(slot)
        return req

    def run_until_drained(self, max_steps: int = 100_000) -> List[Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return self.done
