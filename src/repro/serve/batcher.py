"""Continuous batcher: slot-based request scheduling for decode.

A fixed-width decode batch (B slots) over a shared-shape KV cache; requests
join free slots, run until EOS/max_tokens, and free their slot.  Per-slot
positions (``pos`` is a vector) let slots be at different depths — the
model's decode path masks per-slot.  This is the serving front used by the
serving cells and the tail-latency benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    submitted_at: float = 0.0
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    output: List[int] = dataclasses.field(default_factory=list)

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at


class ContinuousBatcher:
    """Slot-based continuous batching over a single decode program."""

    def __init__(self, model, params, *, batch_slots: int, max_len: int,
                 temperature: float = 0.0, eos_token: Optional[int] = None):
        from repro.serve.serve_step import build_serve_step
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.eos = eos_token
        self.cache = model.init_cache(batch_slots, max_len)
        self.pos = np.zeros(batch_slots, np.int32)
        self.cur_tok = np.zeros(batch_slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.queue: deque = deque()
        self.done: List[Request] = []
        self._step = jax.jit(build_serve_step(model, temperature), donate_argnums=(1,))
        self._rng = jax.random.PRNGKey(0)

    # -- request management --------------------------------------------
    def submit(self, req: Request):
        req.submitted_at = req.submitted_at or time.monotonic()
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.B):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.popleft()
                req.started_at = time.monotonic()
                # the prompt is consumed token-at-a-time through the decode
                # path (shared cache keeps slot shapes uniform)
                self.slot_req[slot] = req
                self.pos[slot] = 0
                self.cur_tok[slot] = int(req.prompt[0]) if len(req.prompt) else 0
                req._prompt_cursor = 1  # type: ignore[attr-defined]

    # -- one decode step over all busy slots -----------------------------
    def step(self) -> int:
        self._admit()
        busy = [s for s in range(self.B) if self.slot_req[s] is not None]
        if not busy:
            return 0
        batch = {
            "tokens": jnp.asarray(self.cur_tok[:, None]),
            "pos": jnp.asarray(self.pos),
        }
        self._rng, sub = jax.random.split(self._rng)
        toks, _logits, self.cache = self._step(self.params, self.cache, batch, sub)
        toks = np.asarray(toks)
        now = time.monotonic()
        for s in busy:
            req = self.slot_req[s]
            self.pos[s] += 1
            cursor = getattr(req, "_prompt_cursor", len(req.prompt))
            if cursor < len(req.prompt):
                # still consuming the prompt: feed next prompt token
                self.cur_tok[s] = int(req.prompt[cursor])
                req._prompt_cursor = cursor + 1  # type: ignore[attr-defined]
                continue
            tok = int(toks[s])
            req.output.append(tok)
            self.cur_tok[s] = tok
            finished = (
                len(req.output) >= req.max_new_tokens
                or (self.eos is not None and tok == self.eos)
                or self.pos[s] >= self.max_len - 1
            )
            if finished:
                req.finished_at = now
                self.done.append(req)
                self.slot_req[s] = None
        return len(busy)

    def run_until_drained(self, max_steps: int = 100_000) -> List[Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return self.done
