"""Disaggregated serving: a prefill cell feeding a decode cell's batcher.

The paper's "isolate first, then share on demand" applied to inference::

    requests ->  [ prefill cell ]  --ArrayChannel(kind="kv")-->  [ decode cell ]
                 whole prompts,        per-request KV rows          continuous
                 1 invocation each     + first token (meta)         batching

Each cell is a subOS: it owns its zone/mesh outright and compiles its own
programs.  The ONLY coupling is the on-demand KV channel opened through the
supervisor — prefill never touches decode's devices except through
``send_kv`` (device_put onto the decode mesh), mirroring RFcom's explicit
resource-sharing surface.

Why disaggregate: prefill is compute-bound over whole prompts, decode is
latency-bound per token.  Co-scheduling them on one cell head-of-line
blocks decode steps behind prompt processing; isolating prefill keeps TPOT
flat while TTFT scales with prefill-cell capacity — and the elastic
``ThresholdScheduler`` can move columns between the two cells as the
prompt/decode load mix shifts (see ``benchmarks/disagg_serving.py``).

Weight placement: both cells need the same parameters.  If the prefill
cell has none, :class:`DisaggServer` syncs them from the decode cell over a
second on-demand channel at construction time (share-on-demand for weights,
too).

Indicative numbers (``benchmarks/disagg_serving.py --smoke``, CPU host,
prompts of 33-48 tokens): program invocations per prompt drop 39x (one
bucket-padded prefill vs one decode call per prompt token), TTFT p50 drops
~2.2x (3.38s -> 1.52s including compile), and the per-request KV handoff
moves ~35 KB/request over the channel.  On accelerators the invocation
count is the dominant TTFT term, so the reduction compounds.
"""
from __future__ import annotations

import time
from collections import deque
from typing import List, Optional

import jax

from repro.serve.batcher import ContinuousBatcher, Request
from repro.serve.serve_step import (
    build_prefill_step,
    run_prefill_prompt,
    supports_chunked_prefill,
)


class PrefillWorker:
    """Runs bucket-padded prefill programs on a (prefill) cell."""

    def __init__(self, cell, *, max_len: int, chunk: int = 32,
                 temperature: float = 0.0):
        if not supports_chunked_prefill(cell.model.cfg, max_len):
            raise ValueError(
                f"family {cell.model.cfg.family!r} has no exact chunked "
                "prefill (recurrent state / rolling cache)"
            )
        if cell.serve_params is None:
            cell.init_serve()
        self.cell = cell
        self.model = cell.model
        self.max_len = max_len
        self.chunk = chunk
        self._step = jax.jit(build_prefill_step(self.model, temperature))
        self._scratch_cache = None
        self._rng = jax.random.PRNGKey(0)
        self.invocations = 0

    def prefill(self, req: Request):
        """One program invocation -> (first_token, 1-row KV cache)."""
        L = len(req.prompt)
        if not 0 < L <= self.max_len - 1:
            raise ValueError(f"prompt length {L} does not fit max_len={self.max_len}")
        if self._scratch_cache is None:
            self._scratch_cache = self.model.init_cache(1, self.max_len)
        tok, row_cache, self._rng = run_prefill_prompt(
            self._step, self.cell.serve_params, self._scratch_cache,
            req.prompt, chunk=self.chunk, max_len=self.max_len, rng=self._rng,
        )
        self.invocations += 1
        self.cell.heartbeat()
        return tok, row_cache


class DisaggServer:
    """Prefill cell -> KV channel -> decode cell, behind one submit() front.

    The decode cell's batcher runs with ``prefill_chunk=None`` — it NEVER
    prefills; every request's KV rows arrive over the channel.  TTFT is the
    prefill invocation + one channel transfer; TPOT is pure decode.
    """

    def __init__(self, supervisor, prefill_cell: str, decode_cell: str, *,
                 batch_slots: int, max_len: int, chunk: int = 32,
                 temperature: float = 0.0, eos_token: Optional[int] = None):
        self.sup = supervisor
        self.prefill_cell = supervisor.cells[prefill_cell]
        self.decode_cell = supervisor.cells[decode_cell]
        self.max_len = max_len
        if self.decode_cell.serve_params is None:
            self.decode_cell.init_serve()
        if self.prefill_cell.serve_params is None:
            # share-on-demand weight sync: decode -> prefill
            wch = supervisor.open_channel(decode_cell, prefill_cell, kind="array")
            shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(self.prefill_cell.mesh, s),
                self.prefill_cell.model.params_pspecs(),
            )
            wch.send(self.decode_cell.serve_params, shardings)
            self.prefill_cell.serve_params = wch.recv()
            wch.close()
        self.worker = PrefillWorker(
            self.prefill_cell, max_len=max_len, chunk=chunk,
            temperature=temperature,
        )
        self.channel = supervisor.open_channel(prefill_cell, decode_cell, kind="kv")
        self.batcher: ContinuousBatcher = self.decode_cell.make_batcher(
            batch_slots=batch_slots, max_len=max_len, temperature=temperature,
            eos_token=eos_token, prefill_chunk=None,
        )
        # per-request target shardings on the decode mesh (1-row cache)
        self._kv_shardings = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(self.decode_cell.mesh, s),
            self.decode_cell.model.cache_pspecs(1, max_len),
        )
        self.pending: deque = deque()
        self._inflight = {}           # rid -> Request (sent, not yet installed)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.submitted_at = req.submitted_at or time.monotonic()
        self.pending.append(req)

    def _free_capacity(self) -> int:
        return len(self.batcher.free_slots()) - len(self._inflight)

    def pump(self) -> int:
        """Prefill waiting requests (up to the decode cell's free capacity),
        stream their KV over the channel, and install arrivals into free
        slots.  Returns the number of requests installed.

        Unservable prompts (empty, or longer than the decode cache) are
        finished immediately with empty output rather than poisoning the
        loop — one bad request must not stall every other request."""
        n = self._free_capacity()
        while self.pending and n > 0:
            req = self.pending.popleft()
            req.started_at = req.started_at or time.monotonic()
            if not 0 < len(req.prompt) <= self.max_len - 1:
                self.batcher._finish(req, time.monotonic())
                continue
            tok, row_cache = self.worker.prefill(req)
            self.channel.send_kv(
                row_cache, self._kv_shardings,
                meta={"rid": req.rid, "first_token": tok,
                      "prompt_len": len(req.prompt)},
            )
            self._inflight[req.rid] = req
            n -= 1
        installed = 0
        while True:
            env = self.channel.poll_kv()
            if env is None:
                break
            req = self._inflight.pop(env.meta["rid"])
            ok = self.batcher.install_prefilled(
                req, env.cache, env.meta["first_token"]
            )
            assert ok, "pump() never sends more KV than there are free slots"
            installed += 1
        return installed

    def step(self) -> int:
        """One scheduler tick: pump the handoff, then one decode step."""
        self.pump()
        n = self.batcher.step()
        self.decode_cell.heartbeat()
        return n

    def run_until_drained(self, max_steps: int = 100_000) -> List[Request]:
        steps = 0
        while (self.pending or self._inflight
               or any(r is not None for r in self.batcher.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return self.batcher.done

    @property
    def done(self) -> List[Request]:
        return self.batcher.done

    def stats(self) -> dict:
        return {
            "prefill_invocations": self.worker.invocations,
            "decode_invocations": self.batcher.decode_invocations,
            "kv_bytes": self.channel.bytes_sent,
            "kv_transfers": self.channel.transfers,
            "kv_seconds": self.channel.seconds,
            "decode_serving": self.decode_cell.accounting.serving_summary(),
        }
