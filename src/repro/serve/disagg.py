"""Disaggregated serving: a prefill cell feeding decode-cell replicas.

The paper's "isolate first, then share on demand" applied to inference::

                                    +--kv channel-->  [ decode cell 0 ]
    requests ->  [ prefill cell ]---+                    continuous
                 whole prompts,     +--kv channel-->  [ decode cell 1 ]
                 batched bucket        per-request KV    batching
                 invocations           rows + meta

Each cell is a subOS: it owns its zone/mesh outright and compiles its own
programs.  The ONLY coupling is the on-demand KV channels opened through
the supervisor — prefill never touches a decode cell's devices except
through ``send_kv`` (device_put onto that decode mesh), mirroring RFcom's
explicit resource-sharing surface.

Why disaggregate: prefill is compute-bound over whole prompts, decode is
latency-bound per token.  Co-scheduling them on one cell head-of-line
blocks decode steps behind prompt processing; isolating prefill keeps TPOT
flat while TTFT scales with prefill-cell capacity.  Decode capacity scales
out *declaratively*: a decode :class:`~repro.core.spec.CellSpec` with
``replicas=N`` materializes N uniform decode cells and the server routes
each request to the replica with the most free slots (per-request routing,
round-robin on ties).  Same-bucket prompts waiting together are prefilled
in ONE batched program invocation (see ``run_prefill_prompts``).

Weight placement: every cell needs the same parameters.  Cells that have
none sync them over on-demand array channels at construction time — decode
replica 0 is the source of truth, further replicas and the prefill cell
pull from it (share-on-demand for weights, too).

The elastic :class:`~repro.core.elastic.ReconcilePolicy` can rebalance
columns between the prefill and decode specs from live TTFT/TPOT
accounting (see ``benchmarks/disagg_serving.py``).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Union

import jax

from repro.serve.batcher import ContinuousBatcher, Request
from repro.serve.serve_step import (
    build_prefill_step,
    bucket_len,
    run_prefill_prompts,
    supports_chunked_prefill,
)


class PrefillWorker:
    """Runs bucket-padded prefill programs on a (prefill) cell."""

    def __init__(self, cell, *, max_len: int, chunk: int = 32,
                 temperature: float = 0.0):
        if not supports_chunked_prefill(cell.model.cfg, max_len):
            raise ValueError(
                f"family {cell.model.cfg.family!r} has no exact chunked "
                "prefill (recurrent state / rolling cache)"
            )
        if cell.serve_params is None:
            cell.init_serve()
        self.cell = cell
        self.model = cell.model
        self.max_len = max_len
        self.chunk = chunk
        self._step = jax.jit(build_prefill_step(self.model, temperature))
        self._scratch_caches: Dict[int, object] = {}
        self._axes = None
        self._rng = jax.random.PRNGKey(0)
        self.invocations = 0

    def _scratch(self, batch: int):
        if batch not in self._scratch_caches:
            self._scratch_caches[batch] = self.model.init_cache(batch, self.max_len)
        return self._scratch_caches[batch]

    def prefill_many(self, reqs: Sequence[Request]):
        """Prefill a batch of requests, ONE invocation per pad bucket.

        Batch dims are padded to the next power of two (dummy rows masked
        and discarded) so compiled variants stay O(log capacity) per
        bucket.  Returns ``[(req, first_token, 1-row cache), ...]`` in
        input order.
        """
        import numpy as np
        from repro.models.cache_utils import cache_batch_axes, slice_cache_slots
        if self._axes is None:
            self._axes = cache_batch_axes(self.model, 1, self.max_len)
        groups: Dict[int, List[Request]] = {}
        for req in reqs:
            L = len(req.prompt)
            if not 0 < L <= self.max_len - 1:
                raise ValueError(
                    f"prompt length {L} does not fit max_len={self.max_len}")
            groups.setdefault(bucket_len(L, self.chunk, self.max_len), []
                              ).append(req)
        out = {}
        for _, group in sorted(groups.items()):
            b_pad = 1 << (len(group) - 1).bit_length()
            prompts = [r.prompt for r in group]
            prompts += [np.zeros(0, np.int32)] * (b_pad - len(group))
            toks, cache, self._rng = run_prefill_prompts(
                self._step, self.cell.serve_params, self._scratch(b_pad),
                prompts, chunk=self.chunk, max_len=self.max_len, rng=self._rng,
            )
            self.invocations += 1
            for i, (req, tok) in enumerate(zip(group, toks)):
                out[req.rid] = (req, tok,
                                slice_cache_slots(cache, self._axes, [i]))
        self.cell.heartbeat()
        return [out[r.rid] for r in reqs]

    def prefill(self, req: Request):
        """One request -> (first_token, 1-row KV cache)."""
        (_, tok, row_cache), = self.prefill_many([req])
        return tok, row_cache


class _DecodeReplica:
    """One decode cell's serving surface: batcher + KV channel + shardings."""

    def __init__(self, cell, channel, batcher, kv_shardings):
        self.cell = cell
        self.channel = channel
        self.batcher = batcher
        self.kv_shardings = kv_shardings
        self.inflight: Dict[int, Request] = {}   # rid -> sent, not installed

    def free_capacity(self) -> int:
        return len(self.batcher.free_slots()) - len(self.inflight)


class DisaggServer:
    """Prefill cell -> KV channels -> decode replica(s), one submit() front.

    ``decode_cells`` is a cell name or a list of replica cell names (e.g.
    ``spec.cell("decode").instances()``).  Each replica's batcher runs
    with ``prefill_chunk=None`` — it NEVER prefills; every request's KV
    rows arrive over its channel.  TTFT is the (possibly batched) prefill
    invocation + one channel transfer; TPOT is pure decode.
    """

    def __init__(self, supervisor, prefill_cell: str,
                 decode_cells: Union[str, Sequence[str]], *,
                 batch_slots: int, max_len: int, chunk: int = 32,
                 temperature: float = 0.0, eos_token: Optional[int] = None):
        if isinstance(decode_cells, str):
            decode_cells = [decode_cells]
        if not decode_cells:
            raise ValueError("need at least one decode cell")
        self.sup = supervisor
        self.prefill_cell = supervisor.cells[prefill_cell]
        self.max_len = max_len

        primary = supervisor.cells[decode_cells[0]]
        if primary.serve_params is None:
            primary.init_serve()
        # share-on-demand weight sync: primary decode -> later replicas,
        # primary decode -> prefill (each over its own array channel)
        sync_to = [n for n in decode_cells[1:]
                   if supervisor.cells[n].serve_params is None]
        if self.prefill_cell.serve_params is None:
            sync_to.append(prefill_cell)
        for name in sync_to:
            dst = supervisor.cells[name]
            wch = (supervisor.find_channel(decode_cells[0], name, "array")
                   or supervisor.open_channel(decode_cells[0], name, kind="array"))
            shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(dst.mesh, s),
                dst.model.params_pspecs(),
            )
            wch.send(primary.serve_params, shardings)
            dst.serve_params = wch.recv()

        self.worker = PrefillWorker(
            self.prefill_cell, max_len=max_len, chunk=chunk,
            temperature=temperature,
        )
        self.replicas: List[_DecodeReplica] = []
        for name in decode_cells:
            cell = supervisor.cells[name]
            ch = (supervisor.find_channel(prefill_cell, name, "kv")
                  or supervisor.open_channel(prefill_cell, name, kind="kv"))
            batcher = cell.make_batcher(
                batch_slots=batch_slots, max_len=max_len,
                temperature=temperature, eos_token=eos_token,
                prefill_chunk=None,
            )
            kv_shardings = jax.tree.map(
                lambda s, m=cell.mesh: jax.sharding.NamedSharding(m, s),
                cell.model.cache_pspecs(1, max_len),
            )
            self.replicas.append(_DecodeReplica(cell, ch, batcher, kv_shardings))
        self.pending: deque = deque()
        self.rejected: List[Request] = []   # unservable, never routed
        self._rr = 0                    # round-robin cursor for routing ties

    # -- legacy single-replica surface ---------------------------------
    @property
    def decode_cell(self):
        return self.replicas[0].cell

    @property
    def batcher(self) -> ContinuousBatcher:
        return self.replicas[0].batcher

    @property
    def channel(self):
        return self.replicas[0].channel

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.submitted_at = req.submitted_at or time.monotonic()
        self.pending.append(req)

    def _route(self, capacity: Dict[int, int]) -> Optional[int]:
        """Pick the replica with the most free capacity (per-request
        routing); round-robin breaks ties so uniform load spreads."""
        best, best_cap = None, 0
        n = len(self.replicas)
        for off in range(n):
            i = (self._rr + off) % n
            if capacity[i] > best_cap:
                best, best_cap = i, capacity[i]
        if best is not None:
            self._rr = (best + 1) % n
        return best

    def pump(self) -> int:
        """Prefill waiting requests (up to the replicas' free capacity,
        batching same-bucket prompts into one invocation), stream their KV
        over the per-replica channels, and install arrivals into free
        slots.  Returns the number of requests installed.

        Unservable prompts (empty, or longer than the decode cache) are
        finished immediately with empty output rather than poisoning the
        loop — one bad request must not stall every other request."""
        capacity = {i: r.free_capacity() for i, r in enumerate(self.replicas)}
        budget = sum(c for c in capacity.values() if c > 0)
        taking: List[Request] = []
        while self.pending and len(taking) < budget:
            req = self.pending.popleft()
            req.started_at = req.started_at or time.monotonic()
            if not 0 < len(req.prompt) <= self.max_len - 1:
                # never reached a replica: finish with empty output here so
                # per-replica stats/accounting only count routed traffic
                req.finished_at = time.monotonic()
                self.rejected.append(req)
                continue
            taking.append(req)
        if taking:
            for req, tok, row_cache in self.worker.prefill_many(taking):
                i = self._route(capacity)
                assert i is not None, "capacity budget guarantees a replica"
                capacity[i] -= 1
                rep = self.replicas[i]
                rep.channel.send_kv(
                    row_cache, rep.kv_shardings,
                    meta={"rid": req.rid, "first_token": tok,
                          "prompt_len": len(req.prompt)},
                )
                rep.inflight[req.rid] = req
        installed = 0
        for rep in self.replicas:
            while True:
                env = rep.channel.poll_kv()
                if env is None:
                    break
                req = rep.inflight.pop(env.meta["rid"])
                ok = rep.batcher.install_prefilled(
                    req, env.cache, env.meta["first_token"]
                )
                assert ok, "pump() never sends more KV than there are free slots"
                installed += 1
        return installed

    def step(self) -> int:
        """One scheduler tick: pump the handoff, then one decode step on
        every replica with busy slots."""
        self.pump()
        n = 0
        for rep in self.replicas:
            n += rep.batcher.step()
            rep.cell.heartbeat()
        return n

    def _busy(self) -> bool:
        return bool(
            self.pending
            or any(rep.inflight for rep in self.replicas)
            or any(r is not None for rep in self.replicas
                   for r in rep.batcher.slot_req)
        )

    def run_until_drained(self, max_steps: int = 100_000) -> List[Request]:
        steps = 0
        while self._busy() and steps < max_steps:
            self.step()
            steps += 1
        return self.done

    @property
    def done(self) -> List[Request]:
        out: List[Request] = list(self.rejected)
        for rep in self.replicas:
            out.extend(rep.batcher.done)
        return out

    def stats(self) -> dict:
        from repro.core.accounting import summarize_requests
        return {
            "decode_serving": summarize_requests(self.done),
            "prefill_invocations": self.worker.invocations,
            "decode_invocations": sum(r.batcher.decode_invocations
                                      for r in self.replicas),
            "kv_bytes": sum(r.channel.bytes_sent for r in self.replicas),
            "kv_transfers": sum(r.channel.transfers for r in self.replicas),
            "kv_seconds": sum(r.channel.seconds for r in self.replicas),
            "replicas": len(self.replicas),
            "per_replica_requests": [len(r.batcher.done) for r in self.replicas],
        }
